#!/usr/bin/env python3
"""detlint — determinism & robustness static analysis for rust/src/**.

Every pinned guarantee in this repo (bit-identical FleetRecords across
host_threads, byte-identical kill/resume snapshots, plan-independent RNG
streams) rests on conventions: seeded RNG only, blessed float-fold
kernels, wall-clock reads confined to the clock/timer/bench seam, sorted
JSON keys. This scanner enforces those conventions mechanically, with no
Rust toolchain required — it tokenizes the source (comment- and
string-aware, raw strings and nested block comments included) and runs a
rule registry over the code text only.

Rules
-----
  D001  wall-clock read (`Instant::now` / `SystemTime::now`) outside the
        blessed clock seam: util/{clock,timer,bench}.rs. Wall time may
        only reach host-profiling fields and log stamps, never a
        deterministic record field.
  D002  iteration over a HashMap/HashSet (`.iter()`, `.keys()`, `for in`,
        `.drain()`, ...) in a module that feeds records, telemetry, or
        snapshots. Iteration order is seeded per-process; use sorted keys
        or a BTreeMap (util::json already sorts object keys).
  D003  ambient randomness (`thread_rng`, `from_entropy`, `OsRng`,
        `rand::random`, `RandomState::new`, `getrandom`). All entropy
        must flow from explicit seeds (`seed ^ 0x...` derivations).
  D004  floating-point fold (`.sum::<f32/f64>()`, float-seeded `.fold`,
        or a `+=` reduction over a float accumulator inside a loop)
        outside the blessed kernels util/{simd,stats}.rs, which exist to
        pin fold order.
  D005  unscoped thread creation (`thread::spawn` / `thread::Builder`)
        outside the coordinator host/pipeline/session seam. Only scoped,
        join-guarded threading keeps panics and shutdown deterministic.
  R001  `.unwrap()` / `.expect(` / `panic!(` in non-test library code.
        The fault-supervision plane turns failures into SessionStatus;
        aborts bypass it.
  R002  `let _ =` silently discarding a value (usually a Result).
  R003  raw file write (`std::fs::write` / `File::create`) outside the
        blessed durability seam util/durable_io.rs. A raw write is
        neither atomic nor torn-write safe; checkpoints go through the
        vault, everything else through durable_io's helpers.
  C001  narrowing numeric cast (`as f32`, float `as usize`/ints) on a
        record/telemetry path — use a checked conversion or document the
        invariant.
  P001  malformed detlint pragma (unknown rule or missing reason).
        Never suppressible, never baselineable.

Pragmas
-------
An inline escape hatch with a mandatory reason:

    do_thing().unwrap(); // detlint: allow(R001) init-only; config was validated above

A pragma on a comment-only line applies to the next line carrying code:

    // detlint: allow(D004) host-clock aggregate, not a deterministic field
    total_host_ms += shard_ms;

Multiple rules: `// detlint: allow(R001,R002) reason`.

Baseline ratchet
----------------
`--baseline detlint_baseline.json` grandfathers existing findings as
per-(file, rule) counts. A count above its baseline entry fails (new
finding); a count below it fails as *stale* (the ratchet only turns one
way: re-run with --write-baseline to lock the improvement in) unless
--allow-stale is given. `--write-baseline` regenerates the counts,
preserving any "notes" block in the existing file.

Exit codes: 0 clean (or fully ratcheted), 1 findings/new/stale/P001,
2 usage error.

Run `scripts/test_detlint.py` for the tokenizer unit tests and the
fixture corpus under scripts/testdata/detlint/.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
from collections import Counter
from dataclasses import dataclass

# --------------------------------------------------------------- registry

RULES = {
    "D001": "wall-clock read outside the blessed clock seam (util::{clock,timer,bench})",
    "D002": "HashMap/HashSet iteration in a record/telemetry/snapshot-feeding module",
    "D003": "ambient (unseeded) randomness; entropy must flow from explicit seeds",
    "D004": "floating-point fold outside the blessed kernels (util::{simd,stats})",
    "D005": "unscoped thread creation outside the coordinator threading seam",
    "R001": ".unwrap()/.expect()/panic! in non-test library code",
    "R002": "value silently discarded with `let _ =`",
    "R003": "raw file write outside the blessed durability seam (util::durable_io)",
    "C001": "narrowing numeric cast on a record/telemetry path",
    "P001": "malformed detlint pragma (unknown rule or missing reason)",
}

# Module scoping, as paths relative to rust/src (directories end in "/").
SCOPE = {
    "d001_blessed": ("util/clock.rs", "util/timer.rs", "util/bench.rs"),
    "d002_scope": ("coordinator/", "retention/", "fault/", "fl/", "metrics/", "data/", "exp/"),
    "d004_blessed": ("util/simd.rs", "util/stats.rs"),
    "d005_allowed": ("coordinator/host.rs", "coordinator/pipeline.rs", "coordinator/session.rs"),
    "c001_scope": ("coordinator/", "metrics/", "retention/", "fl/", "fault/"),
    "r003_blessed": ("util/durable_io.rs",),
}


def in_scope(rel, paths):
    return any(rel == p or (p.endswith("/") and rel.startswith(p)) for p in paths)


@dataclass(frozen=True)
class Finding:
    path: str  # relative to rust/src, "/"-separated
    line: int  # 1-based
    rule: str
    message: str
    snippet: str

    def render(self):
        return f"rust/src/{self.path}:{self.line}: {self.rule} {self.message}\n    {self.snippet}"

    def to_json(self):
        return {
            "path": f"rust/src/{self.path}",
            "line": self.line,
            "rule": self.rule,
            "message": self.message,
            "snippet": self.snippet,
        }


# -------------------------------------------------------------- tokenizer

RAW_STR = re.compile(r'b?r(#*)"')
CHAR_LIT = re.compile(r"'(?:\\u\{[0-9a-fA-F_]+\}|\\.|[^\\'\n])'")


def tokenize(text):
    """Split Rust source into (code_lines, comment_lines).

    code_lines[i] is line i with comment and string/char-literal *content*
    replaced by spaces (delimiters kept), so rule regexes can never match
    inside a string or comment. comment_lines[i] is the comment text on
    line i (for pragma parsing). Handles nested block comments, (byte)
    raw strings r#"..."#, escapes, and char literals vs. lifetimes.
    """
    code, comment = [], []
    cur_code, cur_comment = [], []

    def flush():
        code.append("".join(cur_code))
        comment.append("".join(cur_comment))
        cur_code.clear()
        cur_comment.clear()

    i, n = 0, len(text)
    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "\n":
            flush()
            i += 1
        elif c == "/" and nxt == "/":
            while i < n and text[i] != "\n":
                cur_comment.append(text[i])
                cur_code.append(" ")
                i += 1
        elif c == "/" and nxt == "*":
            depth = 0
            while i < n:
                if text[i] == "/" and i + 1 < n and text[i + 1] == "*":
                    depth += 1
                    cur_comment.append("/*")
                    cur_code.append("  ")
                    i += 2
                elif text[i] == "*" and i + 1 < n and text[i + 1] == "/":
                    depth -= 1
                    cur_comment.append("*/")
                    cur_code.append("  ")
                    i += 2
                    if depth == 0:
                        break
                elif text[i] == "\n":
                    flush()
                    i += 1
                else:
                    cur_comment.append(text[i])
                    cur_code.append(" ")
                    i += 1
        elif c in "br" and (m := RAW_STR.match(text, i)) and (i == 0 or not (text[i - 1].isalnum() or text[i - 1] == "_")):
            hashes = m.group(1)
            cur_code.append(m.group(0))
            i = m.end()
            close = '"' + hashes
            while i < n:
                if text.startswith(close, i):
                    cur_code.append(close)
                    i += len(close)
                    break
                if text[i] == "\n":
                    flush()
                else:
                    cur_code.append(" ")
                i += 1
        elif c == '"':
            cur_code.append('"')
            i += 1
            while i < n:
                if text[i] == "\\" and i + 1 < n:
                    if text[i + 1] == "\n":
                        cur_code.append(" ")
                        flush()
                    else:
                        cur_code.append("  ")
                    i += 2
                elif text[i] == '"':
                    cur_code.append('"')
                    i += 1
                    break
                elif text[i] == "\n":
                    flush()
                    i += 1
                else:
                    cur_code.append(" ")
                    i += 1
        elif c == "'":
            m = CHAR_LIT.match(text, i)
            if m:
                cur_code.append("'" + " " * (len(m.group(0)) - 2) + "'")
                i = m.end()
            else:  # lifetime
                cur_code.append("'")
                i += 1
        else:
            cur_code.append(c)
            i += 1
    if cur_code or cur_comment:
        flush()
    return code, comment


# ---------------------------------------------------- regions over code text


def brace_region(code_text, start):
    """End offset of the item starting at `start`: the close of its first
    `{...}` block, or the first top-level `;` before any brace."""
    depth = 0
    opened = False
    i = start
    n = len(code_text)
    while i < n:
        ch = code_text[i]
        if ch == "{":
            depth += 1
            opened = True
        elif ch == "}":
            depth -= 1
            if opened and depth <= 0:
                return i
        elif ch == ";" and not opened and depth == 0:
            return i
        i += 1
    return n - 1


def line_starts(code_text):
    starts = [0]
    for m in re.finditer(r"\n", code_text):
        starts.append(m.end())
    return starts


def offsets_to_lines(starts, lo, hi):
    """0-based line indices covered by [lo, hi] offsets."""
    import bisect

    first = bisect.bisect_right(starts, lo) - 1
    last = bisect.bisect_right(starts, hi) - 1
    return range(first, last + 1)


TEST_ATTR = re.compile(r"#\[\s*(?:cfg\s*\(\s*(?:test\b|all\s*\(\s*test\b)|test\s*\])")
LOOP_HEAD = re.compile(r"\b(?:for|while)\b|\bloop\s*\{")


def mark_regions(code_text, starts, pattern):
    lines = set()
    covered_until = -1
    for m in pattern.finditer(code_text):
        if m.start() <= covered_until:
            continue
        end = brace_region(code_text, m.start())
        covered_until = max(covered_until, end)
        lines.update(offsets_to_lines(starts, m.start(), end))
    return lines


# ------------------------------------------------------------ rule patterns

RE_D001 = re.compile(r"\b(?:Instant|SystemTime)\s*::\s*now\b")
RE_D003 = re.compile(
    r"\bthread_rng\s*\(|\bfrom_entropy\b|\bOsRng\b|\brand\s*::\s*random\b"
    r"|\bRandomState\s*::\s*new\b|\bgetrandom\b"
)
RE_D004_ITER = re.compile(r"\.\s*(?:sum|product)\s*::\s*<\s*f(?:32|64)\s*>")
RE_D004_FOLD = re.compile(
    r"\.\s*fold\s*\(\s*(?:-?\d+\.\d*(?:_?f(?:32|64))?|-?\d+_?f(?:32|64)"
    r"|f(?:32|64)\s*::\s*(?:NEG_INFINITY|INFINITY|MIN|MAX|EPSILON))"
)
RE_D004_ADD = re.compile(r"\b(?:self\s*\.\s*)?(?:\w+\s*\.\s*)*(\w+)\s*(?:\[[^\]]*\])?\s*\+=")
RE_D005 = re.compile(r"\bthread\s*::\s*(?:spawn\s*\(|Builder\b)")
#  `.expect(` is only Option/Result::expect when its argument is a panic
#  message (string literal or format!); parsers with their own byte-level
#  `expect(b'{')` methods stay unflagged.
RE_R001 = re.compile(r"\.\s*unwrap\s*\(\s*\)|\.\s*expect\s*\(\s*(?:\"|&?\s*format!)|\bpanic!\s*[(\[{]")
RE_R002 = re.compile(r"^\s*let\s+_\s*=")
RE_R003 = re.compile(r"\bfs\s*::\s*write\s*\(|\bFile\s*::\s*create\s*\(")
RE_C001_F32 = re.compile(r"\bas\s+f32\b")
RE_C001_INT = re.compile(r"(?:\bf(?:32|64)\b|\d\.\d*)\s+as\s+(?:usize|u(?:8|16|32|64|128)|i(?:8|16|32|64|128))\b")

RE_FLOAT_DECL = [
    re.compile(r"\blet\s+mut\s+(\w+)\s*=\s*-?(?:\d+\.\d*|\d+_?f(?:32|64))"),
    re.compile(r"\blet\s+mut\s+(\w+)\s*:\s*f(?:32|64)\b"),
    re.compile(r"\blet\s+mut\s+(\w+)\s*(?::[^=;]*)?=\s*vec!\s*\[\s*0(?:\.\d*(?:_?f(?:32|64))?|_?f(?:32|64))\s*;"),
    re.compile(r"\b(\w+)\s*:\s*f(?:32|64)\b"),
]
RE_HASH_DECL = [
    re.compile(r"\b(\w+)\s*:\s*(?:&\s*(?:mut\s+)?)?(?:std\s*::\s*collections\s*::\s*)?Hash(?:Map|Set)\b"),
    re.compile(r"\blet\s+(?:mut\s+)?(\w+)\s*(?::[^=;]*)?=\s*(?:std\s*::\s*collections\s*::\s*)?Hash(?:Map|Set)\s*::"),
]
HASH_ITER_METHODS = r"iter|iter_mut|keys|values|values_mut|into_iter|drain|retain"

PRAGMA = re.compile(r"detlint:\s*allow\s*\(([^)]*)\)\s*(.*)")


# ---------------------------------------------------------------- scanning


def collect_idents(code_lines, patterns, skip_lines=()):
    idents = set()
    for i, line in enumerate(code_lines):
        if i in skip_lines:
            continue
        for pat in patterns:
            for m in pat.finditer(line):
                idents.add(m.group(1))
    return idents


def parse_pragmas(code_lines, comment_lines):
    """Return (allow: {0-based line -> set(rules)}, errors: [Finding-args]).

    A pragma on a comment-only line applies to the next line carrying
    code; an inline pragma applies to its own line.
    """
    allow = {}
    errors = []
    n = len(code_lines)
    for i, comment in enumerate(comment_lines):
        m = PRAGMA.search(comment)
        if not m:
            continue
        rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
        reason = m.group(2).strip()
        bad = [r for r in rules if r not in RULES or r == "P001"]
        if bad or not rules:
            errors.append((i + 1, f"unknown rule(s) {bad or '(none)'} in pragma"))
            continue
        if not reason:
            errors.append((i + 1, f"pragma allow({','.join(rules)}) is missing its mandatory reason"))
            continue
        target = i
        if not code_lines[i].strip():  # standalone comment: next code line
            target = next((j for j in range(i + 1, n) if code_lines[j].strip()), None)
            if target is None:
                errors.append((i + 1, "standalone pragma at end of file applies to nothing"))
                continue
        allow.setdefault(target, set()).update(rules)
    return allow, errors


def scan_file(rel, text):
    """Scan one file; returns (kept_findings, suppressed_count)."""
    code_lines, comment_lines = tokenize(text)
    code_text = "\n".join(code_lines)
    starts = line_starts(code_text)
    test_lines = mark_regions(code_text, starts, TEST_ATTR)
    loop_lines = mark_regions(code_text, starts, LOOP_HEAD)
    float_idents = collect_idents(code_lines, RE_FLOAT_DECL, test_lines)
    hash_idents = collect_idents(code_lines, RE_HASH_DECL, test_lines)
    hash_use = None
    if hash_idents:
        alt = "|".join(sorted(re.escape(x) for x in hash_idents))
        hash_use = re.compile(
            rf"\b(?:self\s*\.\s*)?(?:{alt})\s*\.\s*(?:{HASH_ITER_METHODS})\s*\("
            rf"|\bfor\s+[^;{{]*?\bin\s+&?(?:mut\s+)?(?:self\s*\.\s*)?(?:{alt})\b"
        )

    allow, pragma_errors = parse_pragmas(code_lines, comment_lines)
    raw = []

    def hit(i, rule, message):
        snippet = " ".join((text.splitlines()[i] if i < len(text.splitlines()) else "").split())
        raw.append(Finding(rel, i + 1, rule, message, snippet[:160]))

    for i, line in enumerate(code_lines):
        if i in test_lines or not line.strip():
            continue
        if RE_D001.search(line) and not in_scope(rel, SCOPE["d001_blessed"]):
            hit(i, "D001", RULES["D001"])
        if hash_use and in_scope(rel, SCOPE["d002_scope"]) and hash_use.search(line):
            hit(i, "D002", RULES["D002"])
        if RE_D003.search(line):
            hit(i, "D003", RULES["D003"])
        if not in_scope(rel, SCOPE["d004_blessed"]):
            if RE_D004_ITER.search(line) or RE_D004_FOLD.search(line):
                hit(i, "D004", RULES["D004"])
            elif i in loop_lines:
                for m in RE_D004_ADD.finditer(line):
                    if m.group(1) in float_idents:
                        hit(i, "D004", RULES["D004"] + f" (`{m.group(1)} +=` reduction)")
                        break
        if RE_D005.search(line) and not in_scope(rel, SCOPE["d005_allowed"]):
            hit(i, "D005", RULES["D005"])
        if RE_R001.search(line):
            hit(i, "R001", RULES["R001"])
        if RE_R002.search(line):
            hit(i, "R002", RULES["R002"])
        if RE_R003.search(line) and not in_scope(rel, SCOPE["r003_blessed"]):
            hit(i, "R003", RULES["R003"])
        if in_scope(rel, SCOPE["c001_scope"]) and (RE_C001_F32.search(line) or RE_C001_INT.search(line)):
            hit(i, "C001", RULES["C001"])

    kept, suppressed = [], 0
    for f in raw:
        if f.rule in allow.get(f.line - 1, ()):
            suppressed += 1
        else:
            kept.append(f)
    for line_no, msg in pragma_errors:
        kept.append(Finding(rel, line_no, "P001", msg, ""))
    return kept, suppressed


def scan_tree(root):
    src = os.path.join(root, "rust", "src")
    if not os.path.isdir(src):
        raise SystemExit(f"detlint: no rust/src under {root!r}")
    findings, suppressed = [], 0
    for dirpath, dirnames, filenames in sorted(os.walk(src)):
        dirnames.sort()
        for name in sorted(filenames):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, src).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            got, sup = scan_file(rel, text)
            findings.extend(got)
            suppressed += sup
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, suppressed


# ---------------------------------------------------------------- baseline


def load_baseline(path):
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    if data.get("version") != 1:
        raise SystemExit(f"detlint: unsupported baseline version in {path}")
    return data


def counts_of(findings):
    counts = Counter((f.path, f.rule) for f in findings if f.rule != "P001")
    return counts


def write_baseline(path, findings, old_notes=None):
    counts = counts_of(findings)
    entries = {}
    for (p, rule), cnt in sorted(counts.items()):
        entries.setdefault(p, {})[rule] = cnt
    data = {
        "version": 1,
        "generated_by": "scripts/detlint.py --write-baseline",
        "total": sum(counts.values()),
        "entries": entries,
    }
    if old_notes:
        data["notes"] = old_notes
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return data


def compare(findings, baseline):
    """Partition findings into (new, covered) and find stale baseline keys."""
    entries = baseline.get("entries", {})
    base = {(p, r): c for p, rules in entries.items() for r, c in rules.items()}
    declared = baseline.get("total")
    if declared is not None and declared != sum(base.values()):
        raise SystemExit(
            "detlint: baseline tampered — 'total' does not match the sum of entries"
        )
    counts = counts_of(findings)
    new, covered = [], []
    for f in findings:
        if f.rule == "P001":
            new.append(f)
        elif counts[(f.path, f.rule)] > base.get((f.path, f.rule), 0):
            new.append(f)  # every finding of an over-budget (file, rule) is reported
        else:
            covered.append(f)
    stale = sorted(
        (p, r, c, counts.get((p, r), 0)) for (p, r), c in base.items() if counts.get((p, r), 0) < c
    )
    return new, covered, stale


# -------------------------------------------------------------------- main


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="detlint", description="determinism & robustness lint over rust/src/**"
    )
    ap.add_argument("--root", default=None, help="repo root (default: the script's parent repo)")
    ap.add_argument("--baseline", default=None, help="grandfathered-findings ratchet file")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write the current findings as the new baseline and exit 0")
    ap.add_argument("--json", action="store_true", help="machine-readable report on stdout")
    ap.add_argument("--all", action="store_true", help="also print baseline-covered findings")
    ap.add_argument("--allow-stale", action="store_true",
                    help="do not fail when the tree beats the baseline (ratchet not locked)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule}  {desc}")
        return 0

    root = args.root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings, suppressed = scan_tree(root)

    if args.write_baseline:
        old_notes = None
        if os.path.exists(args.write_baseline):
            old_notes = load_baseline(args.write_baseline).get("notes")
        data = write_baseline(args.write_baseline, findings, old_notes)
        print(f"detlint: baseline written to {args.write_baseline} "
              f"({data['total']} grandfathered findings, {suppressed} pragma-suppressed)")
        return 0

    if args.baseline:
        new, covered, stale = compare(findings, load_baseline(args.baseline))
    else:
        new, covered, stale = findings, [], []

    if args.json:
        print(json.dumps({
            "rules": RULES,
            "findings": [f.to_json() for f in new],
            "baseline_covered": [f.to_json() for f in covered],
            "stale": [{"path": f"rust/src/{p}", "rule": r, "baseline": c, "current": cur}
                      for p, r, c, cur in stale],
            "suppressed": suppressed,
            "counts": {r: c for r, c in sorted(Counter(f.rule for f in findings).items())},
        }, indent=2, sort_keys=True))
    else:
        shown = new + (covered if args.all else [])
        shown.sort(key=lambda f: (f.path, f.line, f.rule))
        for f in shown:
            tag = "" if f in new or f.rule == "P001" else " [baseline]"
            print(f.render() + tag)
        for p, r, c, cur in stale:
            print(f"rust/src/{p}: {r} improved {c} -> {cur}; baseline is stale "
                  f"(lock the ratchet: detlint.py --write-baseline)")
        status = []
        if new:
            status.append(f"{len(new)} finding(s)")
        if covered:
            status.append(f"{len(covered)} baseline-covered")
        if suppressed:
            status.append(f"{suppressed} pragma-suppressed")
        if stale:
            status.append(f"{len(stale)} stale baseline entr(y/ies)")
        print(f"detlint: {', '.join(status) if status else 'clean'}")

    if new or (stale and not args.allow_stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
