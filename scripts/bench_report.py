#!/usr/bin/env python3
"""Emit BENCH_<group>.json trajectory files from the bench harness output.

The Rust bench harness (`titan::util::bench::Bencher`) writes raw
per-iteration summaries to ``rust/results/bench_<group>.json``. This script
post-processes the groups that track the data-plane hot paths into compact
repo-root files (``BENCH_filter.json``, ``BENCH_selection.json``) so future
PRs can diff throughput numbers without re-parsing harness output.

Per entry it reports:

- ``mean_ns`` / ``p50_ns``  — straight from the harness;
- ``n``                     — batch size parsed from a ``_n<digits>`` name
                              suffix (1 if absent);
- ``ns_per_sample``         — ``mean_ns / n``, the headline number;
- ``throughput_msps``       — million samples per second.

For old-vs-new pairs (``*_ref_n<k>`` vs the optimized name) it also emits a
``speedups`` map, e.g. ``{"score_chunk_n1024": 2.7}`` meaning the optimized
path is 2.7x the reference at n=1024.

Usage: python3 scripts/bench_report.py  (run from anywhere; paths are
repo-relative to this file)
"""

import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
RESULTS = REPO / "rust" / "results"
GROUPS = ("filter", "selection")

N_SUFFIX = re.compile(r"_n(\d+)(?:/|$)")


def batch_size(name: str) -> int:
    m = N_SUFFIX.search(name)
    return int(m.group(1)) if m else 1


def load(group: str):
    path = RESULTS / f"bench_{group}.json"
    if not path.exists():
        return None
    with path.open() as f:
        return json.load(f)


def report(group: str, entries) -> dict:
    rows = []
    by_name = {}
    for e in entries:
        n = batch_size(e["name"])
        row = {
            "name": e["name"],
            "n": n,
            "mean_ns": e["mean_ns"],
            "p50_ns": e["p50_ns"],
            "ns_per_sample": e["mean_ns"] / n,
            "throughput_msps": (1e3 / (e["mean_ns"] / n)) if e["mean_ns"] > 0 else 0.0,
        }
        rows.append(row)
        by_name[e["name"]] = row

    # old-vs-new speedups: every *_ref* entry vs its optimized sibling
    # (same name with the "_ref" marker stripped)
    speedups = {}
    for name, row in by_name.items():
        if "_ref" not in name:
            continue
        fast_name = name.replace("_ref", "", 1)
        fast = by_name.get(fast_name)
        if fast and fast["mean_ns"] > 0:
            speedups[fast_name] = round(row["mean_ns"] / fast["mean_ns"], 3)

    return {"group": group, "entries": rows, "speedups": speedups}


def main() -> int:
    wrote = 0
    for group in GROUPS:
        entries = load(group)
        if entries is None:
            print(f"skipping {group}: no rust/results/bench_{group}.json "
                  f"(run scripts/bench_smoke.sh first)", file=sys.stderr)
            continue
        out = REPO / f"BENCH_{group}.json"
        with out.open("w") as f:
            json.dump(report(group, entries), f, indent=2)
            f.write("\n")
        print(f"wrote {out}")
        wrote += 1
    return 0 if wrote else 1


if __name__ == "__main__":
    sys.exit(main())
