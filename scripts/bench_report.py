#!/usr/bin/env python3
"""Emit BENCH_<group>.json trajectory files from the bench harness output.

The Rust bench harness (`titan::util::bench::Bencher`) writes raw
per-iteration summaries to ``rust/results/bench_<group>.json``. This script
post-processes the groups that track the data-plane hot paths into compact
repo-root files (``BENCH_filter.json``, ``BENCH_selection.json``,
``BENCH_fleet.json``) so future PRs can diff throughput numbers without
re-parsing harness output.

Per entry it reports:

- ``mean_ns`` / ``p50_ns``  — straight from the harness;
- ``n``                     — batch size parsed from a ``_n<digits>`` name
                              segment (1 if absent; a trailing qualifier
                              like ``fleet_rr_n1000_t4`` is fine);
- ``ns_per_sample``         — ``mean_ns / n``, the headline number;
- ``throughput_msps``       — million samples per second.

For old-vs-new pairs (``*_ref_n<k>`` vs the optimized name) it also emits a
``speedups`` map, e.g. ``{"score_chunk_n1024": 2.7}`` meaning the optimized
path is 2.7x the reference at n=1024.

Regression gate: ``--regress-threshold X`` compares the freshly measured
``speedups`` against the **committed** ``BENCH_*.json`` baselines: every
speedup key present in a baseline must come out >= X in the new
measurement. On failure the script exits non-zero and leaves the baseline
files untouched (overwriting them with the regressed numbers would make
the next run gate against the regression itself). ``--check-only`` skips
the rewrite even on success — CI runs the gate in fast (smoke) mode, and
passing-but-noisy smoke numbers must not replace a full-``cargo bench``
trajectory; refreshing the committed baselines is a deliberate
full-bench + plain ``bench_report.py`` step. Empty baselines (the
placeholder files committed from environments that cannot run ``cargo
bench``) gate nothing.

Usage: python3 scripts/bench_report.py [--regress-threshold X] [--check-only]
(run from anywhere; paths are repo-relative to this file)
"""

import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
RESULTS = REPO / "rust" / "results"
GROUPS = ("filter", "selection", "fleet")

N_SUFFIX = re.compile(r"_n(\d+)(?=[_/]|$)")


def batch_size(name: str) -> int:
    m = N_SUFFIX.search(name)
    return int(m.group(1)) if m else 1


def load(group: str):
    path = RESULTS / f"bench_{group}.json"
    if not path.exists():
        return None
    with path.open() as f:
        return json.load(f)


def report(group: str, entries) -> dict:
    rows = []
    by_name = {}
    for e in entries:
        n = batch_size(e["name"])
        row = {
            "name": e["name"],
            "n": n,
            "mean_ns": e["mean_ns"],
            "p50_ns": e["p50_ns"],
            "ns_per_sample": e["mean_ns"] / n,
            "throughput_msps": (1e3 / (e["mean_ns"] / n)) if e["mean_ns"] > 0 else 0.0,
        }
        rows.append(row)
        by_name[e["name"]] = row

    # old-vs-new speedups: every *_ref* entry vs its optimized sibling
    # (same name with the "_ref" marker stripped)
    speedups = {}
    for name, row in by_name.items():
        if "_ref" not in name:
            continue
        fast_name = name.replace("_ref", "", 1)
        fast = by_name.get(fast_name)
        if fast and fast["mean_ns"] > 0:
            speedups[fast_name] = round(row["mean_ns"] / fast["mean_ns"], 3)

    return {"group": group, "entries": rows, "speedups": speedups}


def parse_threshold(argv) -> float | None:
    if "--regress-threshold" not in argv:
        return None
    i = argv.index("--regress-threshold")
    try:
        return float(argv[i + 1])
    except (IndexError, ValueError):
        print("--regress-threshold requires a numeric argument", file=sys.stderr)
        sys.exit(2)


def check_regressions(group: str, baseline: dict, fresh: dict, threshold: float):
    """Every baseline speedup key must re-measure >= threshold."""
    failures = []
    base_speedups = baseline.get("speedups") or {}
    new_speedups = fresh.get("speedups") or {}
    for name, old in sorted(base_speedups.items()):
        got = new_speedups.get(name)
        if got is None:
            print(f"warning: {group}: baseline speedup {name!r} "
                  f"missing from the new run (renamed bench?)", file=sys.stderr)
        elif got < threshold:
            failures.append(
                f"{group}: {name} speedup {got} < threshold {threshold}"
                f" (baseline had {old})")
    return failures


def main() -> int:
    threshold = parse_threshold(sys.argv[1:])
    check_only = "--check-only" in sys.argv[1:]
    wrote = 0
    failures = []
    pending = []  # (path, fresh) — written only if the gate passes
    for group in GROUPS:
        entries = load(group)
        if entries is None:
            print(f"skipping {group}: no rust/results/bench_{group}.json "
                  f"(run scripts/bench_smoke.sh first)", file=sys.stderr)
            continue
        fresh = report(group, entries)
        out = REPO / f"BENCH_{group}.json"
        if threshold is not None and out.exists():
            with out.open() as f:
                baseline = json.load(f)
            failures += check_regressions(group, baseline, fresh, threshold)
        pending.append((out, fresh))
    if failures:
        # leave the committed baselines untouched: overwriting them with
        # the regressed (or fast-mode) numbers would make the very next
        # run compare against the regression and pass — the gate would
        # mask itself
        for line in failures:
            print(f"REGRESSION: {line}", file=sys.stderr)
        print("baselines left unmodified (fix the regression, then re-run)",
              file=sys.stderr)
        return 3
    if check_only:
        print(f"gate passed; {len(pending)} baseline(s) left unmodified (--check-only)")
        return 0 if pending else 1
    for out, fresh in pending:
        with out.open("w") as f:
            json.dump(fresh, f, indent=2)
            f.write("\n")
        print(f"wrote {out}")
        wrote += 1
    return 0 if wrote else 1


if __name__ == "__main__":
    sys.exit(main())
