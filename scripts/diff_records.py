#!/usr/bin/env python3
"""Compare two titan RunRecord JSON files on their deterministic fields.

Used by the CI resume smoke: a run that was halted mid-way and resumed
from its checkpoint must produce a record byte-identical to the
uninterrupted reference run on every field that does not read the host
wall clock. Host-clock fields (total_host_ms, round host times, the
curve's host_ms, processing-delay latencies) legitimately differ between
executions and are ignored.

Usage: diff_records.py REFERENCE.json RESUMED.json
Exits 0 when the deterministic fields match exactly, 1 otherwise.
"""
import json
import sys

DETERMINISTIC_TOP = [
    "method",
    "model",
    "final_accuracy",
    "best_accuracy",
    "total_device_ms",
    "energy_j",
    "avg_power_w",
    "peak_memory_bytes",
]
DETERMINISTIC_CURVE = [
    "round",
    "device_ms",
    "train_loss",
    "test_loss",
    "test_accuracy",
]


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    with open(sys.argv[1]) as f:
        ref = json.load(f)
    with open(sys.argv[2]) as f:
        got = json.load(f)

    failures = []
    for key in DETERMINISTIC_TOP:
        if ref.get(key) != got.get(key):
            failures.append(f"{key}: {ref.get(key)!r} != {got.get(key)!r}")

    ref_curve = ref.get("curve", [])
    got_curve = got.get("curve", [])
    if len(ref_curve) != len(got_curve):
        failures.append(f"curve length: {len(ref_curve)} != {len(got_curve)}")
    else:
        for i, (a, b) in enumerate(zip(ref_curve, got_curve)):
            for key in DETERMINISTIC_CURVE:
                if a.get(key) != b.get(key):
                    failures.append(
                        f"curve[{i}].{key}: {a.get(key)!r} != {b.get(key)!r}"
                    )

    if failures:
        print("records diverge on deterministic fields:")
        for line in failures:
            print(f"  {line}")
        sys.exit(1)
    print(
        f"records match on {len(DETERMINISTIC_TOP)} scalar fields and "
        f"{len(ref_curve)} curve points"
    )


if __name__ == "__main__":
    main()
