#!/usr/bin/env python3
"""Compare two titan record JSON files on their deterministic fields.

Used by the CI resume smoke: a run that was halted mid-way and resumed
from its checkpoint must produce a record byte-identical to the
uninterrupted reference run on every field that does not read the host
wall clock. Host-clock fields (total_host_ms, round host times, the
curve's host_ms, processing-delay latencies, scheduler overhead)
legitimately differ between executions and are ignored.

With --fleet both files are FleetRecord JSON (the `titan fleet` output):
per-session status/rounds/record plus the fault telemetry are compared,
which is what the CI chaos smoke uses to pin that the same fault seed
reproduces the same fleet outcome, and that a zero-rate fault plan is
identical to no plan at all (the `fault_plan` key itself is ignored for
exactly that comparison). The checkpoint-vault `recovery` telemetry is
deterministic under a fixed fault script, so it is compared too.

With --recovered, GOT is a run that survived checkpoint corruption and
recovered from an older vault generation, while REFERENCE ran
uninterrupted: the fields a recovery legitimately changes (replayed
round counts, fault telemetry, the recovery block itself) are skipped,
GOT must actually carry recovery telemetry, and everything else —
curves, accuracy, energy, memory — must still match exactly. This is
the CI corruption-recovery leg's oracle: falling back a generation may
cost replayed rounds, never correctness.

Usage: diff_records.py [--fleet] [--recovered] REFERENCE.json GOT.json
Exits 0 when the deterministic fields match exactly, 1 otherwise.
"""
import json
import sys

DETERMINISTIC_TOP = [
    "method",
    "model",
    "final_accuracy",
    "best_accuracy",
    "total_device_ms",
    "energy_j",
    "avg_power_w",
    "peak_memory_bytes",
    # cumulative RetentionTelemetry (counts + bytes; absent for
    # unbudgeted runs, and absence must match too)
    "retention",
    # vault RecoveryTelemetry (absent for clean runs; deterministic
    # under a fixed fault script, so absence must match too)
    "recovery",
]
DETERMINISTIC_CURVE = [
    "round",
    "device_ms",
    "train_loss",
    "test_loss",
    "test_accuracy",
]
DETERMINISTIC_FLEET_TOP = [
    "policy",
    "supervision",
    "rounds_executed",
    "device_ops",
    "total_device_ms",
    "energy_j",
    "peak_memory_bytes",
    "faults",
    "retention",
    "recovery",
]
DETERMINISTIC_SESSION = [
    "name",
    "rounds",
    "status",
    "quarantine_round",
    "reason",
]

# Fields a degraded-but-correct recovery legitimately changes versus an
# uninterrupted reference run (--recovered mode).
RECOVERED_SKIP_TOP = {"recovery"}
RECOVERED_SKIP_FLEET = {"rounds_executed", "device_ops", "faults", "recovery"}
RECOVERED_SKIP_SESSION = {"rounds"}


def diff_run_record(ref, got, prefix="", skip=frozenset()):
    """Failures on a single RunRecord's deterministic fields."""
    failures = []
    for key in DETERMINISTIC_TOP:
        if key in skip:
            continue
        if ref.get(key) != got.get(key):
            failures.append(f"{prefix}{key}: {ref.get(key)!r} != {got.get(key)!r}")

    ref_curve = ref.get("curve", [])
    got_curve = got.get("curve", [])
    if len(ref_curve) != len(got_curve):
        failures.append(f"{prefix}curve length: {len(ref_curve)} != {len(got_curve)}")
    else:
        for i, (a, b) in enumerate(zip(ref_curve, got_curve)):
            for key in DETERMINISTIC_CURVE:
                if a.get(key) != b.get(key):
                    failures.append(
                        f"{prefix}curve[{i}].{key}: {a.get(key)!r} != {b.get(key)!r}"
                    )
    return failures


def diff_fleet_record(ref, got, recovered=False):
    """Failures on a FleetRecord's deterministic fields (host clocks and
    the serialized fault plan ignored)."""
    failures = []
    skip_top = RECOVERED_SKIP_FLEET if recovered else frozenset()
    skip_session = RECOVERED_SKIP_SESSION if recovered else frozenset()
    skip_record = RECOVERED_SKIP_TOP if recovered else frozenset()
    for key in DETERMINISTIC_FLEET_TOP:
        if key in skip_top:
            continue
        if ref.get(key) != got.get(key):
            failures.append(f"{key}: {ref.get(key)!r} != {got.get(key)!r}")
    if recovered and "recovery" not in got:
        failures.append("recovery: recovered fleet carries no recovery telemetry")

    ref_sessions = ref.get("sessions", [])
    got_sessions = got.get("sessions", [])
    if len(ref_sessions) != len(got_sessions):
        failures.append(
            f"sessions length: {len(ref_sessions)} != {len(got_sessions)}"
        )
        return failures
    for i, (a, b) in enumerate(zip(ref_sessions, got_sessions)):
        for key in DETERMINISTIC_SESSION:
            if key in skip_session:
                continue
            if a.get(key) != b.get(key):
                failures.append(
                    f"sessions[{i}].{key}: {a.get(key)!r} != {b.get(key)!r}"
                )
        ra, rb = a.get("record"), b.get("record")
        if (ra is None) != (rb is None):
            failures.append(
                f"sessions[{i}].record: one present, the other null"
            )
        elif ra is not None:
            failures.extend(
                diff_run_record(ra, rb, f"sessions[{i}].record.", skip_record)
            )
    return failures


def main():
    argv = sys.argv[1:]
    fleet = "--fleet" in argv
    recovered = "--recovered" in argv
    argv = [a for a in argv if a not in ("--fleet", "--recovered")]
    if len(argv) != 2:
        sys.exit(__doc__)
    with open(argv[0]) as f:
        ref = json.load(f)
    with open(argv[1]) as f:
        got = json.load(f)

    if fleet:
        failures = diff_fleet_record(ref, got, recovered)
        summary = (
            f"fleet records match on {len(DETERMINISTIC_FLEET_TOP)} scalar "
            f"fields and {len(ref.get('sessions', []))} sessions"
        )
        if recovered:
            summary += " (recovered-run fields skipped)"
    else:
        failures = diff_run_record(
            ref, got, skip=RECOVERED_SKIP_TOP if recovered else frozenset()
        )
        if recovered and "recovery" not in got:
            failures.append("recovery: recovered run carries no recovery telemetry")
        summary = (
            f"records match on {len(DETERMINISTIC_TOP)} scalar fields and "
            f"{len(ref.get('curve', []))} curve points"
        )
        if recovered:
            summary += " (recovered-run fields skipped)"

    if failures:
        print("records diverge on deterministic fields:")
        for line in failures:
            print(f"  {line}")
        sys.exit(1)
    print(summary)


if __name__ == "__main__":
    main()
