#!/usr/bin/env python3
"""Compare two titan record JSON files on their deterministic fields.

Used by the CI resume smoke: a run that was halted mid-way and resumed
from its checkpoint must produce a record byte-identical to the
uninterrupted reference run on every field that does not read the host
wall clock. Host-clock fields (total_host_ms, round host times, the
curve's host_ms, processing-delay latencies, scheduler overhead)
legitimately differ between executions and are ignored.

With --fleet both files are FleetRecord JSON (the `titan fleet` output):
per-session status/rounds/record plus the fault telemetry are compared,
which is what the CI chaos smoke uses to pin that the same fault seed
reproduces the same fleet outcome, and that a zero-rate fault plan is
identical to no plan at all (the `fault_plan` key itself is ignored for
exactly that comparison).

Usage: diff_records.py [--fleet] REFERENCE.json GOT.json
Exits 0 when the deterministic fields match exactly, 1 otherwise.
"""
import json
import sys

DETERMINISTIC_TOP = [
    "method",
    "model",
    "final_accuracy",
    "best_accuracy",
    "total_device_ms",
    "energy_j",
    "avg_power_w",
    "peak_memory_bytes",
    # cumulative RetentionTelemetry (counts + bytes; absent for
    # unbudgeted runs, and absence must match too)
    "retention",
]
DETERMINISTIC_CURVE = [
    "round",
    "device_ms",
    "train_loss",
    "test_loss",
    "test_accuracy",
]
DETERMINISTIC_FLEET_TOP = [
    "policy",
    "supervision",
    "rounds_executed",
    "device_ops",
    "total_device_ms",
    "energy_j",
    "peak_memory_bytes",
    "faults",
    "retention",
]
DETERMINISTIC_SESSION = [
    "name",
    "rounds",
    "status",
    "quarantine_round",
    "reason",
]


def diff_run_record(ref, got, prefix=""):
    """Failures on a single RunRecord's deterministic fields."""
    failures = []
    for key in DETERMINISTIC_TOP:
        if ref.get(key) != got.get(key):
            failures.append(f"{prefix}{key}: {ref.get(key)!r} != {got.get(key)!r}")

    ref_curve = ref.get("curve", [])
    got_curve = got.get("curve", [])
    if len(ref_curve) != len(got_curve):
        failures.append(f"{prefix}curve length: {len(ref_curve)} != {len(got_curve)}")
    else:
        for i, (a, b) in enumerate(zip(ref_curve, got_curve)):
            for key in DETERMINISTIC_CURVE:
                if a.get(key) != b.get(key):
                    failures.append(
                        f"{prefix}curve[{i}].{key}: {a.get(key)!r} != {b.get(key)!r}"
                    )
    return failures


def diff_fleet_record(ref, got):
    """Failures on a FleetRecord's deterministic fields (host clocks and
    the serialized fault plan ignored)."""
    failures = []
    for key in DETERMINISTIC_FLEET_TOP:
        if ref.get(key) != got.get(key):
            failures.append(f"{key}: {ref.get(key)!r} != {got.get(key)!r}")

    ref_sessions = ref.get("sessions", [])
    got_sessions = got.get("sessions", [])
    if len(ref_sessions) != len(got_sessions):
        failures.append(
            f"sessions length: {len(ref_sessions)} != {len(got_sessions)}"
        )
        return failures
    for i, (a, b) in enumerate(zip(ref_sessions, got_sessions)):
        for key in DETERMINISTIC_SESSION:
            if a.get(key) != b.get(key):
                failures.append(
                    f"sessions[{i}].{key}: {a.get(key)!r} != {b.get(key)!r}"
                )
        ra, rb = a.get("record"), b.get("record")
        if (ra is None) != (rb is None):
            failures.append(
                f"sessions[{i}].record: one present, the other null"
            )
        elif ra is not None:
            failures.extend(diff_run_record(ra, rb, f"sessions[{i}].record."))
    return failures


def main():
    argv = sys.argv[1:]
    fleet = "--fleet" in argv
    argv = [a for a in argv if a != "--fleet"]
    if len(argv) != 2:
        sys.exit(__doc__)
    with open(argv[0]) as f:
        ref = json.load(f)
    with open(argv[1]) as f:
        got = json.load(f)

    if fleet:
        failures = diff_fleet_record(ref, got)
        summary = (
            f"fleet records match on {len(DETERMINISTIC_FLEET_TOP)} scalar "
            f"fields and {len(ref.get('sessions', []))} sessions"
        )
    else:
        failures = diff_run_record(ref, got)
        summary = (
            f"records match on {len(DETERMINISTIC_TOP)} scalar fields and "
            f"{len(ref.get('curve', []))} curve points"
        )

    if failures:
        print("records diverge on deterministic fields:")
        for line in failures:
            print(f"  {line}")
        sys.exit(1)
    print(summary)


if __name__ == "__main__":
    main()
