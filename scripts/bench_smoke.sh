#!/usr/bin/env bash
# Bench smoke: run every bench target in fast mode so CI catches bench
# bit-rot (compile errors, panics, missing artifacts handled gracefully)
# without paying the full measurement windows.
#
# The bench targets use `harness = false` with the in-repo harness
# (`titan::util::bench`), so "test mode" is its TITAN_BENCH_FAST env knob:
# ~50ms warmup + ~200ms measure per bench instead of 300ms + 2s. Each run
# still writes rust/results/bench_<group>.json; those are then piped
# through scripts/bench_report.py to refresh the BENCH_*.json trajectory
# files at the repo root.
#
# Usage: scripts/bench_smoke.sh [bench ...]   (default: all four)
set -euo pipefail
script_dir="$(cd "$(dirname "$0")" && pwd)"
repo_root="$(dirname "$script_dir")"
cd "$repo_root/rust"

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
  benches=(bench_filter bench_selection bench_pipeline bench_runtime)
fi

export TITAN_BENCH_FAST=1
for bench in "${benches[@]}"; do
  echo "== smoke: $bench =="
  cargo bench --bench "$bench"
done

echo "== emitting BENCH_*.json =="
python3 "$script_dir/bench_report.py" || true
