#!/usr/bin/env bash
# Bench smoke: run every bench target in fast mode so CI catches bench
# bit-rot (compile errors, panics, missing artifacts handled gracefully)
# without paying the full measurement windows.
#
# The bench targets use `harness = false` with the in-repo harness
# (`titan::util::bench`), so "test mode" is its TITAN_BENCH_FAST env knob:
# ~50ms warmup + ~200ms measure per bench instead of 300ms + 2s. Each run
# still writes rust/results/bench_<group>.json; those are then piped
# through scripts/bench_report.py to refresh the BENCH_*.json trajectory
# files at the repo root.
#
# Usage: scripts/bench_smoke.sh [bench ...]   (default: all six)
#
# Set TITAN_BENCH_REGRESS=<threshold> (ci.sh does) to turn the report step
# into a regression gate: freshly measured speedups are compared against
# the committed BENCH_*.json baselines and the smoke fails if any tracked
# entry drops below the threshold.
set -euo pipefail
script_dir="$(cd "$(dirname "$0")" && pwd)"
repo_root="$(dirname "$script_dir")"
cd "$repo_root/rust"

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
  benches=(bench_filter bench_selection bench_pipeline bench_runtime bench_retention bench_fleet)
fi

export TITAN_BENCH_FAST=1
for bench in "${benches[@]}"; do
  echo "== smoke: $bench =="
  cargo bench --bench "$bench"
done

echo "== emitting BENCH_*.json =="
if [ -n "${TITAN_BENCH_REGRESS:-}" ]; then
  # gate mode: a tracked speedup falling below the threshold fails the
  # smoke; --check-only keeps fast-mode numbers from overwriting the
  # committed full-bench trajectory (refreshing baselines is a deliberate
  # full-bench + plain bench_report.py step)
  python3 "$script_dir/bench_report.py" \
    --regress-threshold "$TITAN_BENCH_REGRESS" --check-only
else
  python3 "$script_dir/bench_report.py" || true
fi
