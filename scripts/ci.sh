#!/usr/bin/env bash
# CI gate: the full local verification ladder, cheapest first.
#
#   0. detlint                    determinism & robustness static analysis
#      (scripts/detlint.py against the committed detlint_baseline.json
#      ratchet; its own Python test suite runs first — no toolchain
#      needed, so this gate runs even where cargo is unavailable)
#   1. cargo fmt --check          formatting drift
#   2. cargo clippy -D warnings   lints (all targets: lib, bins, tests, benches)
#   3. cargo doc -D warnings      rustdoc (intra-doc links, examples)
#   4. tier-1 verify              cargo build --release && cargo test -q
#   5. fleet smoke                tiny multi-session scheduler run
#      (artifact-gated; skipped on a fresh checkout like the benches)
#   6. resume smoke               halt a checkpointed run mid-way, resume
#      it, and diff the final record JSON against an uninterrupted
#      reference on every deterministic field (artifact-gated)
#   7. retention smoke            a byte-budgeted (--store-bytes) run is
#      halted and resumed; the record — cumulative RetentionTelemetry
#      included — must diff clean against the uninterrupted reference
#      (artifact-gated)
#   8. chaos smoke                fault-injected fleet runs: a zero-rate
#      plan diffs clean against no plan, and two runs with the same
#      fault seed under restart supervision diff clean on every
#      deterministic FleetRecord field, telemetry included
#      (artifact-gated)
#   9. recovery smoke             a scripted torn write shreds the newest
#      checkpoint generation and a crash forces a restart: the vault
#      must fall back to the previous generation, replay the lost
#      rounds, and converge to a record that diffs clean (--recovered)
#      against the uninterrupted reference — recovery telemetry present,
#      correctness untouched (artifact-gated)
#  10. fleet-scale smoke          the same fleet on --host-threads 1 and
#      --host-threads 4: the sharded work-stealing host must produce a
#      record that diffs clean against the single-thread host on every
#      deterministic FleetRecord field (artifact-gated)
#  11. bench smoke                every bench target in fast mode
#      (TITAN_BENCH_FAST=1 via scripts/bench_smoke.sh; catches bench
#      bit-rot without paying full measurement windows), then the
#      speedup regression gate: bench_report.py --check-only fails if
#      any tracked speedup drops below 1.0 against the committed
#      BENCH_*.json baseline, without letting fast-mode numbers
#      overwrite it. Perf PRs refresh the committed files from a full
#      cargo bench run (see PERF.md).
#
# Usage: scripts/ci.sh [--no-bench]
set -euo pipefail
script_dir="$(cd "$(dirname "$0")" && pwd)"
repo_root="$(dirname "$script_dir")"

echo "== detlint =="
python3 -m unittest discover -s "$script_dir" -p "test_detlint.py" -q
python3 "$script_dir/detlint.py" --root "$repo_root" \
  --baseline "$repo_root/detlint_baseline.json"

cd "$repo_root/rust"

run_bench=1
if [ "${1:-}" = "--no-bench" ]; then
  run_bench=0
fi

echo "== fmt =="
cargo fmt --check

echo "== clippy =="
cargo clippy --all-targets -- -D warnings

echo "== docs =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "== fleet smoke =="
if [ -f artifacts/mlp/meta.json ]; then
  cargo run --release --quiet -- fleet --sessions 3 --rounds 4 \
    --eval-every 2 --test-size 200 --policy fewest
else
  echo "skipping fleet smoke: no artifacts (run \`make artifacts\`)"
fi

echo "== resume smoke =="
if [ -f artifacts/mlp/meta.json ]; then
  smoke_dir="results/resume_smoke"
  rm -rf "$smoke_dir"
  mkdir -p "$smoke_dir"
  run_flags=(run --model mlp --method titan --sequential --rounds 6 \
    --eval-every 2 --test-size 200)
  # uninterrupted reference record
  cargo run --release --quiet -- "${run_flags[@]}"
  mv results/run_mlp_titan.json "$smoke_dir/reference.json"
  # same run, checkpointed every 2 rounds and "killed" after round 3
  cargo run --release --quiet -- "${run_flags[@]}" \
    --checkpoint "$smoke_dir/ck.json" --checkpoint-every 2 --halt-after 3
  # resumed from the snapshot (round 2; rounds 3-6 re-run)
  cargo run --release --quiet -- run --resume "$smoke_dir/ck.json"
  mv results/run_mlp_titan.json "$smoke_dir/resumed.json"
  python3 "$script_dir/diff_records.py" \
    "$smoke_dir/reference.json" "$smoke_dir/resumed.json"
else
  echo "skipping resume smoke: no artifacts (run \`make artifacts\`)"
fi

echo "== retention smoke =="
if [ -f artifacts/mlp/meta.json ]; then
  ret_dir="results/retention_smoke"
  rm -rf "$ret_dir"
  mkdir -p "$ret_dir"
  ret_flags=(run --model mlp --method titan --sequential --rounds 6 \
    --eval-every 2 --test-size 200 \
    --store-bytes 65536 --retention balanced --replay-mix 0.25)
  # uninterrupted reference of a retaining run
  cargo run --release --quiet -- "${ret_flags[@]}"
  mv results/run_mlp_titan.json "$ret_dir/reference.json"
  # same run killed after round 3 and resumed: the store contents,
  # policy RNG, and telemetry ride the snapshot, so the resumed record
  # must diff clean on every deterministic field, retention included
  cargo run --release --quiet -- "${ret_flags[@]}" \
    --checkpoint "$ret_dir/ck.json" --checkpoint-every 2 --halt-after 3
  cargo run --release --quiet -- run --resume "$ret_dir/ck.json"
  mv results/run_mlp_titan.json "$ret_dir/resumed.json"
  python3 "$script_dir/diff_records.py" \
    "$ret_dir/reference.json" "$ret_dir/resumed.json"
else
  echo "skipping retention smoke: no artifacts (run \`make artifacts\`)"
fi

echo "== chaos smoke =="
if [ -f artifacts/mlp/meta.json ]; then
  chaos_dir="results/chaos_smoke"
  rm -rf "$chaos_dir"
  mkdir -p "$chaos_dir"
  fleet_flags=(fleet --sessions 3 --rounds 4 --eval-every 2 --test-size 200 \
    --policy fewest)
  # pin 1: a zero-rate fault plan (any --fault-seed, all rates 0) is
  # deterministically identical to running with no plan at all
  cargo run --release --quiet -- "${fleet_flags[@]}"
  mv results/fleet.json "$chaos_dir/plain.json"
  cargo run --release --quiet -- "${fleet_flags[@]}" --fault-seed 7
  mv results/fleet.json "$chaos_dir/zero_rate.json"
  python3 "$script_dir/diff_records.py" --fleet \
    "$chaos_dir/plain.json" "$chaos_dir/zero_rate.json"
  # pin 2: the same fault seed under restart supervision reproduces the
  # same fleet outcome byte-for-byte on the deterministic fields —
  # statuses, per-session records, and the fault telemetry included
  chaos_flags=("${fleet_flags[@]}" --checkpoint-every 2 \
    --supervise restart:2:1 --fault-seed 7 \
    --crash-rate 0.15 --transient-rate 0.1 --straggler-rate 0.1)
  cargo run --release --quiet -- "${chaos_flags[@]}" \
    --checkpoint-dir "$chaos_dir/ck_a"
  mv results/fleet.json "$chaos_dir/chaos_a.json"
  cargo run --release --quiet -- "${chaos_flags[@]}" \
    --checkpoint-dir "$chaos_dir/ck_b"
  mv results/fleet.json "$chaos_dir/chaos_b.json"
  python3 "$script_dir/diff_records.py" --fleet \
    "$chaos_dir/chaos_a.json" "$chaos_dir/chaos_b.json"
else
  echo "skipping chaos smoke: no artifacts (run \`make artifacts\`)"
fi

echo "== recovery smoke =="
if [ -f artifacts/mlp/meta.json ]; then
  rec_dir="results/recovery_smoke"
  rm -rf "$rec_dir"
  mkdir -p "$rec_dir"
  rec_flags=(fleet --sessions 3 --rounds 6 --eval-every 2 --test-size 200 \
    --policy fewest --checkpoint-every 2 --keep-checkpoints 2 \
    --supervise restart:2:1:8)
  # uninterrupted reference: same members, same vault config, no faults
  cargo run --release --quiet -- "${rec_flags[@]}" \
    --checkpoint-dir "$rec_dir/ck_ref"
  mv results/fleet.json "$rec_dir/reference.json"
  # member 0: a torn write shreds its newest generation (g2, round 4)
  # after round 4, and a crash one round later forces a restart — the
  # vault must reject the torn frame, resume from the round-2
  # generation, replay the lost rounds, and converge to the same record
  cargo run --release --quiet -- "${rec_flags[@]}" \
    --checkpoint-dir "$rec_dir/ck_chaos" \
    --fault-seed 11 --fault-script "0:4:torn_write;0:5:crash"
  mv results/fleet.json "$rec_dir/recovered.json"
  python3 "$script_dir/diff_records.py" --fleet --recovered \
    "$rec_dir/reference.json" "$rec_dir/recovered.json"
else
  echo "skipping recovery smoke: no artifacts (run \`make artifacts\`)"
fi

echo "== fleet-scale smoke =="
if [ -f artifacts/mlp/meta.json ]; then
  scale_dir="results/fleet_scale_smoke"
  rm -rf "$scale_dir"
  mkdir -p "$scale_dir"
  scale_flags=(fleet --sessions 8 --rounds 3 --eval-every 2 --test-size 200 \
    --policy rr)
  # host_threads = 1 is the determinism oracle: the sharded host at any
  # thread count must reproduce its record on the deterministic fields
  # (diff_records.py ignores the host-clock shard stats and steal counts)
  cargo run --release --quiet -- "${scale_flags[@]}" --host-threads 1
  mv results/fleet.json "$scale_dir/t1.json"
  cargo run --release --quiet -- "${scale_flags[@]}" --host-threads 4
  mv results/fleet.json "$scale_dir/t4.json"
  python3 "$script_dir/diff_records.py" --fleet \
    "$scale_dir/t1.json" "$scale_dir/t4.json"
else
  echo "skipping fleet-scale smoke: no artifacts (run \`make artifacts\`)"
fi

if [ "$run_bench" = 1 ]; then
  echo "== bench smoke (fast mode, regression-gated) =="
  TITAN_BENCH_REGRESS="${TITAN_BENCH_REGRESS:-1.0}" "$script_dir/bench_smoke.sh"
  echo "gate only: refresh committed BENCH_*.json via a full cargo bench +"
  echo "scripts/bench_report.py when a perf PR changes a hot path (PERF.md)"
fi

echo "== ci green =="
