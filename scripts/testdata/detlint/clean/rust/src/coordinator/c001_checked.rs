//! C001 conforming fixture: checked conversions, or a pragma that
//! documents the narrowing invariant.

pub fn checked(ms: u64) -> Result<u32, String> {
    u32::try_from(ms).map_err(|_| "ms overflows u32".to_string())
}

pub fn documented(x: f64) -> f32 {
    // detlint: allow(C001) params are f32 by model contract; the f64 came from a lossless widen
    x as f32
}
