//! D002 conforming fixture: deterministic iteration in a record-feeding
//! module — BTreeMap for anything walked, HashMap only for point lookups.

use std::collections::{BTreeMap, HashMap};

pub struct Telemetry {
    ordered: BTreeMap<u64, u64>,
    index: HashMap<u64, usize>,
}

impl Telemetry {
    pub fn emit(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for (k, v) in &self.ordered {
            out.push((*k, *v));
        }
        out
    }

    pub fn slot_of(&mut self, id: u64, slot: usize) -> Option<usize> {
        self.index.insert(id, slot);
        self.index.get(&id).copied()
    }
}
