//! D005 conforming fixture: the coordinator host seam may create
//! threads (this path is on the allowed list), and scoped spawns are
//! fine anywhere.

pub fn hosted() {
    std::thread::spawn(move || {});
    let builder = std::thread::Builder::new();
    drop(builder);
}
