//! D004 conforming fixture: float folds are the blessed kernels' job,
//! and this file's path (util/stats.rs) is on the blessed list.

pub fn sum(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}

pub fn running(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for x in xs {
        acc += x;
    }
    acc
}
