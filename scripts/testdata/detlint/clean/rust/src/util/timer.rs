//! D001 conforming fixture: wall-clock reads are fine in the blessed
//! clock seam (this file's path, util/timer.rs, is on the blessed list).

use std::time::Instant;

pub fn stopwatch() -> Instant {
    Instant::now()
}

pub fn unix_like() {
    let _t = std::time::SystemTime::now();
}
