//! Blessed durability seam: the one module where raw file writes are
//! allowed (R003 scope) — everything else routes through its helpers.

pub fn write_plain(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, bytes)
}

pub fn create_file(path: &std::path::Path) -> std::io::Result<std::fs::File> {
    std::fs::File::create(path)
}
