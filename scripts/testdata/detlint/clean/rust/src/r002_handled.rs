//! R002 conforming fixture: the Result is inspected, not discarded.

pub fn cleanup(path: &str) -> bool {
    std::fs::remove_file(path).is_ok()
}

pub fn send_or_stop(ok: Result<(), String>, stop: &mut bool) {
    if ok.is_err() {
        *stop = true;
    }
}
