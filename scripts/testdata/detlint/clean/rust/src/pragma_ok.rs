//! Pragma fixture: correctly formed pragmas (rule + mandatory reason)
//! suppress their findings — inline, standalone, and multi-rule forms.

pub fn pinned_fold(xs: &[f64; 4]) -> f64 {
    // detlint: allow(D004) fixed-order four-element fold, pinned by a regression test
    xs.iter().sum::<f64>()
}

pub fn known_some() -> u32 {
    Some(1).unwrap() // detlint: allow(R001) literal is Some by construction
}

pub fn best_effort(path: &str) {
    // detlint: allow(R002,R001) best-effort temp cleanup; failure only leaves a stray file
    let _ = std::fs::remove_file(path);
}
