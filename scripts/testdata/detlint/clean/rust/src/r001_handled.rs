//! R001 conforming fixture: errors handled, not aborted on — and the
//! two deliberate non-matches: a parser's *own* `expect(byte)` method
//! (not Option/Result::expect) and unwraps confined to test code.

pub struct Parser {
    pos: usize,
}

impl Parser {
    fn expect(&mut self, b: u8) -> Result<(), String> {
        self.pos += usize::from(b & 1);
        Ok(())
    }

    pub fn parse(&mut self) -> Result<(), String> {
        self.expect(b'{')?;
        self.expect(b'}')?;
        Ok(())
    }
}

pub fn first(xs: &[u32]) -> Result<u32, String> {
    xs.first().copied().ok_or_else(|| "empty".to_string())
}

pub fn second(xs: &[u32]) -> u32 {
    xs.get(1).copied().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwraps_are_fine_in_tests() {
        assert_eq!(first(&[7]).unwrap(), 7);
        let v: Option<u32> = Some(1);
        if v.expect("set above") != 1 {
            panic!("impossible");
        }
    }
}
