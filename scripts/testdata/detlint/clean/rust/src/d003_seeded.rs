//! D003 conforming fixture: all entropy flows from an explicit seed.

pub struct Rng(u64);

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Rng {
        Rng(seed)
    }
}

pub fn derived(seed: u64) -> Rng {
    Rng::seed_from_u64(seed ^ 0xB1E4_D411)
}
