//! C001 fixture: narrowing casts on a record/telemetry path.

pub fn narrow(ms: f64) -> f32 {
    ms as f32
}

pub fn truncate(ms: f64) -> usize {
    (ms * 1e3) as f64 as usize
}
