//! D002 fixture: HashMap iteration in a record-feeding module.

use std::collections::HashMap;

pub struct Telemetry {
    counts: HashMap<u64, u64>,
}

impl Telemetry {
    pub fn emit(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for (k, v) in &self.counts {
            out.push((*k, *v));
        }
        out
    }
}
