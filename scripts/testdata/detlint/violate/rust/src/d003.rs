//! D003 fixture: ambient randomness instead of an explicit seed.

pub fn noise() -> u64 {
    let mut rng = rand::thread_rng();
    rng.next_u64()
}
