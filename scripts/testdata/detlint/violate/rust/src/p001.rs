//! P001 fixture: pragmas that are malformed (reason missing / unknown rule).

// detlint: allow(R001)
pub fn reasonless() -> u32 {
    7
}

pub fn unknown() -> u32 {
    8 // detlint: allow(Q999) there is no rule Q999
}
