//! D001 fixture: a wall-clock read outside the blessed clock seam.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
