//! R002 fixture: a Result silently discarded.

pub fn cleanup(path: &str) {
    let _ = std::fs::remove_file(path);
}
