//! D005 fixture: an unscoped thread outside the coordinator seam.

pub fn detach() {
    std::thread::spawn(move || {
        do_work();
    });
}

fn do_work() {}
