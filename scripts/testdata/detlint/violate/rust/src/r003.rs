//! R003 fixture: raw, non-atomic file writes outside util/durable_io.

pub fn save(path: &str, payload: &str) -> std::io::Result<()> {
    std::fs::write(path, payload)
}

pub fn open_fresh(path: &str) -> std::io::Result<std::fs::File> {
    std::fs::File::create(path)
}
