//! D004 fixture: a float fold outside the blessed kernels.

pub fn total(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}

pub fn total_manual(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for x in xs {
        acc += x;
    }
    acc
}
