//! R001 fixture: unwrap/expect/panic in non-test library code.

pub fn first(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn second(xs: &[u32]) -> u32 {
    *xs.get(1).expect("needs two elements")
}

pub fn third(xs: &[u32]) -> u32 {
    match xs.get(2) {
        Some(v) => *v,
        None => panic!("needs three elements"),
    }
}
