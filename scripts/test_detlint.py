#!/usr/bin/env python3
"""Unit tests for scripts/detlint.py — tokenizer, regions, pragmas,
rules (via the fixture corpus under scripts/testdata/detlint/), the
baseline ratchet, and the --json report. Pure stdlib; run live with:

    python3 scripts/test_detlint.py
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import detlint  # noqa: E402

TESTDATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "testdata", "detlint")


def scan(rel, text):
    findings, suppressed = detlint.scan_file(rel, text)
    return findings, suppressed


def rules_of(findings):
    return sorted({f.rule for f in findings})


class TokenizerTests(unittest.TestCase):
    def test_line_comment_is_not_code(self):
        code, comment = detlint.tokenize("let x = 1; // .unwrap() here\n")
        self.assertNotIn("unwrap", code[0])
        self.assertIn("let x = 1;", code[0])
        self.assertIn(".unwrap() here", comment[0])

    def test_double_slash_inside_string_is_not_a_comment(self):
        code, comment = detlint.tokenize('let url = "http://x"; let y = 2;\n')
        self.assertIn("let y = 2;", code[0])
        self.assertEqual(comment[0], "")
        self.assertNotIn("http", code[0])  # string content blanked

    def test_nested_block_comments(self):
        src = "a /* outer /* inner .unwrap() */ still comment */ b\n"
        code, comment = detlint.tokenize(src)
        self.assertNotIn("unwrap", code[0])
        self.assertIn("still comment", comment[0])
        self.assertRegex(code[0], r"^a\s+b$")

    def test_multiline_block_comment_preserves_line_count(self):
        src = "a\n/* one\ntwo .expect(\nthree */\nb\n"
        code, _ = detlint.tokenize(src)
        self.assertEqual(len(code), 5)
        self.assertEqual(code[0], "a")
        self.assertEqual(code[4], "b")
        self.assertNotIn("expect", "".join(code))

    def test_raw_strings_hide_their_content(self):
        src = 'let re = r#"quote " and // and .unwrap()"#; f();\n'
        code, comment = detlint.tokenize(src)
        self.assertNotIn("unwrap", code[0])
        self.assertEqual(comment[0], "")
        self.assertIn("f();", code[0])

    def test_byte_raw_string(self):
        src = 'let b = br##"x "# y"##; g();\n'
        code, _ = detlint.tokenize(src)
        self.assertIn("g();", code[0])
        self.assertNotIn("x ", code[0].split("g();")[0].replace('"', "").strip())

    def test_identifier_ending_in_r_is_not_a_raw_string(self):
        src = 'let var = other"x";\n'  # not valid Rust, but must not panic/derail
        code, _ = detlint.tokenize(src)
        self.assertIn("let var = other", code[0])

    def test_char_literals_vs_lifetimes(self):
        src = "fn f<'a>(x: &'a str) { let q = '\"'; let n = '\\n'; let u = '\\u{1F600}'; }\n"
        code, _ = detlint.tokenize(src)
        # the '"' char literal must not open a string that swallows the rest
        self.assertIn("let n =", code[0])
        self.assertIn("let u =", code[0])
        self.assertIn("'a str", code[0])  # lifetime left as code

    def test_escaped_quote_in_string(self):
        src = 'let s = "a\\"b.unwrap()"; h();\n'
        code, _ = detlint.tokenize(src)
        self.assertNotIn("unwrap", code[0])
        self.assertIn("h();", code[0])

    def test_string_spanning_lines_via_escape(self):
        src = 'let s = "one \\\ntwo"; k();\n'
        code, _ = detlint.tokenize(src)
        self.assertEqual(len(code), 2)
        self.assertIn("k();", code[1])


class RegionTests(unittest.TestCase):
    def test_cfg_test_module_is_excluded(self):
        src = (
            "pub fn lib() -> u32 { 1 }\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    #[test]\n"
            "    fn t() { Some(1).unwrap(); panic!(\"x\"); }\n"
            "}\n"
        )
        findings, _ = scan("x.rs", src)
        self.assertEqual(findings, [])

    def test_test_attribute_fn_is_excluded(self):
        src = "#[test]\nfn t() { Some(1).unwrap(); }\npub fn lib() { Some(2).unwrap(); }\n"
        findings, _ = scan("x.rs", src)
        self.assertEqual([(f.rule, f.line) for f in findings], [("R001", 3)])

    def test_code_after_test_module_is_scanned(self):
        src = (
            "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {}\n}\n"
            "pub fn lib() { Some(1).unwrap(); }\n"
        )
        findings, _ = scan("x.rs", src)
        self.assertEqual([(f.rule, f.line) for f in findings], [("R001", 6)])


class PragmaTests(unittest.TestCase):
    def test_inline_pragma_suppresses(self):
        src = "pub fn f() { Some(1).unwrap(); } // detlint: allow(R001) constant Some\n"
        findings, suppressed = scan("x.rs", src)
        self.assertEqual(findings, [])
        self.assertEqual(suppressed, 1)

    def test_standalone_pragma_applies_to_next_code_line(self):
        src = (
            "pub fn f() {\n"
            "    // detlint: allow(R001) constant Some\n"
            "    Some(1).unwrap();\n"
            "}\n"
        )
        findings, suppressed = scan("x.rs", src)
        self.assertEqual(findings, [])
        self.assertEqual(suppressed, 1)

    def test_pragma_does_not_leak_to_later_lines(self):
        src = (
            "pub fn f() {\n"
            "    Some(1).unwrap(); // detlint: allow(R001) constant Some\n"
            "    Some(2).unwrap();\n"
            "}\n"
        )
        findings, _ = scan("x.rs", src)
        self.assertEqual([(f.rule, f.line) for f in findings], [("R001", 3)])

    def test_missing_reason_is_p001(self):
        src = "pub fn f() { Some(1).unwrap(); } // detlint: allow(R001)\n"
        findings, _ = scan("x.rs", src)
        self.assertEqual(rules_of(findings), ["P001", "R001"])  # and does NOT suppress

    def test_unknown_rule_is_p001(self):
        src = "pub fn f() {} // detlint: allow(Q999) no such rule\n"
        findings, _ = scan("x.rs", src)
        self.assertEqual(rules_of(findings), ["P001"])

    def test_multi_rule_pragma(self):
        src = "let _ = Some(1).unwrap(); // detlint: allow(R001,R002) both on purpose here\n"
        findings, suppressed = scan("x.rs", src)
        self.assertEqual(findings, [])
        self.assertEqual(suppressed, 2)


class FixtureCorpusTests(unittest.TestCase):
    """Each violating fixture triggers exactly its own rule; every
    conforming fixture is clean."""

    EXPECT = {
        "d001.rs": "D001",
        "coordinator/d002.rs": "D002",
        "d003.rs": "D003",
        "d004.rs": "D004",
        "d005.rs": "D005",
        "r001.rs": "R001",
        "r002.rs": "R002",
        "r003.rs": "R003",
        "coordinator/c001.rs": "C001",
        "p001.rs": "P001",
    }

    def test_violating_fixtures_trigger_exactly_their_rule(self):
        findings, _ = detlint.scan_tree(os.path.join(TESTDATA, "violate"))
        by_file = {}
        for f in findings:
            by_file.setdefault(f.path, set()).add(f.rule)
        self.assertEqual(set(by_file), set(self.EXPECT), "every fixture must fire")
        for path, rules in by_file.items():
            self.assertEqual(rules, {self.EXPECT[path]}, f"{path} must trigger only its own rule")

    def test_clean_fixtures_pass(self):
        findings, suppressed = detlint.scan_tree(os.path.join(TESTDATA, "clean"))
        self.assertEqual(findings, [], [f.render() for f in findings])
        self.assertGreater(suppressed, 0, "clean tree exercises pragma suppression")


class BaselineTests(unittest.TestCase):
    def setUp(self):
        self.findings, _ = detlint.scan_tree(os.path.join(TESTDATA, "violate"))
        self.tmp = tempfile.TemporaryDirectory()
        self.path = os.path.join(self.tmp.name, "baseline.json")

    def tearDown(self):
        self.tmp.cleanup()

    def test_roundtrip_ratchets_clean(self):
        detlint.write_baseline(self.path, self.findings)
        new, covered, stale = detlint.compare(self.findings, detlint.load_baseline(self.path))
        # P001 findings are never baselineable and always resurface
        self.assertEqual(rules_of(new), ["P001"])
        self.assertEqual(stale, [])
        self.assertEqual(len(covered), len([f for f in self.findings if f.rule != "P001"]))

    def test_new_finding_fails(self):
        detlint.write_baseline(self.path, self.findings)
        extra = detlint.Finding("d001.rs", 99, "D001", "another clock read", "Instant::now()")
        new, _, _ = detlint.compare(self.findings + [extra], detlint.load_baseline(self.path))
        # the over-budget (file, rule) reports all of its findings
        self.assertIn(("d001.rs", "D001"), {(f.path, f.rule) for f in new})

    def test_improvement_is_stale_until_locked(self):
        detlint.write_baseline(self.path, self.findings)
        fewer = [f for f in self.findings if f.path != "d001.rs"]
        new, _, stale = detlint.compare(fewer, detlint.load_baseline(self.path))
        self.assertEqual([r for r in rules_of(new) if r != "P001"], [])
        self.assertEqual([(p, r) for p, r, _, _ in stale], [("d001.rs", "D001")])

    def test_tampered_total_is_rejected(self):
        detlint.write_baseline(self.path, self.findings)
        with open(self.path) as fh:
            data = json.load(fh)
        data["total"] += 5
        with open(self.path, "w") as fh:
            json.dump(data, fh)
        with self.assertRaises(SystemExit):
            detlint.compare(self.findings, detlint.load_baseline(self.path))

    def test_notes_survive_rewrite(self):
        detlint.write_baseline(self.path, self.findings)
        with open(self.path) as fh:
            data = json.load(fh)
        data["notes"] = {"D001": "grandfathered until the clock seam lands"}
        with open(self.path, "w") as fh:
            json.dump(data, fh)
        detlint.write_baseline(self.path, self.findings,
                               detlint.load_baseline(self.path).get("notes"))
        with open(self.path) as fh:
            self.assertIn("notes", json.load(fh))


class CliTests(unittest.TestCase):
    def run_main(self, *argv):
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            code = detlint.main(list(argv))
        return code, out.getvalue()

    def test_violate_tree_exits_nonzero(self):
        code, out = self.run_main("--root", os.path.join(TESTDATA, "violate"))
        self.assertEqual(code, 1)
        self.assertIn("D001", out)

    def test_clean_tree_exits_zero(self):
        code, out = self.run_main("--root", os.path.join(TESTDATA, "clean"))
        self.assertEqual(code, 0)
        self.assertIn("pragma-suppressed", out)

    def test_json_report_shape(self):
        code, out = self.run_main("--root", os.path.join(TESTDATA, "violate"), "--json")
        self.assertEqual(code, 1)
        data = json.loads(out)
        for key in ("rules", "findings", "baseline_covered", "stale", "suppressed", "counts"):
            self.assertIn(key, data)
        paths = {f["path"] for f in data["findings"]}
        self.assertIn("rust/src/d001.rs", paths)
        lines = {f["line"] for f in data["findings"] if f["path"] == "rust/src/d001.rs"}
        self.assertEqual(lines, {4})

    def test_baseline_flow_end_to_end(self):
        with tempfile.TemporaryDirectory() as tmp:
            # p001.rs keeps the violate tree red even under a full baseline,
            # so drive the ratchet flow on a copy without it
            import shutil

            root = os.path.join(tmp, "violate")
            shutil.copytree(os.path.join(TESTDATA, "violate"), root)
            os.remove(os.path.join(root, "rust", "src", "p001.rs"))
            base = os.path.join(tmp, "baseline.json")
            code, _ = self.run_main("--root", root, "--write-baseline", base)
            self.assertEqual(code, 0)
            code, out = self.run_main("--root", root, "--baseline", base)
            self.assertEqual(code, 0, out)
            self.assertIn("baseline-covered", out)
            # fixing a file makes the baseline stale -> fails until locked
            os.remove(os.path.join(root, "rust", "src", "d001.rs"))
            code, out = self.run_main("--root", root, "--baseline", base)
            self.assertEqual(code, 1)
            self.assertIn("stale", out)
            code, _ = self.run_main("--root", root, "--baseline", base, "--allow-stale")
            self.assertEqual(code, 0)
            code, _ = self.run_main("--root", root, "--write-baseline", base)
            code, out = self.run_main("--root", root, "--baseline", base)
            self.assertEqual(code, 0, out)


if __name__ == "__main__":
    unittest.main(verbosity=2)
