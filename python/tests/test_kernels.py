"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

The hypothesis sweeps cover shapes/dtypes/magnitudes; the targeted tests
pin the algebraic identities the coordinator relies on (K symmetry, PSD-ish
structure, mask zeroing, norms == sqrt(diag K)).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.filter_score import repdiv_score
from compile.kernels.grad_gram import delta_and_hnorm2, grad_gram, gram

RNG = np.random.default_rng(1234)


def _case(n, c, f, scale=1.0, mask_frac=1.0, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    z = (rng.normal(size=(n, c)) * scale).astype(dtype)
    y = np.eye(c, dtype=dtype)[rng.integers(0, c, n)]
    h = (rng.normal(size=(n, f)) * scale).astype(dtype)
    m = (rng.random(n) < mask_frac).astype(dtype)
    return jnp.array(z), jnp.array(y), jnp.array(h), jnp.array(m)


# ---------------------------------------------------------------------------
# grad_gram kernel
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=130),  # n (crosses the 64 tile edge)
    st.integers(min_value=2, max_value=21),   # c
    st.integers(min_value=1, max_value=96),   # f
)


@settings(max_examples=25, deadline=None)
@given(
    shape=shape_strategy,
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    mask_frac=st.sampled_from([0.0, 0.5, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_grad_gram_matches_ref(shape, scale, mask_frac, seed):
    n, c, f = shape
    z, y, h, m = _case(n, c, f, scale=scale, mask_frac=mask_frac, seed=seed)
    norms, k = grad_gram(z, y, h, m)
    rn, rk = ref.grad_gram_ref(z, y, h, m)
    kscale = max(1.0, float(jnp.max(jnp.abs(rk))))
    np.testing.assert_allclose(np.asarray(k), np.asarray(rk), atol=2e-4 * kscale, rtol=2e-4)
    nscale = max(1.0, float(jnp.max(rn)))
    np.testing.assert_allclose(np.asarray(norms), np.asarray(rn), atol=2e-4 * nscale, rtol=2e-4)


@settings(max_examples=15, deadline=None)
@given(shape=shape_strategy, seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_gram_symmetric(shape, seed):
    n, c, f = shape
    z, y, h, m = _case(n, c, f, seed=seed)
    _, k = grad_gram(z, y, h, m)
    k = np.asarray(k)
    np.testing.assert_allclose(k, k.T, atol=1e-5 * max(1.0, np.abs(k).max()))


def test_norms_are_sqrt_diag_k():
    z, y, h, m = _case(100, 10, 64, seed=7)
    norms, k = grad_gram(z, y, h, m)
    np.testing.assert_allclose(
        np.asarray(norms),
        np.sqrt(np.maximum(np.diag(np.asarray(k)), 0.0)),
        atol=1e-4, rtol=1e-4,
    )


def test_mask_zeroes_rows_and_cols():
    z, y, h, _ = _case(40, 5, 16, seed=3)
    m = np.ones(40, np.float32)
    m[7] = 0.0
    m[23] = 0.0
    norms, k = grad_gram(z, y, h, jnp.array(m))
    k = np.asarray(k)
    assert float(norms[7]) == 0.0 and float(norms[23]) == 0.0
    assert np.all(k[7, :] == 0.0) and np.all(k[:, 7] == 0.0)
    assert np.all(k[23, :] == 0.0) and np.all(k[:, 23] == 0.0)


def test_extreme_logits_stable():
    """Softmax must be stabilized: huge logits must not produce NaN/inf."""
    z, y, h, m = _case(16, 4, 8, seed=5)
    z = z * 1e4
    norms, k = grad_gram(z, y, h, m)
    assert np.all(np.isfinite(np.asarray(norms)))
    assert np.all(np.isfinite(np.asarray(k)))


def test_delta_rows_sum_to_zero():
    """softmax(z) - onehot rows sum to 0 for unmasked samples."""
    z, y, h, m = _case(32, 6, 8, mask_frac=1.0, seed=9)
    d, hn2 = delta_and_hnorm2(z, y, h, m)
    np.testing.assert_allclose(np.asarray(jnp.sum(d, axis=-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(hn2), np.asarray(jnp.sum(h * h, axis=-1)), rtol=1e-5
    )


def test_gram_psd_on_quadratic_forms():
    """K is a Gram matrix: v^T K v >= 0 for any v (up to f32 noise)."""
    z, y, h, m = _case(60, 10, 32, seed=11)
    _, k = grad_gram(z, y, h, m)
    k = np.asarray(k, dtype=np.float64)
    rng = np.random.default_rng(0)
    for _ in range(20):
        v = rng.normal(size=60)
        q = v @ k @ v
        assert q >= -1e-3 * max(1.0, np.abs(k).max()), q


def test_tile_boundary_sizes():
    """Exercise n exactly at / around the 64 tile size."""
    for n in (63, 64, 65, 128):
        z, y, h, m = _case(n, 7, 24, seed=n)
        norms, k = grad_gram(z, y, h, m)
        rn, rk = ref.grad_gram_ref(z, y, h, m)
        np.testing.assert_allclose(np.asarray(k), np.asarray(rk), atol=1e-3, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(norms), np.asarray(rn), atol=1e-4, rtol=1e-4)


def test_gram_standalone_matches_ref():
    z, y, h, m = _case(50, 8, 40, seed=21)
    d = ref.delta_ref(z, y, m)
    k = gram(d, h)
    rk = ref.gram_ref(z, y, h, m)
    np.testing.assert_allclose(np.asarray(k), np.asarray(rk), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# filter_score kernel
# ---------------------------------------------------------------------------

def _filter_case(b, c, f, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(b, f)).astype(np.float32)
    cen = rng.normal(size=(c, f)).astype(np.float32)
    m2 = (rng.random(c) * 10).astype(np.float32)
    y = np.eye(c, dtype=np.float32)[rng.integers(0, c, b)]
    return jnp.array(feats), jnp.array(cen), jnp.array(m2), jnp.array(y)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=40),
    c=st.integers(min_value=2, max_value=20),
    f=st.integers(min_value=1, max_value=96),
    lam=st.sampled_from([0.0, 0.3, 0.5, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_repdiv_matches_ref(b, c, f, lam, seed):
    feats, cen, m2, y = _filter_case(b, c, f, seed)
    lamv = jnp.array([lam], jnp.float32)
    s = repdiv_score(feats, cen, m2, y, lamv)
    rs = ref.repdiv_ref(feats, cen, m2, y, lamv[0])
    scale = max(1.0, float(jnp.max(jnp.abs(rs))))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=2e-4 * scale, rtol=2e-4)


def test_paper_lam_half_cancels_within_class():
    """DESIGN.md §Discrepancies: at lam=0.5 the score is a per-class
    constant — the paper's unweighted Rep+Div cannot rank within a class."""
    feats, cen, m2, y = _filter_case(30, 4, 16, seed=2)
    s = np.asarray(repdiv_score(feats, cen, m2, y, jnp.array([0.5], jnp.float32)))
    labels = np.argmax(np.asarray(y), axis=-1)
    for cls in range(4):
        vals = s[labels == cls]
        if len(vals) > 1:
            assert np.ptp(vals) < 1e-4 * max(1.0, np.abs(vals).max())


def test_lam_extremes_are_pure_rep_and_div():
    feats, cen, m2, y = _filter_case(12, 3, 8, seed=4)
    s_rep = np.asarray(repdiv_score(feats, cen, m2, y, jnp.array([1.0], jnp.float32)))
    s_div = np.asarray(repdiv_score(feats, cen, m2, y, jnp.array([0.0], jnp.float32)))
    c = np.asarray(y) @ np.asarray(cen)
    m2s = np.asarray(y) @ np.asarray(m2)
    f = np.asarray(feats)
    rep = -np.sum((f - c) ** 2, axis=-1)
    div = np.sum(f * f, axis=-1) + m2s - 2 * np.sum(f * c, axis=-1)
    np.testing.assert_allclose(s_rep, rep, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_div, div, atol=1e-4, rtol=1e-4)
