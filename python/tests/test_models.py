"""L2 model-zoo tests: shapes, learning signal, and the importance math
each variant exposes to the coordinator."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

ALL_VARIANTS = list(M.VARIANTS)


def _batch(mdef, n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, mdef.input_dim)).astype(np.float32)
    y = np.eye(mdef.num_classes, dtype=np.float32)[
        rng.integers(0, mdef.num_classes, n)
    ]
    return jnp.array(x), jnp.array(y)


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_shapes_contract(name):
    mdef = M.VARIANTS[name]
    flat, unravel = M.init_flat(mdef)
    x, y = _batch(mdef, 4)
    z, h = M.logits_and_h(mdef, unravel, flat, x)
    assert z.shape == (4, mdef.num_classes)
    assert h.shape == (4, mdef.h_dim)
    dims = M.block_feature_dims(mdef)
    assert len(dims) >= 2, "filter needs at least 2 depths for Fig. 8"
    for k in range(1, len(dims) + 1):
        feats = M.make_features(mdef, unravel, n_blocks=k)
        (f,) = feats(flat, x)
        assert f.shape == (4, dims[k - 1])


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_train_step_reduces_loss(name):
    """A few SGD steps on a fixed batch must reduce its loss (learning
    signal sanity for every variant we ship)."""
    mdef = M.VARIANTS[name]
    flat, unravel = M.init_flat(mdef)
    step = jax.jit(M.make_train_step(mdef, unravel))
    x, y = _batch(mdef, M.TRAIN_BATCH, seed=3)
    lr = jnp.float32(0.05)
    w = jnp.ones((M.TRAIN_BATCH,), jnp.float32)
    p = flat
    p, loss0 = step(p, x, y, w, lr)
    for _ in range(10):
        p, loss = step(p, x, y, w, lr)
    assert float(loss) < float(loss0), (float(loss0), float(loss))
    assert np.all(np.isfinite(np.asarray(p)))


def test_weighted_step_scales_update():
    """Zero weights freeze the params; doubling all weights doubles the
    (first-order) update — the unbiased-estimator contract."""
    mdef = M.VARIANTS["mlp"]
    flat, unravel = M.init_flat(mdef)
    step = jax.jit(M.make_train_step(mdef, unravel))
    x, y = _batch(mdef, M.TRAIN_BATCH, seed=11)
    lr = jnp.float32(0.01)
    zeros = jnp.zeros((M.TRAIN_BATCH,), jnp.float32)
    p_frozen, loss0 = step(flat, x, y, zeros, lr)
    np.testing.assert_allclose(np.asarray(p_frozen), np.asarray(flat))
    assert float(loss0) == 0.0
    ones = jnp.ones((M.TRAIN_BATCH,), jnp.float32)
    p1, _ = step(flat, x, y, ones, lr)
    p2, _ = step(flat, x, y, 2.0 * ones, lr)
    d1 = np.asarray(p1) - np.asarray(flat)
    d2 = np.asarray(p2) - np.asarray(flat)
    np.testing.assert_allclose(d2, 2.0 * d1, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_importance_consistent_with_ref(name):
    """The per-variant importance graph must agree with composing the
    oracle on that variant's own (logits, h)."""
    mdef = M.VARIANTS[name]
    flat, unravel = M.init_flat(mdef)
    imp = M.make_importance(mdef, unravel)
    n = M.CAND_MAX
    x, y = _batch(mdef, n, seed=5)
    mask = jnp.array((np.arange(n) < 42).astype(np.float32))
    norms, k = imp(flat, x, y, mask)
    z, h = M.logits_and_h(mdef, unravel, flat, x)
    rn, rk = ref.grad_gram_ref(z, y, h, mask)
    kscale = max(1.0, float(jnp.max(jnp.abs(rk))))
    np.testing.assert_allclose(np.asarray(k), np.asarray(rk), atol=3e-4 * kscale, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(rn), atol=1e-3, rtol=1e-3)
    # masked tail contributes nothing
    assert np.all(np.asarray(norms)[42:] == 0.0)


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_evaluate_counts(name):
    mdef = M.VARIANTS[name]
    flat, unravel = M.init_flat(mdef)
    ev = M.make_evaluate(mdef, unravel)
    x, y = _batch(mdef, 16, seed=7)
    ls, corr = ev(flat, x, y)
    z, _ = M.logits_and_h(mdef, unravel, flat, x)
    pred = np.argmax(np.asarray(z), axis=-1)
    truth = np.argmax(np.asarray(y), axis=-1)
    assert float(corr) == float(np.sum(pred == truth))
    # loss_sum == 16 * mean CE
    assert abs(float(ls) / 16.0 - float(M.ce_loss(z, y))) < 1e-4


def test_ce_loss_matches_uniform():
    """CE of uniform logits is log C."""
    z = jnp.zeros((5, 10), jnp.float32)
    y = jnp.array(np.eye(10, dtype=np.float32)[np.arange(5)])
    assert abs(float(M.ce_loss(z, y)) - np.log(10)) < 1e-6


def test_train_step_gradient_check_mlp():
    """Finite-difference check of the lowered loss gradient (mlp)."""
    mdef = M.VARIANTS["mlp"]
    flat, unravel = M.init_flat(mdef)
    x, y = _batch(mdef, 4, seed=9)

    def loss_of(p):
        z, _ = M.logits_and_h(mdef, unravel, p, x)
        return M.ce_loss(z, y)

    g = jax.grad(loss_of)(flat)
    rng = np.random.default_rng(0)
    idxs = rng.integers(0, flat.shape[0], size=5)
    eps = 1e-3
    for i in idxs:
        e = np.zeros(flat.shape[0], np.float32)
        e[i] = eps
        num = (float(loss_of(flat + e)) - float(loss_of(flat - e))) / (2 * eps)
        assert abs(num - float(g[i])) < 5e-3, (i, num, float(g[i]))


def test_init_flat_deterministic():
    mdef = M.VARIANTS["mlp"]
    a, _ = M.init_flat(mdef, seed=0)
    b, _ = M.init_flat(mdef, seed=0)
    c, _ = M.init_flat(mdef, seed=1)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("name", ALL_VARIANTS)
def test_param_counts_edge_sized(name):
    """Every variant stays edge-sized (< 300k params) but non-trivial."""
    mdef = M.VARIANTS[name]
    flat, _ = M.init_flat(mdef)
    assert 1_000 < flat.shape[0] < 300_000, flat.shape[0]
