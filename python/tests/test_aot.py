"""AOT path tests.

Two things are checked here:
  1. every emitted .hlo.txt parses through XLA's HLO *text* parser — the
     exact entry point the Rust runtime uses (HloModuleProto::from_text_file);
  2. golden.json reproduces when the un-lowered jax functions are re-run on
     the deterministic inputs — so the goldens the Rust integration tests
     compare against are trustworthy.

Actually *executing* the HLO artifacts is the Rust runtime's job (jaxlib
0.8's client only accepts StableHLO bytecode, not HLO protos); the Rust
test suite executes every artifact against golden.json.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


def test_det_input_reproducible():
    a = aot.det_input(5, 7)
    b = aot.det_input(5, 7)
    assert np.array_equal(a, b)
    # spot-check the formula the Rust test reimplements
    assert abs(float(a[0, 0]) - np.float32(np.sin(0.1))) < 1e-7
    assert abs(float(a[0, 3]) - np.float32(np.sin(0.4))) < 1e-7


def test_det_onehot():
    y = aot.det_onehot(7, 3)
    assert y.shape == (7, 3)
    assert np.array_equal(np.argmax(y, axis=1), np.arange(7) % 3)
    assert np.all(y.sum(axis=1) == 1.0)


def test_to_hlo_text_smoke():
    fn = lambda a, b: (a @ b + 1.0,)
    sd = jax.ShapeDtypeStruct((3, 3), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(sd, sd))
    assert "HloModule" in text
    assert "ROOT" in text
    # and it parses back through the text parser (the Rust load path)
    xc._xla.hlo_module_from_text(text)


def _built_variants():
    if not os.path.isdir(ART):
        return []
    return sorted(
        d for d in os.listdir(ART)
        if os.path.isdir(os.path.join(ART, d)) and
        os.path.exists(os.path.join(ART, d, "meta.json"))
    )


@pytest.mark.skipif(not _built_variants(), reason="run `make artifacts` first")
def test_all_built_artifacts_complete_and_parse():
    """Every built variant dir carries the full contract, and every HLO text
    file parses through XLA's text parser."""
    for v in _built_variants():
        vdir = os.path.join(ART, v)
        with open(os.path.join(vdir, "meta.json")) as f:
            meta = json.load(f)
        required = ["train_step.hlo.txt", "importance.hlo.txt", "eval.hlo.txt",
                    "init_params.bin", "golden.json"]
        required += [f"features_b{k}.hlo.txt" for k in range(1, len(meta["block_dims"]) + 1)]
        for req in required:
            assert os.path.exists(os.path.join(vdir, req)), (v, req)
            if req.endswith(".hlo.txt"):
                with open(os.path.join(vdir, req)) as f:
                    xc._xla.hlo_module_from_text(f.read())  # raises on bad text
        params = np.fromfile(os.path.join(vdir, "init_params.bin"), dtype="<f4")
        assert params.shape[0] == meta["param_count"]
        assert np.all(np.isfinite(params))


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "mlp")), reason="run `make artifacts` first")
def test_mlp_golden_reproduces():
    """Re-run the (un-lowered) jax functions on the deterministic inputs and
    compare to the shipped golden.json — guards golden staleness."""
    vdir = os.path.join(ART, "mlp")
    with open(os.path.join(vdir, "golden.json")) as f:
        golden = json.load(f)
    mdef = M.VARIANTS["mlp"]
    flat, unravel = M.init_flat(mdef, seed=0)
    shipped = np.fromfile(os.path.join(vdir, "init_params.bin"), dtype="<f4")
    np.testing.assert_allclose(np.asarray(flat), shipped, atol=0, rtol=0)

    fresh = aot.make_golden(mdef, flat, unravel, mdef.input_dim, mdef.num_classes)
    for key, val in golden.items():
        got = fresh[key]
        if isinstance(val, list):
            np.testing.assert_allclose(got, val, atol=1e-5, rtol=1e-5)
        else:
            assert abs(got - val) <= 1e-5 * max(1.0, abs(val)), (key, got, val)


@pytest.mark.skipif(not _built_variants(), reason="run `make artifacts` first")
def test_importance_artifact_contains_pallas_structure():
    """The importance module must contain the Gram matmuls (the L1 kernels
    lowered into the same HLO), i.e. dot ops producing the [N,N] K tile."""
    for v in _built_variants():
        with open(os.path.join(ART, v, "importance.hlo.txt")) as f:
            text = f.read()
        with open(os.path.join(ART, v, "meta.json")) as f:
            meta = json.load(f)
        n = meta["cand_max"]
        assert f"f32[{n},{n}]" in text, v  # the K output / tiles
        assert "dot(" in text, v
