"""L2: JAX model zoo for the Titan reproduction (build-time only).

Six functional model variants mirroring the paper's six rows (Table 1),
scaled to edge/CPU size but architecturally faithful (see DESIGN.md
§Substitutions):

    mlp        - HAR  MLP 900-128-64-6            (paper: MLP)
    tinyalex   - IC   conv5x5 stack + dense head  (paper: AlexNet)
    mobilenet  - IC   depthwise-separable blocks  (paper: MobileNetV1)
    squeeze    - IC   fire modules                (paper: SqueezeNet)
    resnet_ic  - IC   residual blocks             (paper: ResNet50)
    resnet_ar  - AR   residual blocks, 1ch input  (paper: ResNet34)

Every variant exposes the same functional surface, which is all the L3
coordinator ever sees (through the AOT artifacts):

    train_step(params_flat, x, y_onehot, lr)    -> (params_flat', loss)
    features_k(params_flat, x)                  -> block-k features  (filter)
    importance(params_flat, x, y_onehot, mask)  -> (norms, K)        (C-IS)
    evaluate(params_flat, x, y_onehot)          -> (loss_sum, correct)

Parameters cross the Rust boundary as one flat f32 vector; the pytree
structure lives only inside the lowered HLO (ravel_pytree's unravel closure
is baked into the jitted function). `importance` calls the L1 Pallas
kernels so they lower into the same HLO module.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from .kernels.grad_gram import grad_gram

Params = Dict[str, jnp.ndarray]

# Batch geometry shared with the Rust side (recorded in meta.json).
TRAIN_BATCH = 10    # |B|: paper's on-device training batch size
TRAIN_BATCHES_EXTRA = [25]  # extra train_step lowerings (paper Fig. 2b)
FILTER_CHUNK = 25   # streaming samples scored per features() call
CAND_MAX = 100      # importance N (mask handles smaller candidate sets)
EVAL_CHUNK = 200    # test-set evaluation chunk


# --------------------------------------------------------------------------
# Initialization helpers
# --------------------------------------------------------------------------

def _he_conv(key, out_c: int, in_c: int, kh: int, kw: int) -> jnp.ndarray:
    """He-normal conv kernel, OIHW layout."""
    fan_in = in_c * kh * kw
    std = jnp.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (out_c, in_c, kh, kw), jnp.float32) * std


def _he_dense(key, n_in: int, n_out: int) -> jnp.ndarray:
    std = jnp.sqrt(2.0 / n_in)
    return jax.random.normal(key, (n_in, n_out), jnp.float32) * std


def _conv(x, w, b, stride: int = 1, padding: str = "SAME", groups: int = 1):
    """NCHW conv + bias. groups=C_in gives a depthwise convolution."""
    y = lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )
    return y + b[None, :, None, None]


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _gap(x):
    """Global average pool NCHW -> [B, C]."""
    return jnp.mean(x, axis=(2, 3))


def _relu(x):
    return jnp.maximum(x, 0.0)


# --------------------------------------------------------------------------
# Model definitions
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A model variant: init + trunk. The dense head is shared logic.

    trunk(params, x) returns (h, block_feats) where h is the penultimate
    feature [B, h_dim] feeding the final dense layer, and block_feats is the
    list of pooled per-block features [B, f_k] used by the coarse filter at
    depth k (paper Fig. 8 sweeps k).
    """

    name: str
    input_shape: Tuple[int, ...]  # per-sample, e.g. (3, 32, 32) or (900,)
    num_classes: int
    h_dim: int
    init: Callable[[jax.Array], Params]
    trunk: Callable[[Params, jnp.ndarray], Tuple[jnp.ndarray, List[jnp.ndarray]]]

    @property
    def input_dim(self) -> int:
        d = 1
        for s in self.input_shape:
            d *= s
        return d


def _head_init(key, h_dim: int, num_classes: int) -> Params:
    kw, _ = jax.random.split(key)
    return {
        # 0.1x-scaled head: keeps initial logits near zero (loss ~ log C)
        # regardless of the trunk's activation scale, so softmax gradients
        # are healthy from step 0 on every variant.
        "head_w": _he_dense(kw, h_dim, num_classes) * 0.1,
        "head_b": jnp.zeros((num_classes,), jnp.float32),
    }


def _reshape_in(mdef: ModelDef, x: jnp.ndarray) -> jnp.ndarray:
    """Rust always ships x as [B, input_dim]; restore the tensor layout."""
    return x.reshape((x.shape[0],) + mdef.input_shape)


# ----- mlp (HAR) -----------------------------------------------------------

def _mlp_init(key) -> Params:
    k1, k2, kh = jax.random.split(key, 3)
    p = {
        "w1": _he_dense(k1, 900, 128), "b1": jnp.zeros((128,), jnp.float32),
        "w2": _he_dense(k2, 128, 64), "b2": jnp.zeros((64,), jnp.float32),
    }
    p.update(_head_init(kh, 64, 6))
    return p


def _mlp_trunk(p: Params, x: jnp.ndarray):
    a1 = _relu(x @ p["w1"] + p["b1"])
    a2 = _relu(a1 @ p["w2"] + p["b2"])
    return a2, [a1, a2]


# ----- tinyalex (IC) -------------------------------------------------------

def _tinyalex_init(key) -> Params:
    k1, k2, k3, k4, kh = jax.random.split(key, 5)
    p = {
        "c1_w": _he_conv(k1, 16, 3, 5, 5), "c1_b": jnp.zeros((16,), jnp.float32),
        "c2_w": _he_conv(k2, 32, 16, 5, 5), "c2_b": jnp.zeros((32,), jnp.float32),
        "c3_w": _he_conv(k3, 32, 32, 3, 3), "c3_b": jnp.zeros((32,), jnp.float32),
        "f1_w": _he_dense(k4, 32 * 4 * 4, 64), "f1_b": jnp.zeros((64,), jnp.float32),
    }
    p.update(_head_init(kh, 64, 10))
    return p


def _tinyalex_trunk(p: Params, x: jnp.ndarray):
    b1 = _maxpool2(_relu(_conv(x, p["c1_w"], p["c1_b"])))       # 16x16x16
    b2 = _maxpool2(_relu(_conv(b1, p["c2_w"], p["c2_b"])))      # 32x8x8
    b3 = _maxpool2(_relu(_conv(b2, p["c3_w"], p["c3_b"])))      # 32x4x4
    h = _relu(b3.reshape(b3.shape[0], -1) @ p["f1_w"] + p["f1_b"])
    return h, [_gap(b1), _gap(b2), _gap(b3)]


# ----- mobilenet (IC) ------------------------------------------------------

def _dwsep_init(key, in_c: int, out_c: int, tag: str) -> Params:
    kd, kp = jax.random.split(key)
    return {
        f"{tag}_dw": _he_conv(kd, in_c, 1, 3, 3),
        f"{tag}_db": jnp.zeros((in_c,), jnp.float32),
        f"{tag}_pw": _he_conv(kp, out_c, in_c, 1, 1),
        f"{tag}_pb": jnp.zeros((out_c,), jnp.float32),
    }


def _dwsep(p: Params, x: jnp.ndarray, tag: str, stride: int = 1):
    c = x.shape[1]
    y = _relu(_conv(x, p[f"{tag}_dw"], p[f"{tag}_db"], stride=stride, groups=c))
    return _relu(_conv(y, p[f"{tag}_pw"], p[f"{tag}_pb"]))


def _mobilenet_init(key) -> Params:
    k1, k2, k3, k4, kh = jax.random.split(key, 5)
    p = {
        "c1_w": _he_conv(k1, 16, 3, 3, 3), "c1_b": jnp.zeros((16,), jnp.float32),
    }
    p.update(_dwsep_init(k2, 16, 32, "d1"))
    p.update(_dwsep_init(k3, 32, 64, "d2"))
    p.update(_dwsep_init(k4, 64, 64, "d3"))
    p.update(_head_init(kh, 64, 10))
    return p


def _mobilenet_trunk(p: Params, x: jnp.ndarray):
    b1 = _relu(_conv(x, p["c1_w"], p["c1_b"], stride=2))  # 16x16x16
    b2 = _dwsep(p, b1, "d1")                              # 32x16x16
    b3 = _dwsep(p, b2, "d2", stride=2)                    # 64x8x8
    b4 = _dwsep(p, b3, "d3")                              # 64x8x8
    h = _gap(b4)
    return h, [_gap(b1), _gap(b2), _gap(b3), h]


# ----- squeeze (IC) --------------------------------------------------------

def _fire_init(key, in_c: int, sq: int, ex: int, tag: str) -> Params:
    ks, k1, k3 = jax.random.split(key, 3)
    return {
        f"{tag}_sw": _he_conv(ks, sq, in_c, 1, 1),
        f"{tag}_sb": jnp.zeros((sq,), jnp.float32),
        f"{tag}_e1w": _he_conv(k1, ex, sq, 1, 1),
        f"{tag}_e1b": jnp.zeros((ex,), jnp.float32),
        f"{tag}_e3w": _he_conv(k3, ex, sq, 3, 3),
        f"{tag}_e3b": jnp.zeros((ex,), jnp.float32),
    }


def _fire(p: Params, x: jnp.ndarray, tag: str):
    s = _relu(_conv(x, p[f"{tag}_sw"], p[f"{tag}_sb"]))
    e1 = _relu(_conv(s, p[f"{tag}_e1w"], p[f"{tag}_e1b"]))
    e3 = _relu(_conv(s, p[f"{tag}_e3w"], p[f"{tag}_e3b"]))
    return jnp.concatenate([e1, e3], axis=1)


def _squeeze_init(key) -> Params:
    k1, k2, k3, kh = jax.random.split(key, 4)
    p = {
        "c1_w": _he_conv(k1, 24, 3, 3, 3), "c1_b": jnp.zeros((24,), jnp.float32),
    }
    p.update(_fire_init(k2, 24, 8, 16, "f1"))
    p.update(_fire_init(k3, 32, 8, 24, "f2"))
    p.update(_head_init(kh, 48, 10))
    return p


def _squeeze_trunk(p: Params, x: jnp.ndarray):
    b1 = _relu(_conv(x, p["c1_w"], p["c1_b"], stride=2))  # 24x16x16
    b2 = _maxpool2(_fire(p, b1, "f1"))                    # 32x8x8
    b3 = _fire(p, b2, "f2")                               # 48x8x8
    h = _gap(b3)
    return h, [_gap(b1), _gap(b2), h]


# ----- resnets -------------------------------------------------------------

def _resblock_init(key, in_c: int, out_c: int, tag: str) -> Params:
    k1, _k2, kp = jax.random.split(key, 3)
    p = {
        f"{tag}_w1": _he_conv(k1, out_c, in_c, 3, 3),
        f"{tag}_b1": jnp.zeros((out_c,), jnp.float32),
        # zero-init the residual branch's second conv: each block is the
        # identity at init, keeping activation variance (and the initial
        # logit scale) bounded through the residual chain — without this
        # the 20-class audio resnet starts at loss ~20 (softmax saturated)
        # and cannot escape.
        f"{tag}_w2": jnp.zeros((out_c, out_c, 3, 3), jnp.float32),
        f"{tag}_b2": jnp.zeros((out_c,), jnp.float32),
    }
    if in_c != out_c:
        p[f"{tag}_pw"] = _he_conv(kp, out_c, in_c, 1, 1)
        p[f"{tag}_pb"] = jnp.zeros((out_c,), jnp.float32)
    return p


def _resblock(p: Params, x: jnp.ndarray, tag: str, stride: int = 1):
    y = _relu(_conv(x, p[f"{tag}_w1"], p[f"{tag}_b1"], stride=stride))
    y = _conv(y, p[f"{tag}_w2"], p[f"{tag}_b2"])
    if f"{tag}_pw" in p or stride != 1:
        sc = _conv(x, p[f"{tag}_pw"], p[f"{tag}_pb"], stride=stride)
    else:
        sc = x
    return _relu(y + sc)


def _resnet_ic_init(key) -> Params:
    k1, k2, k3, k4, k5, kh = jax.random.split(key, 6)
    p = {
        "c1_w": _he_conv(k1, 16, 3, 3, 3), "c1_b": jnp.zeros((16,), jnp.float32),
    }
    p.update(_resblock_init(k2, 16, 16, "r1"))
    p.update(_resblock_init(k3, 16, 32, "r2"))
    p.update(_resblock_init(k4, 32, 32, "r3"))
    p.update(_resblock_init(k5, 32, 64, "r4"))
    p.update(_head_init(kh, 64, 10))
    return p


def _resnet_ic_trunk(p: Params, x: jnp.ndarray):
    b1 = _relu(_conv(x, p["c1_w"], p["c1_b"]))            # 16x32x32
    b2 = _resblock(p, b1, "r1")                           # 16x32x32
    b3 = _resblock(p, b2, "r2", stride=2)                 # 32x16x16
    b4 = _resblock(p, b3, "r3")                           # 32x16x16
    b5 = _resblock(p, b4, "r4", stride=2)                 # 64x8x8
    h = _gap(b5)
    return h, [_gap(b1), _gap(b2), _gap(b3), _gap(b4), h]


def _resnet_ar_init(key) -> Params:
    k1, k2, k3, k4, kh = jax.random.split(key, 5)
    p = {
        "c1_w": _he_conv(k1, 16, 1, 3, 3), "c1_b": jnp.zeros((16,), jnp.float32),
    }
    p.update(_resblock_init(k2, 16, 16, "r1"))
    p.update(_resblock_init(k3, 16, 32, "r2"))
    p.update(_resblock_init(k4, 32, 32, "r3"))
    p.update(_head_init(kh, 32, 20))
    return p


def _resnet_ar_trunk(p: Params, x: jnp.ndarray):
    b1 = _relu(_conv(x, p["c1_w"], p["c1_b"], stride=2))  # 16x20x20
    b2 = _resblock(p, b1, "r1")                           # 16x20x20
    b3 = _resblock(p, b2, "r2", stride=2)                 # 32x10x10
    b4 = _resblock(p, b3, "r3")                           # 32x10x10
    h = _gap(b4)
    return h, [_gap(b1), _gap(b2), _gap(b3), h]


VARIANTS: Dict[str, ModelDef] = {
    "mlp": ModelDef("mlp", (900,), 6, 64, _mlp_init, _mlp_trunk),
    "tinyalex": ModelDef("tinyalex", (3, 32, 32), 10, 64, _tinyalex_init, _tinyalex_trunk),
    "mobilenet": ModelDef("mobilenet", (3, 32, 32), 10, 64, _mobilenet_init, _mobilenet_trunk),
    "squeeze": ModelDef("squeeze", (3, 32, 32), 10, 48, _squeeze_init, _squeeze_trunk),
    "resnet_ic": ModelDef("resnet_ic", (3, 32, 32), 10, 64, _resnet_ic_init, _resnet_ic_trunk),
    "resnet_ar": ModelDef("resnet_ar", (1, 40, 40), 20, 32, _resnet_ar_init, _resnet_ar_trunk),
}


# --------------------------------------------------------------------------
# Shared functional surface (what gets lowered to HLO)
# --------------------------------------------------------------------------

def init_flat(mdef: ModelDef, seed: int = 0) -> Tuple[jnp.ndarray, Callable]:
    """Initialize a variant; returns (params_flat, unravel)."""
    params = mdef.init(jax.random.PRNGKey(seed))
    flat, unravel = ravel_pytree(params)
    return flat.astype(jnp.float32), unravel


def logits_and_h(mdef: ModelDef, unravel, params_flat, x):
    p = unravel(params_flat)
    h, _ = mdef.trunk(p, _reshape_in(mdef, x))
    z = h @ p["head_w"] + p["head_b"]
    return z, h


def ce_loss(logits: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy (stable log-softmax)."""
    zmax = jnp.max(logits, axis=-1, keepdims=True)
    logz = zmax + jnp.log(jnp.sum(jnp.exp(logits - zmax), axis=-1, keepdims=True))
    ll = jnp.sum(onehot * (logits - logz), axis=-1)
    return -jnp.mean(ll)


def make_train_step(mdef: ModelDef, unravel) -> Callable:
    """Weighted SGD step: (params, x[B,D], y[B,C], w[B], lr[]) -> (params', loss).

    Per-sample weights implement the paper's unbiased estimator (Appendix
    A.2 eq. (f): each selected sample is weighted by 1/(probability x
    size)). w = ones reproduces the plain mini-batch mean.
    """

    def loss_fn(params_flat, x, y, w):
        z, _ = logits_and_h(mdef, unravel, params_flat, x)
        zmax = jnp.max(z, axis=-1, keepdims=True)
        logz = zmax + jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1, keepdims=True))
        ll = jnp.sum(y * (z - logz), axis=-1)
        return -jnp.mean(w * ll)

    def step(params_flat, x, y, w, lr):
        loss, g = jax.value_and_grad(loss_fn)(params_flat, x, y, w)
        return (params_flat - lr * g, loss)

    return step


def make_features(mdef: ModelDef, unravel, n_blocks: int = 1) -> Callable:
    """Coarse-filter feature extractor: first n_blocks of the trunk.

    Returns the pooled features of block n_blocks. The full trunk is traced
    but XLA's dead-code elimination prunes everything past the requested
    block, so the lowered module really is "the first few layers" (verified
    by the per-depth latency spread in `exp fig8`).
    """

    def feats(params_flat, x):
        p = unravel(params_flat)
        _, blocks = mdef.trunk(p, _reshape_in(mdef, x))
        k = min(n_blocks, len(blocks)) - 1
        return (blocks[k],)

    return feats


def make_importance(mdef: ModelDef, unravel) -> Callable:
    """Fine-grained importance: (params, x[N,D], y[N,C], mask[N]) -> (norms, K).

    One shared forward pass produces h and logits; the L1 Pallas kernels
    (grad_gram) lower into this same HLO module.
    """

    def imp(params_flat, x, y, mask):
        z, h = logits_and_h(mdef, unravel, params_flat, x)
        norms, k = grad_gram(z, y, h, mask)
        return (norms, k)

    return imp


def make_probe(mdef: ModelDef, unravel) -> Callable:
    """Per-candidate heuristic scores for the baseline selectors:
    (params, x[N,D], y[N,C], mask[N]) -> (loss[N], entropy[N]).

    loss  - per-sample softmax CE (LL / HL baselines)
    entropy - output-distribution entropy (the "CE" baseline)
    Masked rows return 0 for both.
    """

    def probe(params_flat, x, y, mask):
        z, _ = logits_and_h(mdef, unravel, params_flat, x)
        zmax = jnp.max(z, axis=-1, keepdims=True)
        logz = zmax + jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1, keepdims=True))
        logp = z - logz
        loss = -jnp.sum(y * logp, axis=-1) * mask
        p = jnp.exp(logp)
        ent = -jnp.sum(p * logp, axis=-1) * mask
        return (loss, ent)

    return probe


def make_evaluate(mdef: ModelDef, unravel) -> Callable:
    """Eval chunk: (params, x[E,D], y[E,C]) -> (loss_sum, correct_count)."""

    def ev(params_flat, x, y):
        z, _ = logits_and_h(mdef, unravel, params_flat, x)
        zmax = jnp.max(z, axis=-1, keepdims=True)
        logz = zmax + jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1, keepdims=True))
        ll = jnp.sum(y * (z - logz), axis=-1)
        pred = jnp.argmax(z, axis=-1)
        truth = jnp.argmax(y, axis=-1)
        return (-jnp.sum(ll), jnp.sum((pred == truth).astype(jnp.float32)))

    return ev


def block_feature_dims(mdef: ModelDef) -> List[int]:
    """Static feature dims per trunk block (for meta.json)."""
    x = jnp.zeros((1,) + mdef.input_shape, jnp.float32)
    params = mdef.init(jax.random.PRNGKey(0))
    _, blocks = jax.eval_shape(lambda p, xx: mdef.trunk(p, xx), params, x)
    return [int(b.shape[1]) for b in blocks]
