"""AOT compile path: lower every model variant's functional surface to HLO
text artifacts consumed by the Rust coordinator.

Python runs ONCE, here. The interchange format is **HLO text**, not
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (what the published `xla` crate binds)
rejects (`proto.id() <= INT_MAX`). The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.

Per variant, artifacts/<variant>/ receives:

    train_step.hlo.txt        (params, x[B,D], y[B,C], w[B], lr[]) -> (params', loss)
    features_b<k>.hlo.txt     (params, x[Bf,D])                -> (feats[Bf,Fk],)
    importance.hlo.txt        (params, x[N,D], y[N,C], mask[N])-> (norms[N], K[N,N])
    eval.hlo.txt              (params, x[E,D], y[E,C])         -> (loss_sum, correct)
    init_params.bin           f32 LE initial parameters
    meta.json                 shapes/dims contract for the Rust loader
    golden.json               deterministic input/output pairs for the
                              cross-language numerics integration test

Usage: python -m compile.aot [--out-dir ../artifacts] [--variants mlp,...]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def det_input(n: int, d: int, scale: float = 1.0) -> np.ndarray:
    """Deterministic pseudo-input reproduced bit-for-bit by the Rust tests:
    x[i, j] = sin(0.1 * (i * d + j + 1)) * scale, computed in f64, cast f32.
    """
    idx = np.arange(n * d, dtype=np.float64) + 1.0
    return (np.sin(0.1 * idx) * scale).astype(np.float32).reshape(n, d)


def det_onehot(n: int, c: int) -> np.ndarray:
    y = np.zeros((n, c), dtype=np.float32)
    y[np.arange(n), np.arange(n) % c] = 1.0
    return y


def build_variant(mdef: M.ModelDef, out_dir: str) -> None:
    vdir = os.path.join(out_dir, mdef.name)
    os.makedirs(vdir, exist_ok=True)
    flat, unravel = M.init_flat(mdef, seed=0)
    p = int(flat.shape[0])
    d = mdef.input_dim
    c = mdef.num_classes
    fdims = M.block_feature_dims(mdef)

    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct

    def lower_to(fname: str, fn, *shapes) -> None:
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
        print(f"  {mdef.name}/{fname}: {len(text)} chars")

    # train_step at the default batch plus the Fig. 2(b) comparison batch
    step = M.make_train_step(mdef, unravel)
    lower_to(
        "train_step.hlo.txt", step,
        sd((p,), f32), sd((M.TRAIN_BATCH, d), f32),
        sd((M.TRAIN_BATCH, c), f32), sd((M.TRAIN_BATCH,), f32), sd((), f32),
    )
    for b in M.TRAIN_BATCHES_EXTRA:
        lower_to(
            f"train_step_b{b}.hlo.txt", step,
            sd((p,), f32), sd((b, d), f32), sd((b, c), f32),
            sd((b,), f32), sd((), f32),
        )

    # features at every trunk depth (Fig. 8 sweeps the depth)
    for k in range(1, len(fdims) + 1):
        feats = M.make_features(mdef, unravel, n_blocks=k)
        lower_to(
            f"features_b{k}.hlo.txt", feats,
            sd((p,), f32), sd((M.FILTER_CHUNK, d), f32),
        )

    # importance (contains the L1 Pallas kernels)
    imp = M.make_importance(mdef, unravel)
    lower_to(
        "importance.hlo.txt", imp,
        sd((p,), f32), sd((M.CAND_MAX, d), f32),
        sd((M.CAND_MAX, c), f32), sd((M.CAND_MAX,), f32),
    )

    # probe (per-candidate loss/entropy for the heuristic baselines)
    probe = M.make_probe(mdef, unravel)
    lower_to(
        "probe.hlo.txt", probe,
        sd((p,), f32), sd((M.CAND_MAX, d), f32),
        sd((M.CAND_MAX, c), f32), sd((M.CAND_MAX,), f32),
    )

    # eval
    ev = M.make_evaluate(mdef, unravel)
    lower_to(
        "eval.hlo.txt", ev,
        sd((p,), f32), sd((M.EVAL_CHUNK, d), f32), sd((M.EVAL_CHUNK, c), f32),
    )

    # initial parameters
    np.asarray(flat, dtype="<f4").tofile(os.path.join(vdir, "init_params.bin"))

    # contract for the Rust loader
    meta = {
        "name": mdef.name,
        "param_count": p,
        "input_dim": d,
        "input_shape": list(mdef.input_shape),
        "num_classes": c,
        "h_dim": mdef.h_dim,
        "block_dims": fdims,
        "train_batch": M.TRAIN_BATCH,
        "train_batches": [M.TRAIN_BATCH] + M.TRAIN_BATCHES_EXTRA,
        "filter_chunk": M.FILTER_CHUNK,
        "cand_max": M.CAND_MAX,
        "eval_chunk": M.EVAL_CHUNK,
    }
    with open(os.path.join(vdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)

    # golden numerics for the Rust integration test
    golden = make_golden(mdef, flat, unravel, d, c)
    with open(os.path.join(vdir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=2)


def make_golden(mdef, flat, unravel, d, c):
    """Run the exact functions being lowered on deterministic inputs and
    record outputs. The Rust side regenerates the same inputs and asserts
    allclose after executing the compiled HLO."""
    step = M.make_train_step(mdef, unravel)
    imp = M.make_importance(mdef, unravel)
    ev = M.make_evaluate(mdef, unravel)
    feats1 = M.make_features(mdef, unravel, n_blocks=1)
    probe = M.make_probe(mdef, unravel)

    xb = jnp.array(det_input(M.TRAIN_BATCH, d))
    yb = jnp.array(det_onehot(M.TRAIN_BATCH, c))
    lr = jnp.float32(0.05)
    wb = jnp.ones((M.TRAIN_BATCH,), jnp.float32)
    p1, loss = step(flat, xb, yb, wb, lr)

    xn = jnp.array(det_input(M.CAND_MAX, d))
    yn = jnp.array(det_onehot(M.CAND_MAX, c))
    mask = jnp.array((np.arange(M.CAND_MAX) < 30).astype(np.float32))
    norms, k = imp(flat, xn, yn, mask)

    xe = jnp.array(det_input(M.EVAL_CHUNK, d))
    ye = jnp.array(det_onehot(M.EVAL_CHUNK, c))
    ls, corr = ev(flat, xe, ye)

    xf = jnp.array(det_input(M.FILTER_CHUNK, d))
    (fb,) = feats1(flat, xf)

    pl, pe = probe(flat, xn, yn, mask)

    return {
        "probe_loss_head": [float(v) for v in np.asarray(pl)[:8]],
        "probe_entropy_head": [float(v) for v in np.asarray(pe)[:8]],
        "lr": 0.05,
        "mask_valid": 30,
        "loss_step0": float(loss),
        "params_l2_after_step": float(jnp.linalg.norm(p1)),
        "norms_head": [float(v) for v in np.asarray(norms)[:8]],
        "k_sum": float(jnp.sum(k)),
        "k_trace": float(jnp.trace(k)),
        "eval_loss_sum": float(ls),
        "eval_correct": float(corr),
        "feats_b1_sum": float(jnp.sum(fb)),
        "feats_b1_head": [float(v) for v in np.asarray(fb).reshape(-1)[:8]],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--variants", default=",".join(M.VARIANTS.keys()),
                    help="comma-separated subset of: " + ",".join(M.VARIANTS))
    # legacy single-file mode used by the original scaffold Makefile
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir if args.out is None else os.path.dirname(args.out) or ".")
    os.makedirs(out_dir, exist_ok=True)
    names = [v for v in args.variants.split(",") if v]
    for name in names:
        if name not in M.VARIANTS:
            sys.exit(f"unknown variant {name!r}; have {list(M.VARIANTS)}")
        print(f"[aot] lowering {name} ...")
        build_variant(M.VARIANTS[name], out_dir)
    # stamp file so `make artifacts` can be a cheap no-op
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"[aot] artifacts in {out_dir}")


if __name__ == "__main__":
    main()
