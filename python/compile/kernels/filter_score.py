"""L1 Pallas kernel: coarse-grained filter scoring (Rep + Div).

Scores a chunk of streaming samples against the per-class running
estimators maintained by the L3 coordinator:

    score(x, y) = lam * Rep(x, y) + (1 - lam) * Div(x, y)
    Rep(x, y)   = -||f - c_y||^2
    Div(x, y)   =  ||f||^2 + m2_y - 2 <f, c_y>

with c_y the class feature centroid and m2_y = E||f'||^2 the class mean
squared feature norm. The class lookup is expressed as the one-hot matmuls
`onehot @ centroids` / `onehot @ m2` so the whole scorer is a single
MXU matmul + VPU arithmetic — no gather, which keeps the TPU lowering
trivial (gathers are the classic Pallas-on-TPU footgun).

lam is a traced [1] input (not a compile-time constant) so the same AOT
artifact serves every filter configuration; lam = 0.5 reproduces the
paper's degenerate unweighted sum (see DESIGN.md §Discrepancies).

interpret=True as everywhere: CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Streaming chunks are small (<=32); a single grid step holds everything in
# VMEM: feats[B,F] + centroids[C,F] + outputs ~ a few KiB.
ROW_TILE = 32


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _score_kernel(f_ref, cen_ref, m2_ref, y_ref, lam_ref, out_ref):
    """One grid step over a row tile of the streaming chunk."""
    f = f_ref[...]
    y = y_ref[...]
    c = jax.lax.dot_general(
        y, cen_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m2 = jnp.sum(y * m2_ref[...][None, :], axis=-1)
    fn2 = jnp.sum(f * f, axis=-1)
    cn2 = jnp.sum(c * c, axis=-1)
    fc = jnp.sum(f * c, axis=-1)
    lam = lam_ref[0]
    rep = -(fn2 - 2.0 * fc + cn2)
    div = fn2 + m2 - 2.0 * fc
    out_ref[...] = lam * rep + (1.0 - lam) * div


def repdiv_score(feats, centroids, mean_norm2, onehot, lam, *, tile: int = ROW_TILE):
    """Rep+Div scores [B] for a feature chunk [B,F] against class stats.

    Args:
      feats:      [B, F] shallow-layer features of the streaming chunk.
      centroids:  [C, F] running class centroids (from L3 estimators).
      mean_norm2: [C]    running class mean squared feature norm.
      onehot:     [B, C] labels of the chunk.
      lam:        [1]    Rep weight in [0, 1].
    """
    b, f = feats.shape
    c = centroids.shape[0]
    t = min(tile, b)
    return pl.pallas_call(
        _score_kernel,
        grid=(_ceil_div(b, t),),
        in_specs=[
            pl.BlockSpec((t, f), lambda i: (i, 0)),
            pl.BlockSpec((c, f), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((t, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(
        feats.astype(jnp.float32),
        centroids.astype(jnp.float32),
        mean_norm2.astype(jnp.float32),
        onehot.astype(jnp.float32),
        lam.astype(jnp.float32),
    )
