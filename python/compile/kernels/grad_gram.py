"""L1 Pallas kernels: fused per-sample gradient norms + gradient Gram matrix.

This is the selection hot-spot of Titan's fine-grained stage. Given the
penultimate features `h` and logits `z` of the N candidate samples, the
coordinator needs

    norms[i]  = ||g_i||                    (intra-class sampling, Eq. 3)
    K[i, j]   = <g_i, g_j>                 (class importance, Eq. 2; Fig. 5)

where g_i is the last-layer (W, b) gradient of softmax cross-entropy. The
factorization <g_i, g_j> = (d_i . d_j) * (1 + h_i . h_j) with
d = softmax(z) - y turns the whole computation into two MXU-shaped matmuls
and a VPU elementwise combine — no per-sample backprop anywhere.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the Gram kernel runs a
2-D grid over K output tiles. Each grid step loads a row block and a column
block of (delta | h) into VMEM via BlockSpec and performs

    K_tile = (Dr @ Dc^T) * (1 + Hr @ Hc^T)

which is the TPU analogue of the "one threadblock per output tile" GPU
schedule. Delta is computed once (row-tiled pass 1) instead of being
recomputed per Gram tile: at N=100 the recompute would be cheap, but the
two-pass structure keeps each kernel's VMEM footprint independent of N.

Kernels are lowered with interpret=True everywhere in this repo: the CPU
PJRT plugin cannot execute Mosaic custom-calls. BlockSpecs are still real,
so the HBM<->VMEM schedule is exercised by the interpreter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row/column tile for the Gram grid. 64 keeps the five VMEM-resident tiles
# (Dr, Hr, Dc, Hc, K_tile) under ~200 KiB at F<=128 while staying
# MXU-friendly (>= 8x128 lanes after padding).
TILE = 64


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _delta_norm_kernel(z_ref, y_ref, mask_ref, h_ref, d_ref, hn2_ref):
    """Pass-1 grid step over row tiles: stabilized softmax -> masked delta.

    Also emits ||h_i||^2 so `grad_gram` can form the norms without touching
    diag(K) (no diagonal special case in pass 2).
    """
    z = z_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    d_ref[...] = (p - y_ref[...]) * mask_ref[...][:, None]
    h = h_ref[...]
    hn2_ref[...] = jnp.sum(h * h, axis=-1)


def _gram_kernel(d_ref, h_ref, dt_ref, ht_ref, k_ref):
    """Pass-2 grid step (i, j): one TILE x TILE output tile of K.

    d_ref/h_ref are the row blocks (grid index i), dt_ref/ht_ref the column
    blocks (grid index j). Two matmuls on the MXU, one VPU combine.
    """
    dd = jax.lax.dot_general(
        d_ref[...], dt_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    hh = jax.lax.dot_general(
        h_ref[...], ht_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    k_ref[...] = dd * (1.0 + hh)


def delta_and_hnorm2(logits, onehot, h, mask, *, tile: int = TILE):
    """Pallas pass 1: masked delta [N,C] and feature norms^2 [N]."""
    n, c = logits.shape
    f = h.shape[1]
    t = min(tile, n)
    return pl.pallas_call(
        _delta_norm_kernel,
        grid=(_ceil_div(n, t),),
        in_specs=[
            pl.BlockSpec((t, c), lambda i: (i, 0)),
            pl.BlockSpec((t, c), lambda i: (i, 0)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t, f), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, c), lambda i: (i, 0)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, c), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(
        logits.astype(jnp.float32),
        onehot.astype(jnp.float32),
        mask.astype(jnp.float32),
        h.astype(jnp.float32),
    )


def gram(delta, h, *, tile: int = TILE):
    """Pallas pass 2: K = (D D^T) * (1 + H H^T), tiled (tile x tile)."""
    n, c = delta.shape
    f = h.shape[1]
    t = min(tile, n)
    hf = h.astype(jnp.float32)
    return pl.pallas_call(
        _gram_kernel,
        grid=(_ceil_div(n, t), _ceil_div(n, t)),
        in_specs=[
            pl.BlockSpec((t, c), lambda i, j: (i, 0)),
            pl.BlockSpec((t, f), lambda i, j: (i, 0)),
            pl.BlockSpec((t, c), lambda i, j: (j, 0)),
            pl.BlockSpec((t, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(delta, hf, delta, hf)


def grad_gram(logits, onehot, h, mask, *, tile: int = TILE):
    """Fused entry point used by L2's `importance` graph: (norms[N], K[N,N]).

    norms come from the pass-1 outputs via ||d_i||^2 * (1 + ||h_i||^2); they
    agree with sqrt(diag K) to f32 rounding (pinned by tests).
    """
    delta, hn2 = delta_and_hnorm2(logits, onehot, h, mask, tile=tile)
    dn2 = jnp.sum(delta * delta, axis=-1)
    norms = jnp.sqrt(dn2 * (1.0 + hn2))
    return norms, gram(delta, h, tile=tile)
