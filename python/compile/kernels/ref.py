"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything the kernels compute is defined here in the most direct (and
slowest) jnp form. The pytest suite asserts `assert_allclose(kernel, ref)`
across shape/dtype sweeps — this file is the correctness ground truth.

Math recap (see DESIGN.md "Key math"): for softmax cross-entropy with
penultimate features h_i and one-hot labels y_i, the last-layer gradient of
sample i is g_i = [delta_i (x) h_i ; delta_i] with delta_i = p_i - y_i, so

    <g_i, g_j> = (delta_i . delta_j) * (1 + h_i . h_j)     (Gram matrix K)
    ||g_i||^2  = ||delta_i||^2 * (1 + ||h_i||^2)           (norms = sqrt diag K)
"""

from __future__ import annotations

import jax.numpy as jnp


def softmax(logits: jnp.ndarray) -> jnp.ndarray:
    """Row-wise, numerically stabilized softmax."""
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def delta_ref(logits: jnp.ndarray, onehot: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked softmax-CE logit gradient: (softmax(z) - y) * mask[:, None].

    Masked-out rows (mask == 0) produce an all-zero delta row, which zeroes
    the corresponding K rows/columns and norms downstream.
    """
    return (softmax(logits) - onehot) * mask[:, None]


def grad_norms_ref(
    logits: jnp.ndarray, onehot: jnp.ndarray, h: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Per-sample last-layer gradient norms ||g_i|| (weight + bias terms)."""
    d = delta_ref(logits, onehot, mask)
    dn2 = jnp.sum(d * d, axis=-1)
    hn2 = jnp.sum(h * h, axis=-1)
    return jnp.sqrt(dn2 * (1.0 + hn2))


def gram_ref(
    logits: jnp.ndarray, onehot: jnp.ndarray, h: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Pairwise gradient Gram matrix K[i,j] = <g_i, g_j>."""
    d = delta_ref(logits, onehot, mask)
    return (d @ d.T) * (1.0 + h @ h.T)


def grad_gram_ref(logits, onehot, h, mask):
    """(norms, K) exactly as the fused kernel pipeline returns them.

    norms are taken from sqrt(diag K) so the two outputs are always
    mutually consistent (same rounding path as the kernel contract).
    """
    k = gram_ref(logits, onehot, h, mask)
    return jnp.sqrt(jnp.maximum(jnp.diagonal(k), 0.0)), k


def repdiv_ref(
    feats: jnp.ndarray,
    centroids: jnp.ndarray,
    mean_norm2: jnp.ndarray,
    onehot: jnp.ndarray,
    lam: jnp.ndarray,
) -> jnp.ndarray:
    """Coarse-filter score: lam * Rep + (1 - lam) * Div, per sample.

    Rep(x,y) = -||f - c_y||^2
    Div(x,y) =  ||f||^2 + E||f'||^2 - 2 <f, c_y>

    NOTE the paper's unweighted sum (lam = 0.5, up to scale) collapses to a
    per-class constant (E||f'||^2 - ||c_y||^2) / 2 — see DESIGN.md
    §Discrepancies. A unit test pins this cancellation.
    """
    c = onehot @ centroids  # [B, F] class centroid per sample
    m2 = onehot @ mean_norm2  # [B]   class mean feature norm^2
    fn2 = jnp.sum(feats * feats, axis=-1)
    cn2 = jnp.sum(c * c, axis=-1)
    fc = jnp.sum(feats * c, axis=-1)
    rep = -(fn2 - 2.0 * fc + cn2)
    div = fn2 + m2 - 2.0 * fc
    return lam * rep + (1.0 - lam) * div
