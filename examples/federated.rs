//! Federated-learning scenario (paper Appendix B, Fig. 10): 50 devices
//! with non-IID streams (5 classes each), 20% participation, 3 local
//! iterations, FedAvg — with per-device data selection.
//!
//! ```sh
//! cargo run --release --example federated [comm_rounds]
//! ```

use titan::config::{presets, Method};
use titan::coordinator::session::observers::ProgressLog;
use titan::fl::{FlBuilder, FlConfig};
use titan::metrics::render_table;
use titan::util::logging;

fn main() -> titan::Result<()> {
    logging::init();
    let comm_rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    let mut rows = Vec::new();
    let mut rs_target = 0.0f64;
    let mut rs_rounds: Option<usize> = None;
    for method in [Method::Rs, Method::Cis] {
        let mut base = presets::table1("mlp", method);
        base.pipeline = false;
        base.eval_every = 5;
        base.test_size = 600;
        let mut cfg = FlConfig::paper_default(base);
        cfg.comm_rounds = comm_rounds;
        // builder-driven FL: per-device DataSources + comm-round observers
        let rec = FlBuilder::new(cfg).observe(ProgressLog::every(5)).run()?;
        if method == Method::Rs {
            rs_target = rec.final_accuracy;
            rs_rounds = rec.rounds_to_accuracy(rs_target);
        }
        let to_target = rec.rounds_to_accuracy(rs_target);
        let speedup = match (rs_rounds, to_target) {
            (Some(a), Some(b)) if b > 0 => format!("{:.2}x", a as f64 / b as f64),
            _ => "-".into(),
        };
        rows.push(vec![
            method.name().to_string(),
            format!("{:.1}", rec.final_accuracy * 100.0),
            to_target.map(|r| r.to_string()).unwrap_or("-".into()),
            speedup,
        ]);
    }
    println!("\nfederated (50 devices, non-IID, {comm_rounds} comm rounds):\n");
    println!(
        "{}",
        render_table(
            &["selection", "final_acc_%", "rounds_to_RS_acc", "speedup"],
            &rows
        )
    );
    println!("paper shape: C-IS selection converges ~3x faster, +2% accuracy.");
    Ok(())
}
