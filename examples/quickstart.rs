//! Quickstart — the end-to-end driver: train the HAR MLP on a synthetic
//! data stream with full Titan (coarse filter + C-IS + pipeline), compare
//! against random selection, and print both loss curves.
//!
//! This is the EXPERIMENTS.md §End-to-end run:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use titan::config::{presets, Method};
use titan::coordinator::SessionBuilder;
use titan::util::logging;

fn main() -> titan::Result<()> {
    logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    println!("== Titan quickstart: HAR MLP, {rounds} rounds, stream 100/round ==\n");

    // Baseline: random selection, sequential (how the paper deploys RS).
    let mut rs_cfg = presets::table1("mlp", Method::Rs);
    rs_cfg.rounds = rounds;
    rs_cfg.eval_every = (rounds / 15).max(5);
    let (rs, _) = SessionBuilder::new(rs_cfg.clone()).sequential().run()?;

    // Titan: coarse filter -> C-IS -> pipelined co-execution.
    let mut ti_cfg = presets::table1("mlp", Method::Titan);
    ti_cfg.rounds = rounds;
    ti_cfg.eval_every = rs_cfg.eval_every;
    let (ti, _) = SessionBuilder::new(ti_cfg).run()?; // cfg.pipeline picks the backend

    println!("loss/accuracy curves (test set):");
    println!(
        "{:>6} | {:>10} {:>8} | {:>10} {:>8}",
        "round", "RS loss", "RS acc", "Titan loss", "T acc"
    );
    for (a, b) in rs.curve.iter().zip(ti.curve.iter()) {
        println!(
            "{:>6} | {:>10.4} {:>7.2}% | {:>10.4} {:>7.2}%",
            a.round,
            a.test_loss,
            a.test_accuracy * 100.0,
            b.test_loss,
            b.test_accuracy * 100.0
        );
    }

    let target = rs.final_accuracy * 0.98; // see exp::TARGET_FRAC
    let rs_t = rs.time_to_accuracy_device(target).unwrap_or(rs.total_device_ms);
    let ti_t = ti.time_to_accuracy_device(target).unwrap_or(ti.total_device_ms);
    println!("\nsummary:");
    println!(
        "  RS    final acc {:.2}%  device time {:.1}s  energy {:.0} J",
        rs.final_accuracy * 100.0,
        rs.total_device_ms / 1e3,
        rs.energy_j
    );
    println!(
        "  Titan final acc {:.2}%  device time {:.1}s  energy {:.0} J",
        ti.final_accuracy * 100.0,
        ti.total_device_ms / 1e3,
        ti.energy_j
    );
    println!(
        "  time-to-RS-accuracy: Titan/RS = {:.2}x  (paper: 0.57-0.77x)",
        ti_t / rs_t.max(1e-9)
    );
    println!(
        "  per-sample processing delay: {:.3} ms host ({} samples)",
        ti.processing_delay.mean_ms(),
        ti.processing_delay.count()
    );
    Ok(())
}
