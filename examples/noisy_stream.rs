//! Noisy-stream scenario (paper Fig. 11): train under feature noise
//! (Gaussian on 40% of inputs) and label noise (40% of labels flipped),
//! comparing Titan against RS and IS. Titan should win both, and suffer
//! more from label noise than feature noise.
//!
//! ```sh
//! cargo run --release --example noisy_stream [rounds]
//! ```

use titan::config::{presets, Method};
use titan::coordinator::SessionBuilder;
use titan::metrics::render_table;
use titan::util::logging;

fn main() -> titan::Result<()> {
    logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);

    let mut rows = Vec::new();
    for (noise_name, label_noise) in [("feature(40%)", false), ("label(40%)", true)] {
        for method in [Method::Rs, Method::Is, Method::Titan] {
            let mut cfg = presets::noisy("mlp", method, label_noise);
            cfg.rounds = rounds;
            cfg.eval_every = (rounds / 8).max(5);
            // the session backend follows the preset's pipeline flag
            let (record, _) = SessionBuilder::new(cfg).run()?;
            rows.push(vec![
                noise_name.to_string(),
                method.name().to_string(),
                format!("{:.1}", record.final_accuracy * 100.0),
                format!("{:.1}s", record.total_device_ms / 1e3),
            ]);
        }
    }
    println!("\nnoisy streams (HAR MLP, {rounds} rounds):\n");
    println!(
        "{}",
        render_table(&["noise", "method", "final_acc_%", "device_time"], &rows)
    );
    println!("paper shape: Titan leads both settings; label noise hurts more.");
    Ok(())
}
