//! Fleet — many concurrent device sessions multiplexed on one host.
//!
//! Three sessions (Titan / RS / C-IS, one with a drifting class mix)
//! interleave round-by-round on the host scheduler under the
//! fewest-rounds-first policy; the per-session records are identical to
//! running each session alone.
//!
//! The fleet is **crash-safe**: every member checkpoints to
//! `results/fleet_example/` every 5 rounds, so killing the example
//! mid-run (Ctrl-C) and re-running it resumes each member at its own
//! saved round instead of restarting from 0. Members that already
//! finished are skipped; delete the directory for a fresh start.
//!
//! ```sh
//! make artifacts && cargo run --release --example fleet [rounds]
//! ```

use titan::config::{presets, Method};
use titan::coordinator::host::{FewestRoundsFirst, FleetBuilder, FleetProgress};
use titan::coordinator::SessionBuilder;
use titan::data::DriftSource;
use titan::data::SynthTask;
use titan::metrics::render_table;
use titan::util::logging;

fn main() -> titan::Result<()> {
    logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    println!("== Titan fleet: 3 sessions x {rounds} rounds, fewest-rounds-first ==\n");

    // per-member checkpoints: kill + re-run resumes each member at its
    // own saved round (delete the directory for a fresh start)
    let ck_dir = std::path::Path::new("results/fleet_example");
    std::fs::create_dir_all(ck_dir)?;

    let mut fleet = FleetBuilder::new()
        .policy(FewestRoundsFirst::new())
        .observe(FleetProgress::every(10));
    for (i, method) in [Method::Titan, Method::Rs, Method::Cis].into_iter().enumerate() {
        let mut cfg = presets::table1("mlp", method);
        cfg.rounds = rounds;
        cfg.eval_every = (rounds / 4).max(2);
        cfg.test_size = 400;
        cfg.pipeline = false; // the scheduler owns the interleaving
        cfg.seed = cfg.seed.wrapping_add(i as u64);
        let mut builder = SessionBuilder::new(cfg.clone());
        if i == 2 {
            // one continual-learning session: uniform mix drifting to a
            // skewed one over the first half of the run
            let task = SynthTask::for_model(&cfg.model, cfg.seed);
            let c = task.num_classes();
            let end: Vec<f64> = (0..c).map(|y| if y < c / 2 { 3.0 } else { 0.25 }).collect();
            let drift = DriftSource::new(task, vec![1.0; c], end, (rounds / 2).max(1), cfg.seed)?;
            builder = builder.source(drift);
        }
        let name = format!("dev{i}-{}", method.name());
        fleet = fleet.session_checkpointed(
            name.clone(),
            builder,
            ck_dir.join(format!("{name}.json")),
            5,
            true,
        )?;
    }
    if fleet.is_empty() {
        println!("all sessions already complete — delete results/fleet_example to re-run");
        return Ok(());
    }

    let record = fleet.run()?;
    let rows: Vec<Vec<String>> = record
        .names
        .iter()
        .zip(&record.records)
        .zip(&record.session_rounds)
        .map(|((name, rec), &r)| {
            vec![
                name.clone(),
                r.to_string(),
                format!("{:.2}", rec.final_accuracy * 100.0),
                format!("{:.1}", rec.total_device_ms / 1e3),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(&["session", "rounds", "final_acc_%", "device_s"], &rows)
    );
    println!(
        "policy {}: {} interleaved rounds, scheduler overhead {:.3} ms/round, host {:.1}s",
        record.policy,
        record.rounds_executed,
        record.sched_overhead_per_round_ms(),
        record.total_host_ms / 1e3
    );
    Ok(())
}
