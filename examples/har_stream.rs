//! HAR streaming scenario — the paper's Table-1 HAR row in miniature:
//! runs every selection method on the human-activity-recognition task
//! (900-dim IMU windows, 6 classes, MLP) and prints a Table-1-style row
//! set: normalized time-to-accuracy + final accuracy per method.
//!
//! ```sh
//! cargo run --release --example har_stream [rounds]
//! ```

use titan::config::{presets, Method};
use titan::coordinator::SessionBuilder;
use titan::metrics::render_table;
use titan::util::logging;

fn main() -> titan::Result<()> {
    logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let methods = [
        Method::Rs,
        Method::Is,
        Method::Ll,
        Method::Hl,
        Method::Ce,
        Method::Ocs,
        Method::Camel,
        Method::Titan,
    ];

    // RS defines the target + normalizer
    let mut rs_cfg = presets::table1("mlp", Method::Rs);
    rs_cfg.rounds = rounds;
    rs_cfg.eval_every = (rounds / 10).max(5);
    let (rs, _) = SessionBuilder::new(rs_cfg.clone()).sequential().run()?;
    let target = rs.final_accuracy * 0.98; // see exp::TARGET_FRAC
    let rs_time = rs.time_to_accuracy_device(target).unwrap_or(rs.total_device_ms);

    let mut rows = Vec::new();
    for method in methods {
        let record = if method == Method::Rs {
            rs.clone()
        } else {
            let mut cfg = presets::table1("mlp", method);
            cfg.rounds = rounds;
            cfg.eval_every = rs_cfg.eval_every;
            // the session backend follows the preset's pipeline flag
            SessionBuilder::new(cfg).run()?.0
        };
        let (tta, reached) = match record.time_to_accuracy_device(target) {
            Some(t) => (t, true),
            None => (record.total_device_ms, false),
        };
        rows.push(vec![
            method.name().to_string(),
            format!("{}{:.2}", if reached { "" } else { ">" }, tta / rs_time),
            format!("{:.1}", record.final_accuracy * 100.0),
        ]);
    }

    println!("\nHAR (MLP, 6 classes) — target accuracy {:.1}%:\n", target * 100.0);
    println!(
        "{}",
        render_table(&["method", "norm_time_to_acc", "final_acc_%"], &rows)
    );
    println!("paper shape: Titan ~0.71x and top-tier accuracy; IS/HDS/CS >1x.");
    Ok(())
}
