//! Example: long-horizon drift under a storage budget — the three
//! retention policies compared on the same drifting stream.
//!
//! A `DriftSource` shifts the class mix over the first half of the run,
//! so by the late rounds the *stream* underrepresents the early classes;
//! a byte-budgeted store decides which already-seen samples stay
//! available for replay. The example runs Titan four times — unbudgeted,
//! then once per `RetentionPolicy` — with a `RoundObserver` collecting
//! both the accuracy curve (`on_eval`) and the store telemetry
//! (`on_retention`), and prints the curves side by side.
//!
//! Run: `cargo run --release --example retention`

use std::sync::{Arc, Mutex};

use titan::config::{presets, Method};
use titan::coordinator::session::{Control, RoundObserver};
use titan::coordinator::SessionBuilder;
use titan::data::{DriftSource, SynthTask};
use titan::metrics::CurvePoint;
use titan::retention::{RetentionKind, RetentionTelemetry};
use titan::util::logging;

/// Collects the eval curve and the last retention telemetry via the
/// observer hooks (the record carries both too — the point here is to
/// exercise the hooks the way a monitoring integration would).
#[derive(Clone, Default)]
struct Tap {
    curve: Arc<Mutex<Vec<CurvePoint>>>,
    telemetry: Arc<Mutex<Option<RetentionTelemetry>>>,
}

impl RoundObserver for Tap {
    fn on_eval(&mut self, point: &CurvePoint) -> Control {
        self.curve.lock().unwrap().push(*point);
        Control::Continue
    }
    fn on_retention(&mut self, _round: usize, telemetry: &RetentionTelemetry) -> Control {
        *self.telemetry.lock().unwrap() = Some(telemetry.clone());
        Control::Continue
    }
}

fn drift_source(seed: u64, rounds: usize) -> titan::Result<DriftSource> {
    let task = SynthTask::for_model("mlp", seed);
    let c = task.num_classes();
    // uniform start, heavily skewed end: late rounds nearly stop
    // streaming the even classes — only retention keeps them trainable
    let start = vec![1.0; c];
    let end: Vec<f64> = (0..c).map(|y| if y % 2 == 0 { 0.05 } else { 3.0 }).collect();
    DriftSource::new(task, start, end, (rounds / 2).max(1), seed ^ 0xD21F7)
}

fn run_one(budget: usize, kind: RetentionKind) -> titan::Result<(String, Tap, f64)> {
    let mut cfg = presets::table1("mlp", Method::Titan);
    cfg.rounds = 40;
    cfg.eval_every = 5;
    cfg.test_size = 400;
    cfg.store_bytes = budget;
    cfg.retention = kind;
    cfg.replay_mix = 0.3;
    cfg.validate()?;
    let tap = Tap::default();
    let (record, _) = SessionBuilder::new(cfg.clone())
        .sequential()
        .source(drift_source(cfg.seed, cfg.rounds)?)
        .observe(tap.clone())
        .run()?;
    let label = if budget == 0 { "none".to_string() } else { kind.name().to_string() };
    Ok((label, tap, record.final_accuracy))
}

fn main() -> titan::Result<()> {
    logging::init();
    let runs = [
        (0, RetentionKind::Score),
        (1 << 16, RetentionKind::Score),
        (1 << 16, RetentionKind::Balanced),
        (1 << 16, RetentionKind::Reservoir),
    ];
    let mut results = Vec::new();
    for &(budget, kind) in &runs {
        let r = run_one(budget, kind)?;
        println!("policy {:<10} final_acc {:.2}%", r.0, r.2 * 100.0);
        results.push(r);
    }

    println!("\naccuracy under drift (64 KiB budget, replay mix 0.3):");
    print!("{:>8}", "round");
    for (label, _, _) in &results {
        print!("  {label:>10}");
    }
    println!();
    let n = results[0].1.curve.lock().unwrap().len();
    for i in 0..n {
        print!("{:>8}", results[0].1.curve.lock().unwrap()[i].round);
        for (_, tap, _) in &results {
            let curve = tap.curve.lock().unwrap();
            match curve.get(i) {
                Some(p) => print!("  {:>9.2}%", p.test_accuracy * 100.0),
                None => print!("  {:>10}", "-"),
            }
        }
        println!();
    }

    println!("\nstore telemetry (from the on_retention hook):");
    for (label, tap, _) in &results {
        match tap.telemetry.lock().unwrap().as_ref() {
            Some(t) => println!(
                "  {label:<10} offers {:>6}  admits {:>5}  evicts {:>5}  bytes {:>6}  hit_rate {:.3}",
                t.offers,
                t.admits,
                t.evicts_total(),
                t.bytes_held,
                t.hit_rate()
            ),
            None => println!("  {label:<10} (no store — unbudgeted baseline)"),
        }
    }
    Ok(())
}
