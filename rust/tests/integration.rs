//! Cross-module integration tests: full training runs through the real
//! PJRT artifacts, pipeline-vs-sequential equivalences, and end-to-end
//! learning signals for Titan vs baselines — all driven through the
//! session API (`SessionBuilder`), with one pin on the deprecated shims.
//!
//! These tests need `make artifacts`; they skip (with a note) otherwise so
//! `cargo test` stays green on a fresh checkout.

use titan::config::{presets, Method, NoiseKind, RunConfig};
use titan::coordinator::host::{parse_policy, FleetBuilder};
use titan::coordinator::session::observers::EarlyStop;
use titan::coordinator::{Session, SessionBuilder, SessionStatus, StepEvent};
use titan::coordinator::shard_of;
use titan::data::{DataSource, DriftSource, ReplaySource, Sample, StreamSource, SynthTask};
use titan::device::idle::IdleTrace;
use titan::fault::{FaultKind, FaultPlan, SupervisionPolicy};
use titan::metrics::RunRecord;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/mlp/meta.json").exists();
    if !ok {
        eprintln!("skipping integration test: run `make artifacts` first");
    }
    ok
}

fn base(method: Method, rounds: usize) -> RunConfig {
    let mut c = presets::table1("mlp", method);
    c.rounds = rounds;
    c.test_size = 200;
    c.eval_every = (rounds / 4).max(2);
    c
}

fn run_pipelined(cfg: &RunConfig) -> (titan::metrics::RunRecord, Vec<titan::coordinator::RoundOutcome>) {
    SessionBuilder::new(cfg.clone())
        .pipelined(IdleTrace::Constant(1.0))
        .run()
        .unwrap()
}

fn run_sequential(cfg: &RunConfig) -> (titan::metrics::RunRecord, Vec<titan::coordinator::RoundOutcome>) {
    SessionBuilder::new(cfg.clone()).sequential().run().unwrap()
}

#[test]
fn titan_end_to_end_learns() {
    if !have_artifacts() {
        return;
    }
    let cfg = base(Method::Titan, 40);
    let (record, outcomes) = run_pipelined(&cfg);
    assert_eq!(outcomes.len(), 40);
    // learning signal: accuracy above chance (1/6) by the end
    assert!(
        record.final_accuracy > 1.0 / 6.0 + 0.05,
        "no learning: {:.3}",
        record.final_accuracy
    );
    // accuracy does not regress from the first checkpoint (Titan converges
    // near-plateau within ~10 rounds on this task, so strict monotone loss
    // is noise — accuracy stability is the meaningful invariant)
    let first = record.curve.first().unwrap().test_accuracy;
    assert!(
        record.best_accuracy() >= first - 0.02,
        "accuracy regressed: {first} -> {}",
        record.best_accuracy()
    );
    // filter really capped candidates
    assert!(outcomes.iter().all(|o| o.selector.candidates <= cfg.candidate_size));
    // processing delay was recorded for every round
    assert_eq!(record.processing_delay.count(), 40);
}

#[test]
fn all_methods_complete_short_runs() {
    if !have_artifacts() {
        return;
    }
    for method in Method::ALL {
        let mut cfg = base(method, 5);
        cfg.pipeline = false;
        let (record, outcomes) = run_sequential(&cfg);
        assert_eq!(outcomes.len(), 5, "{method:?}");
        assert!(record.final_accuracy.is_finite(), "{method:?}");
        assert!(
            outcomes.iter().all(|o| o.train_loss.is_finite()),
            "{method:?}"
        );
    }
}

#[test]
fn all_methods_complete_pipelined_runs() {
    // pipelining is method-agnostic under the session API: every method
    // must also complete through the selector thread
    if !have_artifacts() {
        return;
    }
    for method in Method::ALL {
        let cfg = base(method, 4);
        let (record, outcomes) = run_pipelined(&cfg);
        assert_eq!(outcomes.len(), 4, "{method:?}");
        assert!(record.final_accuracy.is_finite(), "{method:?}");
        for o in &outcomes {
            // lanes overlap on the device clock
            assert!(
                o.device_wall_ms >= o.device_cpu_ms.max(o.device_gpu_ms) - 1e-9,
                "{method:?}"
            );
        }
    }
}

#[test]
fn pipeline_and_sequential_agree_on_device_lane_costs() {
    if !have_artifacts() {
        return;
    }
    // same seed => same selection decisions => same per-lane device costs;
    // only the wall aggregation (max vs sum) differs. The pipelined run
    // syncs params with one-round delay, so train losses differ — but the
    // GPU lane ops of round 0 (selection under init params) must match.
    let cfg = base(Method::Titan, 3);
    let (_, pipe) = run_pipelined(&cfg);
    let mut seq_cfg = cfg.clone();
    seq_cfg.pipeline = false;
    let (_, seq) = run_sequential(&seq_cfg);
    assert_eq!(pipe[0].selector.candidates, seq[0].selector.candidates);
    assert_eq!(pipe[0].selector.arrivals, seq[0].selector.arrivals);
    for (p, s) in pipe.iter().zip(seq.iter()) {
        assert!(p.device_wall_ms <= s.device_wall_ms + 1e-9,
            "pipelined round must not be slower on the device clock");
    }
}

#[test]
fn deprecated_shims_match_session_runs() {
    // the kept shims must be byte-equivalent to the session API they wrap
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(Method::Rs, 6);
    cfg.pipeline = false;
    #[allow(deprecated)]
    let (shim, _) = titan::coordinator::sequential::run(&cfg).unwrap();
    let (sess, _) = run_sequential(&cfg);
    assert_eq!(shim.final_accuracy, sess.final_accuracy);
    let a: Vec<f64> = shim.curve.iter().map(|p| p.test_accuracy).collect();
    let b: Vec<f64> = sess.curve.iter().map(|p| p.test_accuracy).collect();
    assert_eq!(a, b);

    let ti = base(Method::Titan, 4);
    #[allow(deprecated)]
    let (shim, _) = titan::coordinator::pipeline::run(&ti).unwrap();
    let (sess, _) = run_pipelined(&ti);
    assert_eq!(shim.final_accuracy, sess.final_accuracy);
}

#[test]
fn titan_early_convergence_advantage() {
    if !have_artifacts() {
        return;
    }
    // The paper's Table-1 effect in its most robust form: after the same
    // small number of rounds, Titan's selected batches have moved the
    // model further than random selection (the full plateau-crossing
    // comparison is measured by `exp table1`, not asserted here — it is
    // seed/eval-grid sensitive at short budgets).
    let mut rs_cfg = base(Method::Rs, 30);
    rs_cfg.eval_every = 10;
    let mut ti_cfg = base(Method::Titan, 30);
    ti_cfg.eval_every = 10;
    let (rs, _) = run_sequential(&rs_cfg);
    let (ti, _) = run_pipelined(&ti_cfg);
    // compare the best of the first two checkpoints: a single round-10
    // eval point carries ±0.04 seed noise on the synthetic task
    let early = |r: &titan::metrics::RunRecord| {
        r.curve
            .iter()
            .take(2)
            .map(|p| p.test_accuracy)
            .fold(0.0f64, f64::max)
    };
    let rs_early = early(&rs);
    let ti_early = early(&ti);
    assert!(
        ti_early >= rs_early - 0.05,
        "titan early accuracy {ti_early:.3} well below rs {rs_early:.3}"
    );
    // and Titan's per-round device cost must not exceed RS-sequential's
    // by more than the sync overhead (the pipeline hides selection)
    let rs_round = rs.total_device_ms / 30.0;
    let ti_round = ti.total_device_ms / 30.0;
    assert!(
        ti_round <= rs_round * 1.15,
        "titan round {ti_round:.0}ms vs rs {rs_round:.0}ms"
    );
}

#[test]
fn noisy_streams_complete_and_learn() {
    if !have_artifacts() {
        return;
    }
    for noise in [
        NoiseKind::Feature { frac: 0.4, sigma: 1.0 },
        NoiseKind::Label { frac: 0.4 },
    ] {
        let mut cfg = base(Method::Titan, 25);
        cfg.noise = noise;
        let (record, _) = run_pipelined(&cfg);
        assert!(record.final_accuracy > 1.0 / 6.0 - 0.02, "{noise:?}");
    }
}

#[test]
fn idle_budget_trace_respected_through_pipeline() {
    if !have_artifacts() {
        return;
    }
    let cfg = base(Method::Titan, 8);
    let trace = IdleTrace::Sine { min: 0.2, max: 1.0, period: 4.0 };
    let budgets: Vec<usize> = (0..8).map(|r| trace.candidate_budget(r, 30)).collect();
    let (_, outcomes) = SessionBuilder::new(cfg)
        .pipelined(trace)
        .run()
        .unwrap();
    for (o, &b) in outcomes.iter().zip(&budgets) {
        assert!(
            o.selector.candidates <= b,
            "round {}: {} > budget {b}",
            o.round,
            o.selector.candidates
        );
    }
}

#[test]
fn replay_source_with_early_stop_session() {
    // a non-default DataSource + observer through the full stack: Titan
    // training from a replayed pool, stopped at the first checkpoint that
    // clears chance accuracy
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(Method::Titan, 30);
    cfg.eval_every = 5;
    let task = SynthTask::for_model(&cfg.model, cfg.seed);
    let mut stream = StreamSource::new(task, cfg.seed, cfg.noise);
    let replay = ReplaySource::capture(&mut stream, 400).unwrap();
    assert_eq!(replay.task().num_classes(), 6);
    let (record, outcomes) = SessionBuilder::new(cfg)
        .pipelined(IdleTrace::Constant(1.0))
        .source(replay)
        .observe(EarlyStop::at_accuracy(1.0 / 6.0))
        .run()
        .unwrap();
    assert!(!outcomes.is_empty());
    assert!(outcomes.len() <= 30);
    assert!(record.final_accuracy.is_finite());
}

/// Deterministic RunRecord fields (everything off the host wall clock).
fn assert_records_equivalent(a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.method, b.method);
    assert_eq!(a.model, b.model);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.total_device_ms, b.total_device_ms);
    assert_eq!(a.energy_j, b.energy_j);
    assert_eq!(a.avg_power_w, b.avg_power_w);
    assert_eq!(a.peak_memory_bytes, b.peak_memory_bytes);
    assert_eq!(a.round_device_ms, b.round_device_ms);
    assert_eq!(a.curve.len(), b.curve.len());
    for (x, y) in a.curve.iter().zip(&b.curve) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.device_ms, y.device_ms);
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.test_loss, y.test_loss);
        assert_eq!(x.test_accuracy, y.test_accuracy);
    }
}

/// Three heterogeneous fleet members: different methods, round budgets
/// and data sources (stream / drift / replay), all sequential
/// (deterministic under interleaving). Returned as a builder so the
/// resume tests can attach checkpoint observers / snapshots before
/// building.
fn fleet_member_builder(i: usize) -> SessionBuilder {
    let (method, rounds) = [(Method::Titan, 6), (Method::Rs, 4), (Method::Cis, 5)][i];
    let mut cfg = base(method, rounds);
    cfg.pipeline = false;
    cfg.eval_every = 2;
    cfg.seed += i as u64;
    let builder = SessionBuilder::new(cfg.clone()).sequential();
    match i {
        1 => {
            let task = SynthTask::for_model(&cfg.model, cfg.seed);
            let end: Vec<f64> = (0..6).map(|y| if y < 3 { 3.0 } else { 0.25 }).collect();
            builder.source(DriftSource::new(task, vec![1.0; 6], end, 2, cfg.seed).unwrap())
        }
        2 => {
            let mut stream = StreamSource::new(
                SynthTask::for_model(&cfg.model, cfg.seed),
                cfg.seed,
                cfg.noise,
            );
            builder.source(ReplaySource::capture(&mut stream, 300).unwrap())
        }
        _ => builder,
    }
}

fn fleet_member(i: usize) -> Session {
    fleet_member_builder(i).build().unwrap()
}

/// The ISSUE's fleet determinism pin: under every scheduling policy,
/// each session's final record in a 3-session fleet is identical to the
/// record produced by running that session alone.
#[test]
fn fleet_sessions_match_solo_runs_under_every_policy() {
    if !have_artifacts() {
        return;
    }
    let solo: Vec<RunRecord> = (0..3).map(|i| fleet_member(i).run().unwrap().0).collect();
    for policy in ["rr", "fewest", "staleness"] {
        let mut fleet = FleetBuilder::new().policy_boxed(parse_policy(policy).unwrap());
        for i in 0..3 {
            fleet = fleet.session(format!("s{i}"), fleet_member_builder(i));
        }
        let record = fleet.run().unwrap();
        assert_eq!(record.records.len(), 3, "{policy}");
        assert_eq!(record.session_rounds, vec![6, 4, 5], "{policy}");
        assert_eq!(record.rounds_executed, 15, "{policy}");
        assert!(record.statuses.iter().all(|s| s.is_finished()), "{policy}");
        for (f, s) in record.records.iter().zip(&solo) {
            assert_records_equivalent(f.as_ref().expect("finished member has a record"), s);
        }
        // aggregate accounting is the sum of the solo runs
        let want_device: f64 = solo.iter().map(|r| r.total_device_ms).sum();
        assert!((record.total_device_ms - want_device).abs() < 1e-9, "{policy}");
        let want_mem: usize = solo.iter().map(|r| r.peak_memory_bytes).sum();
        assert_eq!(record.peak_memory_bytes, want_mem, "{policy}");
    }
}

/// ISSUE 4's fleet-resume pin: kill a 3-member heterogeneous fleet
/// (stream / drift / replay sources) mid-run with each member at a
/// *different* completed round, resume via
/// `FleetBuilder::session_checkpointed`, and every member's final record
/// is byte-identical to its uninterrupted solo run.
#[test]
fn killed_fleet_resumes_each_member_at_its_own_round() {
    use titan::coordinator::session::observers::Checkpoint;
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("titan_fleet_resume");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = |i: usize| dir.join(format!("s{i}.json"));

    let solo: Vec<RunRecord> = (0..3).map(|i| fleet_member(i).run().unwrap().0).collect();

    // the "kill": run each member a different number of rounds with its
    // checkpoint observer (cadence 2), then drop it mid-run. Member 0
    // snapshots at round 4; members 1 and 2 at round 2 — member 2's
    // third round ran after the cadence multiple, so it is lost on disk
    // and must be re-executed identically after resume.
    for (i, steps) in [(0usize, 4usize), (1, 2), (2, 3)] {
        let mut session = fleet_member_builder(i)
            .observe(Checkpoint::every(path(i), 2))
            .build()
            .unwrap();
        for _ in 0..steps {
            session.step().unwrap();
        }
        drop(session);
    }

    let mut fleet = FleetBuilder::new().policy_boxed(parse_policy("fewest").unwrap());
    for i in 0..3 {
        fleet = fleet
            .session_checkpointed(format!("s{i}"), fleet_member_builder(i), path(i), 2, true)
            .unwrap();
    }
    let record = fleet.run().unwrap();
    assert_eq!(record.records.len(), 3);
    // post-resume rounds only: (6-4, 4-2, 5-2)
    assert_eq!(record.session_rounds, vec![2, 2, 3]);
    for (resumed, uninterrupted) in record.records.iter().zip(&solo) {
        assert_records_equivalent(
            resumed.as_ref().expect("finished member has a record"),
            uninterrupted,
        );
    }
    // every member's file now marks completion...
    for i in 0..3 {
        assert!(Checkpoint::load(&path(i)).unwrap().complete);
    }
    // ...so a second resume skips all members instead of re-running them
    let mut fleet = FleetBuilder::new();
    for i in 0..3 {
        fleet = fleet
            .session_checkpointed(format!("s{i}"), fleet_member_builder(i), path(i), 2, true)
            .unwrap();
    }
    assert!(fleet.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Stepping a session by hand through the public API yields the same
/// record as `run` — end-to-end, over a non-default source.
#[test]
fn manual_stepping_matches_run_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let (solo, solo_out) = fleet_member(1).run().unwrap();
    let mut session = fleet_member(1);
    let stepped = loop {
        match session.step().unwrap() {
            StepEvent::OpCompleted(op) => panic!("step() must not surface ops: {}", op.name()),
            StepEvent::RoundCompleted(_) => {}
            StepEvent::Finished(record) => break record,
        }
    };
    assert_records_equivalent(&solo, &stepped);
    assert_eq!(solo_out.len(), session.outcomes().len());
}

/// DriftSource through the full Titan stack: the class mix the filter
/// sees moves over the run and the session still learns/completes.
#[test]
fn drift_source_through_titan_session() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(Method::Titan, 12);
    cfg.pipeline = false;
    let task = SynthTask::for_model(&cfg.model, cfg.seed);
    // uniform -> one dominant class over the first 6 rounds
    let mut end = vec![0.25; 6];
    end[0] = 6.0;
    let drift = DriftSource::new(task, vec![1.0; 6], end, 6, cfg.seed).unwrap();
    let (record, outcomes) = SessionBuilder::new(cfg.clone())
        .sequential()
        .source(drift)
        .run()
        .unwrap();
    assert_eq!(outcomes.len(), 12);
    assert!(record.final_accuracy.is_finite());
    assert!(outcomes.iter().all(|o| o.selector.candidates <= cfg.candidate_size));
}

/// Option-record equivalence: presence must agree, and present records
/// must match on every deterministic field.
fn assert_opt_records_equivalent(a: &Option<RunRecord>, b: &Option<RunRecord>) {
    match (a, b) {
        (Some(x), Some(y)) => assert_records_equivalent(x, y),
        (None, None) => {}
        _ => panic!("one record present, the other missing"),
    }
}

/// The fault plane's first determinism pin: a zero-rate fault plan under
/// every supervision policy is bit-identical to today's fleet with no
/// plan at all — same records, rounds, statuses, and (empty) telemetry.
#[test]
fn zero_rate_fault_plan_is_bit_identical_under_every_supervision() {
    if !have_artifacts() {
        return;
    }
    let baseline = {
        let mut fleet = FleetBuilder::new();
        for i in 0..3 {
            fleet = fleet.session(format!("s{i}"), fleet_member_builder(i));
        }
        fleet.run().unwrap()
    };
    assert!(baseline.statuses.iter().all(|s| s.is_finished()));
    for supervise in [
        SupervisionPolicy::FailFast,
        SupervisionPolicy::Isolate,
        SupervisionPolicy::Restart { max_retries: 3, backoff_rounds: 1, backoff_cap: 32 },
    ] {
        let mut fleet = FleetBuilder::new()
            .supervise(supervise)
            .fault_plan(FaultPlan::new(0xD15EA5E));
        for i in 0..3 {
            // restart supervision wants a factory; give everyone one
            fleet = fleet
                .session_restartable(format!("s{i}"), move || Ok(fleet_member_builder(i)))
                .unwrap();
        }
        let record = fleet.run().unwrap();
        assert_eq!(record.session_rounds, baseline.session_rounds, "{supervise:?}");
        assert_eq!(record.rounds_executed, baseline.rounds_executed, "{supervise:?}");
        assert_eq!(record.statuses, baseline.statuses, "{supervise:?}");
        assert_eq!(record.faults, baseline.faults, "{supervise:?}");
        assert_eq!(record.total_device_ms, baseline.total_device_ms, "{supervise:?}");
        assert_eq!(record.energy_j, baseline.energy_j, "{supervise:?}");
        assert_eq!(record.peak_memory_bytes, baseline.peak_memory_bytes, "{supervise:?}");
        for (a, b) in record.records.iter().zip(&baseline.records) {
            assert_opt_records_equivalent(a, b);
        }
    }
}

/// The ISSUE's isolate pin: a 3-member fleet with one scripted crasher
/// completes with 2 finished members (whose records are untouched by the
/// neighbour's crash) and 1 quarantined member.
#[test]
fn isolate_quarantines_the_crasher_and_finishes_the_rest() {
    if !have_artifacts() {
        return;
    }
    let solo: Vec<RunRecord> = (0..3).map(|i| fleet_member(i).run().unwrap().0).collect();
    let plan = FaultPlan::new(1).script(1, 2, FaultKind::Crash);
    let mut fleet = FleetBuilder::new()
        .supervise(SupervisionPolicy::Isolate)
        .fault_plan(plan);
    for i in 0..3 {
        fleet = fleet.session(format!("s{i}"), fleet_member_builder(i));
    }
    let record = fleet.run().unwrap();
    assert_eq!(record.finished(), 2);
    assert!(record.statuses[0].is_finished());
    assert!(record.statuses[2].is_finished());
    match &record.statuses[1] {
        SessionStatus::Quarantined { round, reason } => {
            assert_eq!(*round, 2);
            assert!(reason.contains("injected crash"), "{reason}");
        }
        other => panic!("expected quarantine, got {other:?}"),
    }
    assert!(record.records[1].is_none());
    assert_records_equivalent(record.records[0].as_ref().unwrap(), &solo[0]);
    assert_records_equivalent(record.records[2].as_ref().unwrap(), &solo[2]);
    assert_eq!(record.faults.crashes, 1);
    assert_eq!(record.faults.quarantines, 1);
    // aggregate accounting only counts finished members
    assert_eq!(
        record.peak_memory_bytes,
        solo[0].peak_memory_bytes + solo[2].peak_memory_bytes
    );
}

/// The ISSUE's restart pin: a member crashed mid-run is rebuilt from its
/// latest checkpoint and its final record is byte-identical (on the
/// deterministic fields) to the uninterrupted solo run — the whole fleet
/// finishes with no quarantines.
#[test]
fn crashed_member_recovers_identically_under_restart_supervision() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("titan_fleet_restart");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = |i: usize| dir.join(format!("s{i}.json"));

    let solo: Vec<RunRecord> = (0..3).map(|i| fleet_member(i).run().unwrap().0).collect();

    // member 0 (6 rounds, cadence-2 checkpoints) crashes at its round 3:
    // the latest snapshot is round 2, so the restart replays one round
    let plan = FaultPlan::new(2).script(0, 3, FaultKind::Crash);
    let mut fleet = FleetBuilder::new()
        .supervise(SupervisionPolicy::Restart {
            max_retries: 3,
            backoff_rounds: 1,
            backoff_cap: 32,
        })
        .fault_plan(plan);
    for i in 0..3 {
        fleet = fleet
            .session_checkpointed_restartable(
                format!("s{i}"),
                move || Ok(fleet_member_builder(i)),
                path(i),
                2,
                false,
            )
            .unwrap();
    }
    let record = fleet.run().unwrap();
    assert!(record.statuses.iter().all(|s| s.is_finished()), "{:?}", record.statuses);
    for (f, s) in record.records.iter().zip(&solo) {
        assert_records_equivalent(f.as_ref().unwrap(), s);
    }
    assert_eq!(record.faults.crashes, 1);
    assert_eq!(record.faults.restarts, 1);
    assert_eq!(record.faults.quarantines, 0);
    assert_eq!(record.faults.rounds_recovered, 1);
    // the replayed round shows up in the fleet's executed-round counts
    assert_eq!(record.session_rounds, vec![7, 4, 5]);
    assert_eq!(record.rounds_executed, 16);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The vault's ISSUE pin: a torn newest generation plus a crash falls
/// back to the previous generation under restart supervision, replays
/// the lost rounds, finishes with records identical to the solo runs,
/// and surfaces the degradation as recovery telemetry on both the
/// member's record and the fleet aggregate.
#[test]
fn torn_checkpoint_falls_back_a_generation_and_recovers() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("titan_fleet_torn");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = |i: usize| dir.join(format!("s{i}.json"));

    let solo: Vec<RunRecord> = (0..3).map(|i| fleet_member(i).run().unwrap().0).collect();

    // member 0 (6 rounds, cadence-2 checkpoints, keep=2): after its round
    // 4 the vault holds generations g1 (round 2) and g2 (round 4). The
    // scripted torn write truncates g2; the crash one round later forces
    // a restart whose vault walk rejects g2 and resumes from g1.
    let plan = FaultPlan::new(3)
        .script(0, 4, FaultKind::TornWrite)
        .script(0, 5, FaultKind::Crash);
    let mut fleet = FleetBuilder::new()
        .supervise(SupervisionPolicy::Restart {
            max_retries: 3,
            backoff_rounds: 1,
            backoff_cap: 32,
        })
        .fault_plan(plan)
        .keep_checkpoints(2);
    for i in 0..3 {
        fleet = fleet
            .session_checkpointed_restartable(
                format!("s{i}"),
                move || Ok(fleet_member_builder(i)),
                path(i),
                2,
                false,
            )
            .unwrap();
    }
    let record = fleet.run().unwrap();
    assert!(record.statuses.iter().all(|s| s.is_finished()), "{:?}", record.statuses);
    for (f, s) in record.records.iter().zip(&solo) {
        assert_records_equivalent(f.as_ref().unwrap(), s);
    }
    assert_eq!(record.faults.corruptions, 1);
    assert_eq!(record.faults.crashes, 1);
    assert_eq!(record.faults.restarts, 1);
    assert_eq!(record.faults.quarantines, 0);
    // resumed from the round-2 generation: rounds 3..5 replay
    assert_eq!(record.faults.rounds_recovered, 3);
    assert_eq!(record.session_rounds, vec![9, 4, 5]);
    assert_eq!(record.rounds_executed, 18);
    // the degraded resume is visible on the member's record...
    let rec = record.records[0].as_ref().unwrap().recovery.as_ref().unwrap();
    assert_eq!(rec.frames_scanned, 2);
    assert_eq!(rec.torn_frames, 1);
    assert_eq!(rec.crc_failures, 0);
    assert_eq!(rec.generation_used, 1);
    // ...and on the fleet aggregate, while untouched members stay clean
    assert_eq!(record.recovery.as_ref(), Some(rec));
    assert!(record.records[1].as_ref().unwrap().recovery.is_none());
    assert!(record.records[2].as_ref().unwrap().recovery.is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE's telemetry pin: the same config + fault seed twice yields
/// byte-identical deterministic FleetRecord fields, including the full
/// fault telemetry and the serialized plan.
#[test]
fn same_fault_seed_yields_identical_fleet_telemetry() {
    if !have_artifacts() {
        return;
    }
    let run = || {
        let mut plan = FaultPlan::new(0xFA7E);
        plan.crash_rate = 0.08;
        plan.transient_rate = 0.10;
        plan.straggler_rate = 0.10;
        // one scripted fault so the run is guaranteed to inject something
        let plan = plan.script(0, 1, FaultKind::Transient);
        let mut fleet = FleetBuilder::new()
            .supervise(SupervisionPolicy::Isolate)
            .fault_plan(plan);
        for i in 0..3 {
            fleet = fleet.session(format!("s{i}"), fleet_member_builder(i));
        }
        fleet.run().unwrap()
    };
    let a = run();
    let b = run();
    assert!(a.faults.total() > 0);
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.statuses, b.statuses);
    assert_eq!(a.session_rounds, b.session_rounds);
    assert_eq!(a.rounds_executed, b.rounds_executed);
    assert_eq!(a.total_device_ms, b.total_device_ms);
    assert_eq!(a.energy_j, b.energy_j);
    assert_eq!(a.peak_memory_bytes, b.peak_memory_bytes);
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_opt_records_equivalent(x, y);
    }
    assert_eq!(
        a.fault_plan.as_ref().unwrap().to_string_compact(),
        b.fault_plan.as_ref().unwrap().to_string_compact()
    );
}

/// The sharded host's determinism oracle (ISSUE 8): the same
/// heterogeneous fleet — stream / drift / replay members, a scripted
/// mid-run crash and restart supervision — produces bit-identical
/// per-session records and fault telemetry at every `--host-threads`.
#[test]
fn fleet_records_identical_across_host_threads() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join("titan_fleet_threads");
    let run = |threads: usize| {
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // member 0 crashes at its round 3; cadence-2 checkpoints mean the
        // restart replays exactly one round, on whichever worker admits it
        let plan = FaultPlan::new(2).script(0, 3, FaultKind::Crash);
        let mut fleet = FleetBuilder::new()
            .supervise(SupervisionPolicy::Restart {
                max_retries: 3,
                backoff_rounds: 1,
                backoff_cap: 32,
            })
            .fault_plan(plan)
            .host_threads(threads);
        for i in 0..3 {
            fleet = fleet
                .session_checkpointed_restartable(
                    format!("s{i}"),
                    move || Ok(fleet_member_builder(i)),
                    dir.join(format!("s{i}.json")),
                    2,
                    false,
                )
                .unwrap();
        }
        fleet.run().unwrap()
    };

    // host_threads = 1 is the reference: the legacy single-thread loop
    let reference = run(1);
    assert!(reference.statuses.iter().all(|s| s.is_finished()));
    assert_eq!(reference.host_threads, 1);
    assert_eq!(reference.steals, 0);
    assert_eq!(reference.faults.crashes, 1);
    assert_eq!(reference.faults.restarts, 1);
    assert_eq!(reference.session_rounds, vec![7, 4, 5]);

    for threads in [2usize, 4] {
        let record = run(threads);
        // 3 sessions clamp a 4-thread host to 3 shards
        assert_eq!(record.host_threads, threads.min(3), "t={threads}");
        assert_eq!(record.shards.len(), threads.min(3), "t={threads}");
        // 3 admissions plus 1 restart re-admission, wherever they landed
        assert_eq!(
            record.shards.iter().map(|s| s.sessions).sum::<usize>(),
            4,
            "t={threads}"
        );
        assert_eq!(record.statuses, reference.statuses, "t={threads}");
        assert_eq!(record.faults, reference.faults, "t={threads}");
        assert_eq!(record.session_rounds, reference.session_rounds, "t={threads}");
        assert_eq!(record.rounds_executed, reference.rounds_executed, "t={threads}");
        assert_eq!(record.total_device_ms, reference.total_device_ms, "t={threads}");
        assert_eq!(record.energy_j, reference.energy_j, "t={threads}");
        assert_eq!(record.peak_memory_bytes, reference.peak_memory_bytes, "t={threads}");
        for (a, b) in record.records.iter().zip(&reference.records) {
            assert_opt_records_equivalent(a, b);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Wraps a source so its first batch takes a long wall-clock time: the
/// worker that admits it blocks mid-op with the rest of its cold queue
/// still parked — exactly the window work stealing exists for.
struct SlowStart<S: DataSource> {
    inner: S,
    delay: std::time::Duration,
    fired: bool,
}

impl<S: DataSource> DataSource for SlowStart<S> {
    fn task(&self) -> &SynthTask {
        self.inner.task()
    }
    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        if !self.fired {
            self.fired = true;
            std::thread::sleep(self.delay);
        }
        self.inner.next_round(v)
    }
    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.inner.test_set(n, seed)
    }
}

/// The steal path end-to-end: session 0 stalls its whole shard on a
/// deliberately slow first op, so the other worker drains its own queue
/// and then steals session 0's parked neighbours. Records don't depend
/// on who ran what — only the steal counters do.
#[test]
fn idle_worker_steals_from_a_blocked_shard() {
    if !have_artifacts() {
        return;
    }
    // grow the fleet until session 0's shard holds at least two other
    // members: cold members parked behind the slow session are what the
    // idle worker has to steal (shard_of is a pure hash, so this count
    // is a compile-time-stable property of the fleet size)
    let home = shard_of(0, 2);
    let mut n = 3;
    while (1..n).filter(|&i| shard_of(i, 2) == home).count() < 2 {
        n += 1;
    }
    let mut fleet = FleetBuilder::new()
        .policy_boxed(parse_policy("rr").unwrap())
        .host_threads(2);
    for i in 0..n {
        let mut cfg = base(Method::Rs, 2);
        cfg.pipeline = false;
        cfg.eval_every = 2;
        cfg.test_size = 50;
        cfg.seed += i as u64;
        let mut builder = SessionBuilder::new(cfg.clone()).sequential();
        if i == 0 {
            let task = SynthTask::for_model(&cfg.model, cfg.seed);
            let stream = StreamSource::new(task, cfg.seed, cfg.noise);
            builder = builder.source(SlowStart {
                inner: stream,
                delay: std::time::Duration::from_millis(4000),
                fired: false,
            });
        }
        fleet = fleet.session(format!("s{i}"), builder);
    }
    let record = fleet.run().unwrap();
    assert!(record.statuses.iter().all(|s| s.is_finished()), "{:?}", record.statuses);
    assert_eq!(record.session_rounds, vec![2; n]);
    assert_eq!(record.host_threads, 2);
    assert_eq!(record.shards.len(), 2);
    assert!(record.steals > 0, "idle worker never stole: {:?}", record.shards);
    // both sides of every steal are counted, once each
    let steals_in: u64 = record.shards.iter().map(|s| s.steals_in).sum();
    let steals_out: u64 = record.shards.iter().map(|s| s.steals_out).sum();
    assert_eq!(steals_in, record.steals);
    assert_eq!(steals_out, record.steals);
    // every session was admitted exactly once, wherever it ran
    assert_eq!(record.shards.iter().map(|s| s.sessions).sum::<usize>(), n);
    assert_eq!(record.shards.iter().map(|s| s.rounds).sum::<usize>(), 2 * n);
}

#[test]
fn batch25_artifact_trains() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base(Method::Rs, 4);
    cfg.batch_size = 25;
    cfg.candidate_size = 30;
    cfg.pipeline = false;
    let (record, outcomes) = run_sequential(&cfg);
    assert_eq!(outcomes.len(), 4);
    assert!(record.final_accuracy.is_finite());
}

#[test]
fn conv_variant_end_to_end_if_built() {
    // exercise one conv artifact set end-to-end (squeeze = cheapest conv)
    if !std::path::Path::new("artifacts/squeeze/meta.json").exists() {
        eprintln!("skipping: squeeze artifacts not built");
        return;
    }
    let mut cfg = presets::table1("squeeze", Method::Titan);
    cfg.rounds = 6;
    cfg.test_size = 200;
    cfg.eval_every = 3;
    let (record, outcomes) = run_pipelined(&cfg);
    assert_eq!(outcomes.len(), 6);
    assert!(record.final_accuracy.is_finite());
    assert!(outcomes[0].selector.candidates <= cfg.candidate_size);
}
