//! Bench: raw PJRT artifact execution — train_step / importance / probe /
//! features / eval latency per model (the L1+L2 hot paths as seen from
//! L3). These are the numbers the §Perf pass optimizes.
//!
//! Run: `cargo bench --bench bench_runtime`

use titan::data::Sample;
use titan::runtime::artifact::ArtifactSet;
use titan::runtime::model::{ModelRuntime, RuntimeRole};
use titan::util::bench::Bencher;

fn det_samples(n: usize, d: usize, classes: usize) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            let x: Vec<f32> = (0..d).map(|j| ((i * d + j) as f32 * 0.01).sin()).collect();
            Sample::new(i as u64, (i % classes) as u32, x)
        })
        .collect()
}

fn main() {
    let models = ArtifactSet::list_models("artifacts");
    if models.is_empty() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return;
    }
    let mut b = Bencher::new("runtime");
    // full sweep for mlp; headline ops for the rest
    for model in &models {
        let mut rt = match ModelRuntime::load("artifacts", model, RuntimeRole::Full) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let m = rt.set.meta.clone();
        let train = det_samples(m.train_batch, m.input_dim, m.num_classes);
        let trefs: Vec<&Sample> = train.iter().collect();
        b.bench(&format!("train_step_b{}/{model}", m.train_batch), || {
            rt.train_step(&trefs, 0.01).expect("train")
        });
        // param snapshot cost, old vs new: full Vec clone vs Arc bump —
        // this is what the pipeline pays per round to sync the selector
        b.bench(&format!("params_to_vec/{model}"), || rt.params().to_vec());
        b.bench(&format!("params_share_arc/{model}"), || rt.share_params());
        let cands = det_samples(30, m.input_dim, m.num_classes);
        let crefs: Vec<&Sample> = cands.iter().collect();
        b.bench(&format!("importance_n30/{model}"), || {
            rt.importance(&crefs).expect("imp")
        });
        if model == "mlp" {
            let full = det_samples(m.cand_max, m.input_dim, m.num_classes);
            let frefs: Vec<&Sample> = full.iter().collect();
            b.bench(&format!("importance_n{}/{model}", m.cand_max), || {
                rt.importance(&frefs).expect("imp")
            });
            b.bench(&format!("probe_n{}/{model}", m.cand_max), || {
                rt.probe(&frefs).expect("probe")
            });
            let chunk = det_samples(m.filter_chunk, m.input_dim, m.num_classes);
            let chrefs: Vec<&Sample> = chunk.iter().collect();
            rt.ensure_features(1).expect("features");
            b.bench(&format!("features_b1_chunk{}/{model}", m.filter_chunk), || {
                rt.features(&chrefs, 1).expect("features")
            });
            let test = det_samples(m.eval_chunk, m.input_dim, m.num_classes);
            b.bench(&format!("eval_chunk{}/{model}", m.eval_chunk), || {
                rt.evaluate(&test).expect("eval")
            });
        }
    }
    b.finish();
}
