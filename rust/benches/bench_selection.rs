//! Bench: per-round selection cost per method (paper Fig. 2a / Table 1's
//! time column, host clock). Measures the full selection round — evidence
//! computation (importance/probe/features via the PJRT artifacts) plus the
//! strategy itself — for each method on the mlp artifact set.
//!
//! Run: `cargo bench --bench bench_selection` (TITAN_BENCH_FAST=1 to smoke)

use titan::config::{presets, Method};
use titan::coordinator::{build_stream, SelectorEngine};
use titan::util::bench::Bencher;

fn main() {
    if !std::path::Path::new("artifacts/mlp/meta.json").exists() {
        eprintln!("skipping bench_selection: run `make artifacts` first");
        return;
    }
    let mut b = Bencher::new("selection");
    for method in [
        Method::Rs,
        Method::Is,
        Method::Ll,
        Method::Ce,
        Method::Ocs,
        Method::Camel,
        Method::Cis,
        Method::Titan,
    ] {
        let mut cfg = presets::table1("mlp", method);
        cfg.rounds = 4;
        let (mut stream, _) = build_stream(&cfg);
        let mut sel = SelectorEngine::new(&cfg, stream.task()).expect("selector");
        // pre-pull a fixed round of arrivals so the bench isolates selection
        let arrivals = stream.next_round(cfg.stream_per_round);
        let mut round = 0usize;
        b.bench(&format!("select_round/{}", method.name()), || {
            round += 1;
            sel.select_round(round, arrivals.clone()).expect("select")
        });
    }
    b.finish();
}
