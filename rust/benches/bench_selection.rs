//! Bench: per-round selection cost per method (paper Fig. 2a / Table 1's
//! time column, host clock). Measures the full selection round — evidence
//! computation (importance/probe/features via the PJRT artifacts) plus the
//! strategy itself — for each method on the mlp artifact set.
//!
//! The `class_summaries{,_ref}_n*` pairs compare the single-pass Gram
//! triangle sweep against the per-class nested `k_at` reference at
//! realistic candidate sizes (host-only: synthetic K, no artifacts
//! needed); divide per-iteration time by `n` for ns/sample.
//!
//! Run: `cargo bench --bench bench_selection` (TITAN_BENCH_FAST=1 to smoke)

use titan::config::{presets, Method};
use titan::coordinator::{build_stream, SelectorEngine};
use titan::runtime::model::ImportanceOut;
use titan::selection::cis::{class_summaries, class_summaries_ref};
use titan::util::bench::Bencher;

/// Synthetic ImportanceOut: low-rank-ish symmetric K from 2-D gradients.
fn synth_importance(n: usize) -> ImportanceOut {
    let grads: Vec<(f64, f64)> = (0..n)
        .map(|i| {
            let th = i as f64 * 0.37;
            let r = 0.5 + (i % 7) as f64 * 0.25;
            (r * th.cos(), r * th.sin())
        })
        .collect();
    let mut k = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            k[i * n + j] = (grads[i].0 * grads[j].0 + grads[i].1 * grads[j].1) as f32;
        }
    }
    let norms: Vec<f32> = grads
        .iter()
        .map(|g| ((g.0 * g.0 + g.1 * g.1) as f32).sqrt())
        .collect();
    ImportanceOut {
        norms,
        k,
        n_total: n,
        valid: n,
    }
}

fn main() {
    let mut b = Bencher::new("selection");

    // single-pass Gram reduction vs the per-class nested reference
    let classes = 10usize;
    for n in [64usize, 256, 1024] {
        let imp = synth_importance(n);
        let labels: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
        b.bench(&format!("class_summaries_ref_n{n}"), || {
            class_summaries_ref(&labels, &imp, classes)
        });
        b.bench(&format!("class_summaries_n{n}"), || {
            class_summaries(&labels, &imp, classes)
        });
    }

    // parallel triangle sweep: 1 worker (the ref side) vs 4 workers at
    // cand_max scales where the sweep actually splits into blocks.
    // Results are bit-identical across thread counts (pinned in the lib
    // tests); this pair measures the wall-clock side. n=8192 allocates a
    // 256 MiB K, so the smoke (fast) mode stops at 4096.
    let fast = std::env::var("TITAN_BENCH_FAST").is_ok();
    let par_sizes: &[usize] = if fast { &[1024, 4096] } else { &[1024, 4096, 8192] };
    for &n in par_sizes {
        let imp = synth_importance(n);
        let labels: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
        b.bench(&format!("gram_par_ref_n{n}"), || {
            imp.gram_class_sums_threaded(&labels, classes, 1)
        });
        b.bench(&format!("gram_par_n{n}"), || {
            imp.gram_class_sums_threaded(&labels, classes, 4)
        });
    }

    if !std::path::Path::new("artifacts/mlp/meta.json").exists() {
        eprintln!("skipping artifact benches: run `make artifacts` first");
        b.finish();
        return;
    }
    for method in [
        Method::Rs,
        Method::Is,
        Method::Ll,
        Method::Ce,
        Method::Ocs,
        Method::Camel,
        Method::Cis,
        Method::Titan,
    ] {
        let mut cfg = presets::table1("mlp", method);
        cfg.rounds = 4;
        let (mut stream, _) = build_stream(&cfg);
        let mut sel = SelectorEngine::new(&cfg, stream.task()).expect("selector");
        // pre-pull a fixed round of arrivals so the bench isolates selection
        let arrivals = stream.next_round(cfg.stream_per_round);
        let mut round = 0usize;
        b.bench(&format!("select_round/{}", method.name()), || {
            round += 1;
            sel.select_round(round, arrivals.clone()).expect("select")
        });
    }
    b.finish();
}
