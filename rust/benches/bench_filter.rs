//! Bench: coarse-filter per-sample processing cost (paper Fig. 6b) —
//! feature extraction (PJRT features artifact, chunked) + scoring +
//! buffer maintenance, reported per streaming sample. Also benches the
//! host-side scoring/buffer path alone (no model), which bounds the
//! coordinator overhead.
//!
//! The `score_{ref,chunk}_n*` pairs compare the pre-optimization scalar
//! scorer (fresh centroid `Vec` + `‖c‖²` recompute per sample) against
//! the zero-alloc chunked path at realistic candidate sizes; divide the
//! per-iteration time by `n` for ns/sample (scripts/bench_report.py does
//! this when emitting BENCH_filter.json).
//!
//! Run: `cargo bench --bench bench_filter`

use titan::config::{presets, Method};
use titan::coordinator::build_stream;
use titan::data::Sample;
use titan::filter::CoarseFilter;
use titan::runtime::model::{ModelRuntime, RuntimeRole};
use titan::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("filter");

    // host-only scoring path (no model involved)
    {
        let dim = 64usize;
        let mut filt = CoarseFilter::new(10, dim, 30, 0.3);
        let feats: Vec<Vec<f32>> = (0..100)
            .map(|i| (0..dim).map(|j| ((i * dim + j) as f32 * 0.01).sin()).collect())
            .collect();
        let samples: Vec<Sample> = (0..100)
            .map(|i| Sample::new(i as u64, (i % 10) as u32, vec![0.0; 4]))
            .collect();
        let mut i = 0usize;
        b.bench("host_score_and_buffer/sample", || {
            let k = i % 100;
            i += 1;
            filt.process(samples[k].clone(), &feats[k])
        });
    }

    // old-vs-new scoring at realistic candidate sizes: the scalar
    // reference path allocates a centroid per sample; the chunked path is
    // zero-alloc (one reused output buffer per chunk)
    for n in [64usize, 256, 1024] {
        let dim = 64usize;
        let classes = 10usize;
        let mut filt = CoarseFilter::new(classes, dim, 30, 0.3);
        let feats: Vec<f32> = (0..n * dim).map(|i| ((i as f32) * 0.01).sin()).collect();
        let samples: Vec<Sample> = (0..n)
            .map(|i| Sample::new(i as u64, (i % classes) as u32, vec![0.0; 4]))
            .collect();
        for (i, s) in samples.iter().enumerate() {
            filt.estimators.update(s.label, &feats[i * dim..(i + 1) * dim]);
        }
        b.bench(&format!("score_chunk_ref_n{n}/chunk"), || {
            let mut acc = 0.0f64;
            for (i, s) in samples.iter().enumerate() {
                acc += filt.score_ref(s.label, &feats[i * dim..(i + 1) * dim]);
            }
            acc
        });
        let mut out: Vec<f64> = Vec::with_capacity(n);
        b.bench(&format!("score_chunk_n{n}/chunk"), || {
            filt.score_chunk_into(&samples, &feats, &mut out);
            out.iter().sum::<f64>()
        });
        // the full streaming path (update + score + offer), chunked
        let mut stream_filt = CoarseFilter::new(classes, dim, 30, 0.3);
        b.bench(&format!("process_chunk_n{n}/chunk"), || {
            stream_filt.process_chunk(&samples, &feats);
            stream_filt.processed()
        });
    }

    // full path with the PJRT features artifact (chunk of 25)
    if std::path::Path::new("artifacts/mlp/meta.json").exists() {
        let cfg = presets::table1("mlp", Method::Titan);
        let (mut stream, _) = build_stream(&cfg);
        let mut rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Selector).expect("rt");
        rt.ensure_features(1).expect("features");
        let arrivals = stream.next_round(25);
        let refs: Vec<&Sample> = arrivals.iter().collect();
        b.bench("features_chunk25_b1/mlp", || {
            rt.features(&refs, 1).expect("features")
        });
        for k in 1..=rt.set.meta.num_blocks() {
            rt.ensure_features(k).expect("features");
            b.bench(&format!("features_chunk25_b{k}/mlp"), || {
                rt.features(&refs, k).expect("features")
            });
        }
    } else {
        eprintln!("skipping artifact benches: run `make artifacts` first");
    }
    b.finish();
}
