//! Bench: coarse-filter per-sample processing cost (paper Fig. 6b) —
//! feature extraction (PJRT features artifact, chunked) + scoring +
//! buffer maintenance, reported per streaming sample. Also benches the
//! host-side scoring/buffer path alone (no model), which bounds the
//! coordinator overhead.
//!
//! The `score_{ref,chunk}_n*` pairs compare the pre-optimization scalar
//! scorer (fresh centroid `Vec` + `‖c‖²` recompute per sample) against
//! the zero-alloc chunked path at realistic candidate sizes; divide the
//! per-iteration time by `n` for ns/sample (scripts/bench_report.py does
//! this when emitting BENCH_filter.json).
//!
//! Run: `cargo bench --bench bench_filter`

use titan::config::{presets, Method};
use titan::coordinator::build_stream;
use titan::data::Sample;
use titan::filter::CoarseFilter;
use titan::runtime::model::{ModelRuntime, RuntimeRole};
use titan::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("filter");

    // host-only scoring path (no model involved)
    {
        let dim = 64usize;
        let mut filt = CoarseFilter::new(10, dim, 30, 0.3);
        let feats: Vec<Vec<f32>> = (0..100)
            .map(|i| (0..dim).map(|j| ((i * dim + j) as f32 * 0.01).sin()).collect())
            .collect();
        let samples: Vec<Sample> = (0..100)
            .map(|i| Sample::new(i as u64, (i % 10) as u32, vec![0.0; 4]))
            .collect();
        let mut i = 0usize;
        b.bench("host_score_and_buffer/sample", || {
            let k = i % 100;
            i += 1;
            filt.process(samples[k].clone(), &feats[k])
        });
    }

    // old-vs-new scoring at realistic candidate sizes: the scalar
    // reference path allocates a centroid per sample; the chunked path is
    // zero-alloc (one reused output buffer per chunk)
    for n in [64usize, 256, 1024] {
        let dim = 64usize;
        let classes = 10usize;
        let mut filt = CoarseFilter::new(classes, dim, 30, 0.3);
        let feats: Vec<f32> = (0..n * dim).map(|i| ((i as f32) * 0.01).sin()).collect();
        let samples: Vec<Sample> = (0..n)
            .map(|i| Sample::new(i as u64, (i % classes) as u32, vec![0.0; 4]))
            .collect();
        for (i, s) in samples.iter().enumerate() {
            filt.estimators.update(s.label, &feats[i * dim..(i + 1) * dim]);
        }
        b.bench(&format!("score_chunk_ref_n{n}/chunk"), || {
            let mut acc = 0.0f64;
            for (i, s) in samples.iter().enumerate() {
                acc += filt.score_ref(s.label, &feats[i * dim..(i + 1) * dim]);
            }
            acc
        });
        let mut out: Vec<f64> = Vec::with_capacity(n);
        b.bench(&format!("score_chunk_n{n}/chunk"), || {
            filt.score_chunk_into(&samples, &feats, &mut out);
            out.iter().sum::<f64>()
        });
        // wide-lane vs the PR-1 "narrow" chunked path: same cached
        // centroid + cached ‖c‖², but scalar left-to-right dot/norm — the
        // pair isolates exactly what the 8-lane kernels buy
        b.bench(&format!("score_chunk_wide_ref_n{n}/chunk"), || {
            let mut acc = 0.0f64;
            let lambda = 0.3f64;
            for (i, s) in samples.iter().enumerate() {
                let f = &feats[i * dim..(i + 1) * dim];
                let c = filt.estimators.centroid_ref(s.label);
                let cn2 = filt.estimators.centroid_norm2(s.label);
                let m2 = filt.estimators.mean_norm2(s.label);
                let fn2 = titan::util::stats::norm2(f);
                let fc = titan::util::stats::dot(f, c);
                let rep = -(fn2 - 2.0 * fc + cn2);
                let div = fn2 + m2 - 2.0 * fc;
                acc += lambda * rep + (1.0 - lambda) * div;
            }
            acc
        });
        b.bench(&format!("score_chunk_wide_n{n}/chunk"), || {
            filt.score_chunk_into(&samples, &feats, &mut out);
            out.iter().sum::<f64>()
        });
        // the full streaming path (update + score + offer), chunked
        let mut stream_filt = CoarseFilter::new(classes, dim, 30, 0.3);
        b.bench(&format!("process_chunk_n{n}/chunk"), || {
            stream_filt.process_chunk(&samples, &feats);
            stream_filt.processed()
        });
    }

    // candidate ring: a round's worth of offers + the winners-only drain
    // (paper shape: cap 30, ~100 arrivals/round; plus a 4k-cap regime)
    for (cap, offers) in [(30usize, 100usize), (4096, 16384)] {
        let scores: Vec<f64> = (0..offers)
            .map(|i| ((i as f64 * 0.7311).sin() + 1.0) * 50.0 + i as f64 * 1e-9)
            .collect();
        let samples: Vec<Sample> =
            (0..offers).map(|i| Sample::new(i as u64, 0, vec![0.0; 4])).collect();
        let mut buf = titan::data::buffer::CandidateBuffer::new(cap);
        b.bench(&format!("ring_offer_drain_cap{cap}_n{offers}/round"), || {
            for (s, &score) in samples.iter().zip(&scores) {
                buf.offer(s.clone(), score);
            }
            buf.drain_sorted().len()
        });
    }

    // full path with the PJRT features artifact (chunk of 25)
    if std::path::Path::new("artifacts/mlp/meta.json").exists() {
        let cfg = presets::table1("mlp", Method::Titan);
        let (mut stream, _) = build_stream(&cfg);
        let mut rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Selector).expect("rt");
        rt.ensure_features(1).expect("features");
        let arrivals = stream.next_round(25);
        let refs: Vec<&Sample> = arrivals.iter().collect();
        b.bench("features_chunk25_b1/mlp", || {
            rt.features(&refs, 1).expect("features")
        });
        for k in 1..=rt.set.meta.num_blocks() {
            rt.ensure_features(k).expect("features");
            b.bench(&format!("features_chunk25_b{k}/mlp"), || {
                rt.features(&refs, k).expect("features")
            });
        }
    } else {
        eprintln!("skipping artifact benches: run `make artifacts` first");
    }
    b.finish();
}
