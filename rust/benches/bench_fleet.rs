//! Bench: fleet host at scale — end-to-end wall time for n one-round
//! light sessions on the single-thread host (`t1`) vs the sharded
//! work-stealing host (`t4`). The per-session work is identical across
//! thread counts (that is the determinism contract), so the t1/t4 ratio
//! is pure host-level speedup and the `sched_overhead_per_tick_ms`
//! fields in the resulting FleetRecord bound the scheduler's own cost.
//!
//! Run: `cargo bench --bench bench_fleet`

use titan::config::{presets, Method};
use titan::coordinator::host::{parse_policy, FleetBuilder};
use titan::coordinator::SessionBuilder;
use titan::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("fleet");
    if !std::path::Path::new("artifacts/mlp/meta.json").exists() {
        eprintln!("skipping fleet benches: run `make artifacts` first");
        b.finish();
        return;
    }
    // fast (smoke) mode caps the fleet size: a 10k-session fleet is a
    // full-bench measurement, not a compile-rot check
    let fast = std::env::var("TITAN_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[100, 1000] } else { &[100, 1000, 10_000] };
    if fast {
        eprintln!("fast mode: skipping fleet_rr_n10000_t{{1,4}} (run full `cargo bench` for them)");
    }
    for &n in sizes {
        for &threads in &[1usize, 4] {
            b.bench(&format!("fleet_rr_n{n}_t{threads}"), || {
                let mut fleet = FleetBuilder::new()
                    .policy_boxed(parse_policy("rr").unwrap())
                    .host_threads(threads);
                for i in 0..n {
                    let mut cfg = presets::table1("mlp", Method::Rs);
                    cfg.rounds = 1;
                    cfg.eval_every = 0;
                    cfg.test_size = 50;
                    cfg.pipeline = false;
                    cfg.seed = cfg.seed.wrapping_add(i as u64);
                    fleet = fleet.session(format!("s{i}"), SessionBuilder::new(cfg));
                }
                let record = fleet.run().expect("fleet");
                assert_eq!(record.rounds_executed, n);
                record
            });
        }
    }
    b.finish();
}
