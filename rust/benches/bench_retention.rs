//! Bench: retention-plane hot paths — the per-offer admit/evict cost of
//! a full [`SampleStore`] under each eviction policy (steady state: every
//! offer scans for its victim), the cheap-admit path into a store with
//! headroom, and the per-round blend cost of `RetainedSource`.
//!
//! The store's offer path is O(n) in live entries (duplicate-id scan +
//! victim scan, see PERF.md), so the `_n<k>` suffix is the store
//! occupancy in samples — divide by k for the per-entry scan cost.
//!
//! Run: `cargo bench --bench bench_retention`
//!
//! [`SampleStore`]: titan::retention::SampleStore

use titan::data::buffer::Candidate;
use titan::data::Sample;
use titan::retention::{sample_cost, RetentionKind, SampleStore};
use titan::util::bench::{black_box, Bencher};

const DIM: usize = 64;
const CLASSES: usize = 10;

fn candidate(id: u64, score: f64) -> Candidate {
    let x: Vec<f32> = (0..DIM).map(|j| ((id as usize * DIM + j) as f32 * 0.01).sin()).collect();
    Candidate {
        sample: Sample::new(id, (id % CLASSES as u64) as u32, x),
        score,
    }
}

/// A store filled to exactly `n` entries (budget fits n, no more).
fn full_store(n: usize, kind: RetentionKind) -> SampleStore {
    let mut st = SampleStore::new(n * sample_cost(DIM), CLASSES, kind, 7);
    for i in 0..n as u64 {
        st.offer(candidate(i, i as f64 * 0.1));
    }
    assert_eq!(st.len(), n);
    st
}

fn main() {
    let mut b = Bencher::new("retention");

    // steady-state admit/evict: every offer on a full store pays the
    // duplicate scan, the policy's victim scan, and the entry swap
    for kind in [RetentionKind::Score, RetentionKind::Balanced, RetentionKind::Reservoir] {
        for n in [64usize, 256, 1024] {
            let mut st = full_store(n, kind);
            let mut id = n as u64;
            b.bench(&format!("retention_admit_evict_{}_n{n}/offer", kind.name()), || {
                id += 1;
                // fresh id, high score: ScoreWeighted always admits, the
                // other policies exercise their own accept paths
                black_box(st.offer(candidate(id, 1e9)))
            });
        }
    }

    // cheap path: admitting into headroom (no victim scan, still the
    // duplicate-id scan over live entries)
    {
        let mut st = SampleStore::new(usize::MAX / 2, CLASSES, RetentionKind::Score, 7);
        for i in 0..1024u64 {
            st.offer(candidate(i, 0.5));
        }
        let mut id = 2048u64;
        b.bench("retention_admit_headroom_n1024/offer", || {
            id += 1;
            black_box(st.offer(candidate(id, 0.5)))
        });
    }

    // duplicate refresh: re-offering a live id updates in place
    {
        let mut st = full_store(256, RetentionKind::Score);
        let mut i = 0u64;
        b.bench("retention_refresh_n256/offer", || {
            i = (i + 1) % 256;
            black_box(st.offer(candidate(i, 0.9)))
        });
    }

    b.finish();
}
