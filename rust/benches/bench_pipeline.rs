//! Bench: pipelined vs sequential coordination (paper Fig. 6a) — full
//! short runs on the host clock, plus the channel/sync machinery alone.
//!
//! Run: `cargo bench --bench bench_pipeline`

use titan::config::{presets, Method};
use titan::coordinator::{pipeline, sequential};
use titan::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("pipeline");

    // sync-cost bound: round-trip a param-sized vector over a channel
    {
        let params = vec![0.5f32; 120_000];
        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<f32>>(1);
        b.bench("param_sync_roundtrip/120k_f32", || {
            tx.send(params.clone()).unwrap();
            rx.recv().unwrap()
        });
    }

    if !std::path::Path::new("artifacts/mlp/meta.json").exists() {
        eprintln!("skipping run benches: run `make artifacts` first");
        b.finish();
        return;
    }
    let mk = |pipeline: bool| {
        let mut cfg = presets::table1("mlp", Method::Titan);
        cfg.rounds = 5;
        cfg.eval_every = 0;
        cfg.test_size = 200;
        cfg.pipeline = pipeline;
        cfg
    };
    let seq_cfg = mk(false);
    b.bench("run5rounds/sequential", || {
        sequential::run(&seq_cfg).expect("seq")
    });
    let pipe_cfg = mk(true);
    b.bench("run5rounds/pipelined", || {
        pipeline::run(&pipe_cfg).expect("pipe")
    });
    b.finish();
}
