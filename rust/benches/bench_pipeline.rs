//! Bench: pipelined vs sequential coordination (paper Fig. 6a) — full
//! short runs on the host clock, plus the channel/sync machinery alone.
//!
//! Run: `cargo bench --bench bench_pipeline`

use std::sync::Arc;

use titan::config::{presets, Method};
use titan::coordinator::host::FleetBuilder;
use titan::coordinator::SessionBuilder;
use titan::device::idle::IdleTrace;
use titan::util::bench::Bencher;
use titan::util::sync::Latest;

fn main() {
    let mut b = Bencher::new("pipeline");

    // sync-cost bound, old vs new: a cloned Vec over a channel (the
    // pre-optimization handoff) vs an Arc snapshot through the latest-only
    // slot (the shipping handoff — refcount bump, no payload copy)
    {
        let params = vec![0.5f32; 120_000];
        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<f32>>(1);
        b.bench("param_sync_clone_channel/120k_f32", || {
            tx.send(params.clone()).unwrap();
            rx.recv().unwrap()
        });
    }
    {
        let params = Arc::new(vec![0.5f32; 120_000]);
        let slot: Latest<Arc<Vec<f32>>> = Latest::new();
        b.bench("param_sync_latest_slot/120k_f32", || {
            slot.publish(Arc::clone(&params));
            slot.take().unwrap()
        });
    }

    if !std::path::Path::new("artifacts/mlp/meta.json").exists() {
        eprintln!("skipping run benches: run `make artifacts` first");
        b.finish();
        return;
    }
    let mk = |pipeline: bool| {
        let mut cfg = presets::table1("mlp", Method::Titan);
        cfg.rounds = 5;
        cfg.eval_every = 0;
        cfg.test_size = 200;
        cfg.pipeline = pipeline;
        cfg
    };
    let seq_cfg = mk(false);
    b.bench("run5rounds/sequential", || {
        SessionBuilder::new(seq_cfg.clone())
            .sequential()
            .run()
            .expect("seq")
    });
    let pipe_cfg = mk(true);
    b.bench("run5rounds/pipelined", || {
        SessionBuilder::new(pipe_cfg.clone())
            .pipelined(IdleTrace::Constant(1.0))
            .run()
            .expect("pipe")
    });
    // fleet scheduling overhead: 3 sessions interleaved round-by-round on
    // the host scheduler vs the 3 solo runs above (the delta over 3x
    // run5rounds/sequential is the per-round scheduler cost — PERF.md)
    b.bench("run5rounds/fleet3_round_robin", || {
        let mut fleet = FleetBuilder::new();
        for i in 0..3u64 {
            let mut cfg = seq_cfg.clone();
            cfg.seed = cfg.seed.wrapping_add(i);
            fleet = fleet.session(format!("s{i}"), SessionBuilder::new(cfg));
        }
        fleet.run().expect("fleet")
    });
    b.finish();
}
