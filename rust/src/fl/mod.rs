//! Federated-learning orchestration (paper Appendix B, Fig. 10).
//!
//! Setting: 50 devices, non-IID local distributions (each device's stream
//! covers only 5 of the task's classes), 20% participation per round,
//! 3 local SGD iterations per selected device, FedAvg aggregation.
//! Each device runs the configured data-selection method locally over its
//! own stream before training — Titan's selection plugs in per-device.
//!
//! Built on the session API's extension seams: every device pulls its
//! arrivals through an object-safe [`DataSource`] (default:
//! [`ClassSubsetSource`], the Appendix-B non-IID shape; replay buffers or
//! custom streams swap in via [`FlBuilder::device_sources`]), and
//! [`RoundObserver`]s hook each communication round — progress logging
//! and early stopping without touching the FedAvg loop. [`FlBuilder`]
//! mirrors `SessionBuilder` for the federated deployment shape.
//!
//! Per-device rounds dispatch through the host scheduler machinery
//! ([`crate::coordinator::host`]): each comm round's participant set
//! drains in the order a pluggable [`SchedPolicy`] picks — the same
//! policies (round-robin, fewest-rounds-first, priority-by-staleness)
//! that interleave whole sessions in a
//! [`Fleet`](crate::coordinator::host::Fleet) order device work here,
//! over per-device participation counts and staleness. FedAvg still
//! aggregates the identical participant set — the policy never changes
//! who was sampled — but execution order feeds the shared selection RNG
//! and the FedAvg float-accumulation order, so numeric results are
//! reproducible per (seed, policy), not across policies.
//!
//! Real federated rounds lose devices. [`FlBuilder::fault_plan`] reuses
//! the fleet's deterministic [`FaultPlan`] as a per-device dropout and
//! straggler model: each *sampled* device consults
//! `plan.fault_for(device, comm_round)` — a crash kills the device for
//! the rest of the run, a transient failure or brown-out drops it for
//! this round only, and an injected slowdown beyond
//! [`FlBuilder::straggler_deadline`] misses the round deadline and is
//! cut. FedAvg then aggregates **survivors only**, weighting by actual
//! participation (a zero-survivor round leaves the global model
//! untouched); the coordinator's sampling stream never depends on the
//! plan, so a zero-rate plan is bit-identical to no plan at all.
//!
//! Interrupted deployments resume: [`FlBuilder::checkpoint`] persists
//! one [`CheckpointVault`]-backed capsule per run — global parameters,
//! the orchestrator RNG, and each device's dispatch state — and
//! [`FlBuilder::resume`] restores it, fast-forwarding the deterministic
//! device streams instead of persisting per-device buffers. The torn-
//! write story is the vault's: a shredded newest generation falls back
//! to the previous one and the replay cost is reported as the record's
//! `recovery` telemetry.
//!
//! Implementation note: devices share one `ModelRuntime` (Full role) and
//! swap parameter vectors in/out — functionally identical to 50 separate
//! processes, and the only tractable layout on a one-core host.

use crate::config::RunConfig;
use crate::coordinator::host::{pick_validated, RoundRobin, SchedPolicy, TaskState};
use crate::coordinator::session::{Control, RoundObserver};
use crate::coordinator::snapshot::{
    f32_list, u64_from_json, u64_to_json, words_from_json, words_to_json,
};
use crate::coordinator::vault::CheckpointVault;
use crate::coordinator::RoundOutcome;
use crate::data::buffer::Candidate;
use crate::data::{ClassSubsetSource, DataSource, RetainedSource, Sample, SynthTask};
use crate::fault::{FaultKind, FaultPlan};
use crate::metrics::{CurvePoint, RunRecord};
use crate::runtime::model::{ModelRuntime, RuntimeRole};
use crate::selection::{make_strategy, SelectionContext};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};
use std::path::PathBuf;

/// FL experiment configuration on top of a base RunConfig.
#[derive(Clone, Debug)]
pub struct FlConfig {
    pub base: RunConfig,
    pub num_devices: usize,
    /// Fraction of devices participating per round.
    pub participation: f64,
    /// Classes visible to each device's stream.
    pub classes_per_device: usize,
    /// Local SGD iterations per participating device per round.
    pub local_iters: usize,
    /// Communication rounds.
    pub comm_rounds: usize,
}

impl FlConfig {
    pub fn paper_default(base: RunConfig) -> FlConfig {
        // detlint: allow(R001) constructor precondition: a bad base config is a programming error
        base.validate().expect("base config invalid");
        FlConfig {
            base,
            num_devices: 50,
            participation: 0.2,
            classes_per_device: 5,
            local_iters: 3,
            comm_rounds: 60,
        }
    }
}

/// One simulated device: its data source plus local stream statistics.
struct FlDevice {
    source: Box<dyn DataSource>,
    /// Stream class frequencies |S_y| observed so far (Eq. 2's input).
    seen_per_class: Vec<u64>,
}

impl FlDevice {
    fn stream_round(&mut self, v: usize) -> Vec<Sample> {
        let arrivals = self.source.next_round(v);
        for s in &arrivals {
            self.seen_per_class[s.label as usize] += 1;
        }
        arrivals
    }
}

/// Builder for a federated run — the FL counterpart of the coordinator's
/// `SessionBuilder`: pluggable per-device data sources and per-comm-round
/// observers around one canonical FedAvg loop.
pub struct FlBuilder {
    cfg: FlConfig,
    sources: Option<Vec<Box<dyn DataSource>>>,
    observers: Vec<Box<dyn RoundObserver>>,
    policy: Box<dyn SchedPolicy>,
    fault_plan: Option<FaultPlan>,
    straggler_deadline: f64,
    /// (vault path, checkpoint cadence in comm rounds, generations kept).
    checkpoint: Option<(PathBuf, usize, usize)>,
    resume: bool,
}

impl FlBuilder {
    pub fn new(cfg: FlConfig) -> FlBuilder {
        FlBuilder {
            cfg,
            sources: None,
            observers: Vec::new(),
            policy: Box::new(RoundRobin::new()),
            fault_plan: None,
            straggler_deadline: 8.0,
            checkpoint: None,
            resume: false,
        }
    }

    /// Attach a deterministic per-device fault plan; validated at run.
    /// Cells are `(device, comm_round)`, consulted only for sampled
    /// devices: crash = dead for the rest of the run, transient /
    /// brown-out = dropped this round, straggler = cut iff its slowdown
    /// exceeds the [`FlBuilder::straggler_deadline`].
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Straggler tolerance (default 8×): an injected slowdown at or
    /// under the deadline is tolerated — it costs only simulated device
    /// time, which FL does not model — while a slower device misses the
    /// round deadline and is cut from aggregation.
    pub fn straggler_deadline(mut self, deadline: f64) -> Self {
        self.straggler_deadline = deadline;
        self
    }

    /// Checkpoint the federated run into a [`CheckpointVault`] at `path`
    /// every `every` comm rounds, keeping the newest `keep` generations.
    /// One capsule holds the whole deployment: global parameters, the
    /// orchestrator RNG, and every device's dispatch state — device
    /// streams are deterministic, so a resume fast-forwards them instead
    /// of persisting per-device buffers. Incompatible with retaining
    /// device sources (`store_bytes > 0`): a store's contents depend on
    /// model outputs at offer time, which a fast-forward cannot replay.
    pub fn checkpoint(mut self, path: impl Into<PathBuf>, every: usize, keep: usize) -> Self {
        self.checkpoint = Some((path.into(), every.max(1), keep.max(1)));
        self
    }

    /// Resume from the vault's newest valid generation when one exists
    /// (requires [`FlBuilder::checkpoint`]); fresh start otherwise. A
    /// degraded recovery — torn or corrupt newer generations skipped on
    /// the walk — is surfaced as the record's `recovery` telemetry.
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// Replace the default round-robin device-dispatch order. The policy
    /// sees per-device participation counts (`rounds_done`) and comm-round
    /// staleness; it reorders execution *within* each comm round — FedAvg
    /// aggregates the same participant set either way.
    pub fn policy(mut self, policy: impl SchedPolicy + 'static) -> Self {
        self.policy = Box::new(policy);
        self
    }

    /// Replace the default non-IID device partition with explicit
    /// per-device sources (must provide exactly `num_devices` of them).
    pub fn device_sources(mut self, sources: Vec<Box<dyn DataSource>>) -> Self {
        self.sources = Some(sources);
        self
    }

    /// Attach a per-communication-round observer. `on_round` fires each
    /// comm round (train-loss only — there is no device sim in FL),
    /// `on_eval` at each eval checkpoint; `Control::Stop` ends the run.
    pub fn observe(mut self, observer: impl RoundObserver + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Run the federated experiment; returns the global-model run record.
    pub fn run(self) -> Result<RunRecord> {
        Ok(self.run_with_faults()?.0)
    }

    /// [`FlBuilder::run`], also returning the per-comm-round fault log
    /// (one [`FlRoundFaults`] per executed round; every entry has an
    /// empty `dropped` list when no plan — or a zero-rate one — is
    /// attached).
    pub fn run_with_faults(self) -> Result<(RunRecord, Vec<FlRoundFaults>)> {
        let FlBuilder {
            cfg,
            sources,
            mut observers,
            mut policy,
            fault_plan,
            straggler_deadline,
            checkpoint,
            resume,
        } = self;
        if let Some(plan) = &fault_plan {
            plan.validate()?;
        }
        if resume && checkpoint.is_none() {
            return Err(Error::Config(
                "resume(true) requires a checkpoint() vault path".into(),
            ));
        }
        let base = &cfg.base;
        let task = SynthTask::for_model(&base.model, base.seed);
        let test = task.test_set(base.test_size, base.seed);
        let num_classes = task.num_classes();

        // device sources: explicit, or the paper's non-IID partition
        // (device d sees classes {d, d+1, .., d+k-1} mod C)
        let sources: Vec<Box<dyn DataSource>> = match sources {
            Some(s) => {
                if s.len() != cfg.num_devices {
                    return Err(Error::Config(format!(
                        "{} device sources for {} devices",
                        s.len(),
                        cfg.num_devices
                    )));
                }
                for (d, src) in s.iter().enumerate() {
                    if src.task().num_classes() != num_classes {
                        return Err(Error::Config(format!(
                            "device {d} source has {} classes, task has {num_classes}",
                            src.task().num_classes()
                        )));
                    }
                }
                s
            }
            None => {
                if cfg.classes_per_device > num_classes {
                    return Err(Error::Config(format!(
                        "classes_per_device {} > classes {}",
                        cfg.classes_per_device, num_classes
                    )));
                }
                (0..cfg.num_devices)
                    .map(|d| {
                        let classes: Vec<u32> = (0..cfg.classes_per_device)
                            .map(|i| ((d + i) % num_classes) as u32)
                            .collect();
                        // seed layout preserved from the pre-session
                        // orchestrator: each device's *stream* reproduces
                        // bit-for-bit (the global model additionally
                        // depends on the dispatch policy's execution
                        // order — see the module docs)
                        ClassSubsetSource::new(
                            task.clone(),
                            classes,
                            base.seed ^ (0xD0 + d as u64),
                        )
                        .map(|s| Box::new(s) as Box<dyn DataSource>)
                    })
                    .collect::<Result<Vec<_>>>()?
            }
        };
        // storage budget: each device keeps its own byte-budgeted store
        // (distinct policy/blend RNG streams per device), exactly the
        // session-layer wrapping — explicit sources that already retain
        // are left alone
        let sources: Vec<Box<dyn DataSource>> = sources
            .into_iter()
            .enumerate()
            .map(|(d, src)| {
                if base.store_bytes > 0 && !src.retains() {
                    Ok(Box::new(RetainedSource::new(
                        src,
                        base.store_bytes,
                        base.retention,
                        base.replay_mix,
                        base.seed ^ (0x2E7_0000 + d as u64),
                    )?) as Box<dyn DataSource>)
                } else {
                    Ok(src)
                }
            })
            .collect::<Result<Vec<_>>>()?;

        // the capsule persists only each device's dispatch count — enough
        // to fast-forward a deterministic stream, but a retention store's
        // contents depend on model outputs at offer time, which a resume
        // cannot replay; refuse rather than silently diverge
        if checkpoint.is_some() && sources.iter().any(|s| s.retains()) {
            return Err(Error::Config(
                "FL checkpointing does not support retaining device sources \
                 (set store_bytes = 0 and use non-retaining sources)"
                    .into(),
            ));
        }

        let mut rt = ModelRuntime::load(&base.artifacts_dir, &base.model, RuntimeRole::Full)?;
        let mut global = rt.set.init_params()?;
        let mut strategy = make_strategy(base.method, base.select_threads);
        let mut orchestrator_rng = Xoshiro256::seed_from_u64(base.seed ^ 0xF1_F1);

        let mut devices: Vec<FlDevice> = sources
            .into_iter()
            .map(|source| FlDevice {
                source,
                seen_per_class: vec![0; num_classes],
            })
            .collect();

        let mut record = RunRecord::new(base.method.name(), &base.model);
        let sw = Stopwatch::start();
        let per_round = (cfg.num_devices as f64 * cfg.participation).round().max(1.0) as usize;
        // host-scheduler bookkeeping: one TaskState per device
        // (rounds_done = participations; last_run = the comm round the
        // device last dispatched in, so staleness-in-comm-rounds is the
        // difference — no per-round aging pass over all devices)
        let mut dev_states = vec![TaskState::default(); cfg.num_devices];
        // devices an injected Crash permanently removed
        let mut dead = vec![false; cfg.num_devices];
        let mut fault_log: Vec<FlRoundFaults> = Vec::new();

        let fingerprint = fl_fingerprint(&cfg);
        let vault = checkpoint
            .as_ref()
            .map(|(path, every, keep)| (CheckpointVault::new(path, *keep), *every));
        let mut start_round = 0usize;
        if resume {
            if let Some((v, _)) = vault.as_ref() {
                if v.has_artifacts() {
                    let (win, telemetry) = v.load_latest_valid();
                    let win = win?;
                    let at = win.path.display().to_string();
                    let j = Json::parse(&win.text).map_err(|e| Error::Checkpoint {
                        path: at.clone(),
                        stage: "parse",
                        detail: e.to_string(),
                    })?;
                    let capsule = FlCapsule::from_json(&j).map_err(|e| Error::Checkpoint {
                        path: at.clone(),
                        stage: "field",
                        detail: e.to_string(),
                    })?;
                    // the frame codec already cross-checked the config
                    // fingerprint for framed generations; re-checking here
                    // also covers the unframed keep=1 / legacy layout
                    let want = fingerprint.to_string_compact();
                    let got = j.get("config").map(Json::to_string_compact).unwrap_or_default();
                    if got != want {
                        return Err(Error::Checkpoint {
                            path: at.clone(),
                            stage: "fingerprint",
                            detail: "capsule was written by a different FL configuration".into(),
                        });
                    }
                    if capsule.devices.len() != cfg.num_devices {
                        return Err(Error::Checkpoint {
                            path: at.clone(),
                            stage: "field",
                            detail: format!(
                                "capsule has {} devices, run has {}",
                                capsule.devices.len(),
                                cfg.num_devices
                            ),
                        });
                    }
                    if capsule.params.len() != global.len() {
                        return Err(Error::Checkpoint {
                            path: at,
                            stage: "field",
                            detail: format!(
                                "capsule has {} parameters, model has {}",
                                capsule.params.len(),
                                global.len()
                            ),
                        });
                    }
                    global = capsule.params;
                    orchestrator_rng = Xoshiro256::from_state(capsule.rng)?;
                    for (d, st) in capsule.devices.iter().enumerate() {
                        dev_states[d].rounds_done = st.rounds_done;
                        dev_states[d].last_run = st.last_run;
                        dead[d] = st.dead;
                        // fast-forward the device's deterministic stream:
                        // rounds_done counts dispatches, each of which
                        // consumed exactly one stream round — replaying
                        // them also recomputes seen_per_class exactly
                        for _ in 0..st.rounds_done {
                            devices[d].stream_round(base.stream_per_round);
                        }
                    }
                    record.curve = capsule.curve;
                    start_round = capsule.round;
                    if telemetry.degraded() {
                        record.recovery = Some(telemetry);
                    }
                }
            }
        }

        for round in start_round..cfg.comm_rounds {
            let chosen = orchestrator_rng.sample_indices(cfg.num_devices, per_round);
            // dropout filtering happens *after* sampling: the coordinator
            // samples blind (it cannot know who will fail), so the
            // sampling stream — and with a zero-rate plan the whole run —
            // is independent of the fault plan
            let mut dropped: Vec<(usize, &'static str)> = Vec::new();
            let mut survivors: Vec<usize> = Vec::with_capacity(chosen.len());
            for &d in &chosen {
                if dead[d] {
                    dropped.push((d, "crash"));
                    continue;
                }
                match fault_plan.as_ref().and_then(|p| p.fault_for(d, round)) {
                    Some(FaultKind::Crash) => {
                        dead[d] = true;
                        dropped.push((d, "crash"));
                    }
                    Some(FaultKind::Transient) => dropped.push((d, "transient")),
                    Some(FaultKind::EnergyBrownout { .. }) => dropped.push((d, "brownout")),
                    Some(FaultKind::Straggler { slowdown })
                        if slowdown > straggler_deadline =>
                    {
                        dropped.push((d, "straggler"));
                    }
                    // a tolerated straggler only costs simulated device
                    // time (unmodelled here); checkpoint corruption has
                    // no target in FL — both participate normally
                    _ => survivors.push(d),
                }
            }
            fault_log.push(FlRoundFaults { round, dropped, survivors: survivors.len() });
            let mut acc: Vec<f64> = vec![0.0; global.len()];
            let mut last_loss = 0.0f32;
            // this comm round's device work drains in policy order, not
            // sample order — the same dispatch seam the session Fleet uses
            let mut ready = survivors;
            ready.sort_unstable();
            let participants = ready.len();
            // (re)index the policy over this round's participants — a
            // picked device leaves the ready set, so no task_ran re-adds
            policy.prepare(&dev_states, &ready);
            while !ready.is_empty() {
                // shared validated dispatch (host::pick_validated): a
                // misbehaving custom policy errors instead of spinning
                // this loop forever in release builds
                let d = pick_validated(policy.as_mut(), &dev_states, &ready)?;
                ready.retain(|&x| x != d);
                dev_states[d].rounds_done += 1;
                // dispatched this comm round; a round-0 dispatch is
                // indistinguishable from "never ran" (both 0), exactly
                // the tie the old aging counters produced
                dev_states[d].last_run = round as u64;
                let dev = &mut devices[d];
                let arrivals = dev.stream_round(base.stream_per_round);
                // local selection over the device's stream
                let n = arrivals.len().min(rt.set.meta.cand_max);
                let refs: Vec<&Sample> = arrivals[..n].iter().collect();
                rt.set_params(global.clone())?;
                let importance = if base.method.needs_importance() {
                    Some(rt.importance(&refs)?)
                } else {
                    None
                };
                let probe = if base.method.needs_forward() {
                    Some(rt.probe(&refs)?)
                } else {
                    None
                };
                let ctx = SelectionContext {
                    samples: &refs,
                    seen_per_class: &dev.seen_per_class,
                    num_classes,
                    batch: base.batch_size,
                    importance: importance.as_ref(),
                    probe: probe.as_ref(),
                    features: None,
                    feature_dim: 0,
                };
                let sel = strategy.select(&ctx, &mut orchestrator_rng)?;
                let batch: Vec<&Sample> = sel.indices.iter().map(|&i| refs[i]).collect();
                // retention offer: the locally selected batch, scored by
                // its selection weights (the per-device analogue of the
                // session layer feeding coarse-filter scores)
                if dev.source.retains() {
                    let scored: Vec<Candidate> = sel
                        .indices
                        .iter()
                        .zip(&sel.weights)
                        .map(|(&i, &w)| Candidate { sample: refs[i].clone(), score: w as f64 })
                        .collect();
                    dev.source.offer_retention(scored);
                }
                // local training (weighted: unbiased estimator)
                for _ in 0..cfg.local_iters {
                    last_loss = rt.train_step_weighted(&batch, &sel.weights, base.lr)?;
                }
                for (a, &p) in acc.iter_mut().zip(rt.params()) {
                    *a += p as f64;
                }
            }
            // participation-weighted FedAvg: average over the devices
            // that actually reported (identical to the historical
            // all-participants average when nothing dropped); a
            // zero-survivor round leaves the global model untouched
            if participants > 0 {
                for (g, a) in global.iter_mut().zip(&acc) {
                    // detlint: allow(C001) params are f32 by the model contract; f64 only widens the average
                    *g = (a / participants as f64) as f32;
                }
            }

            let mut stop = false;
            let outcome = RoundOutcome {
                round,
                train_loss: last_loss,
                ..Default::default()
            };
            for obs in observers.iter_mut() {
                stop |= obs.on_round(&outcome) == Control::Stop;
            }
            // fleet-style aggregate over every retaining device; each
            // device's telemetry is cumulative, so the last comm round's
            // merge IS the run total (mirrors the session layer)
            let retention = devices.iter().filter_map(|d| d.source.retention_stats()).fold(
                None,
                |acc: Option<crate::retention::RetentionTelemetry>, t| {
                    let mut sum = acc.unwrap_or_default();
                    sum.merge(&t);
                    Some(sum)
                },
            );
            if let Some(t) = &retention {
                record.retention = Some(t.clone());
                for obs in observers.iter_mut() {
                    stop |= obs.on_retention(round, t) == Control::Stop;
                }
            }

            if base.eval_every > 0 && (round + 1) % base.eval_every == 0 {
                rt.set_params(global.clone())?;
                let rep = rt.evaluate(&test)?;
                let point = CurvePoint {
                    round: round + 1,
                    device_ms: 0.0,
                    host_ms: sw.elapsed_ms(),
                    train_loss: last_loss as f64,
                    test_loss: rep.loss,
                    test_accuracy: rep.accuracy,
                };
                for obs in observers.iter_mut() {
                    stop |= obs.on_eval(&point) == Control::Stop;
                }
                record.curve.push(point);
            }
            // durable capsule at cadence: everything the loop reads at
            // `round + 1` — written through the vault's atomic-rename +
            // generation-ring path, so a torn write can only cost the
            // replay back to an older intact generation, never the run
            if let Some((v, every)) = &vault {
                if (round + 1) % every == 0 {
                    let capsule = FlCapsule {
                        round: round + 1,
                        params: global.clone(),
                        rng: orchestrator_rng.state(),
                        devices: dev_states
                            .iter()
                            .zip(&dead)
                            .map(|(st, &is_dead)| FlDeviceState {
                                rounds_done: st.rounds_done,
                                last_run: st.last_run,
                                dead: is_dead,
                            })
                            .collect(),
                        curve: record.curve.clone(),
                    };
                    let payload = capsule.to_json(fingerprint.clone()).to_string_compact();
                    v.write(round + 1, &fingerprint.to_string_compact(), &payload)?;
                }
            }
            if stop {
                break;
            }
        }

        rt.set_params(global)?;
        let final_eval = rt.evaluate(&test)?;
        record.final_accuracy = final_eval.accuracy;
        record.total_host_ms = sw.elapsed_ms();
        Ok((record, fault_log))
    }
}

// ---- checkpoint capsule ---------------------------------------------------

/// FL-relevant configuration fingerprint embedded in every capsule as
/// its `config` value: everything the comm-round loop and the default
/// device partition read. Its compact serialization is also the vault
/// frame's fingerprint string, so the frame codec rejects a generation
/// written under a different configuration before the capsule is even
/// parsed.
fn fl_fingerprint(cfg: &FlConfig) -> Json {
    Json::obj(vec![
        ("titan_fl_checkpoint", Json::Num(1.0)),
        ("model", Json::Str(cfg.base.model.clone())),
        ("method", Json::Str(cfg.base.method.name().to_string())),
        ("seed", u64_to_json(cfg.base.seed)),
        ("num_devices", Json::Num(cfg.num_devices as f64)),
        ("participation", Json::Num(cfg.participation)),
        ("classes_per_device", Json::Num(cfg.classes_per_device as f64)),
        ("local_iters", Json::Num(cfg.local_iters as f64)),
        ("comm_rounds", Json::Num(cfg.comm_rounds as f64)),
        ("stream_per_round", Json::Num(cfg.base.stream_per_round as f64)),
        ("eval_every", Json::Num(cfg.base.eval_every as f64)),
    ])
}

/// One device's dispatch state inside a capsule. `rounds_done` doubles
/// as the stream fast-forward distance on resume: every dispatch
/// consumed exactly one stream round.
struct FlDeviceState {
    rounds_done: usize,
    last_run: u64,
    dead: bool,
}

/// Resumable mid-run state of a federated deployment — one capsule per
/// vault generation. The top-level `round` and `config` keys are load-
/// bearing: the vault frame codec cross-checks both against its header.
struct FlCapsule {
    /// Comm rounds completed when the capsule was written (the resume
    /// loop re-enters at this round).
    round: usize,
    params: Vec<f32>,
    /// Orchestrator RNG state (sampling + selection share this stream).
    rng: [u64; 4],
    devices: Vec<FlDeviceState>,
    curve: Vec<CurvePoint>,
}

impl FlCapsule {
    fn to_json(&self, fingerprint: Json) -> Json {
        Json::obj(vec![
            ("round", Json::Num(self.round as f64)),
            ("config", fingerprint),
            // f32 -> f64 -> f32 is lossless, so Num carries params bit-exactly
            ("params", Json::from_f32s(&self.params)),
            ("rng", words_to_json(&self.rng)),
            (
                "devices",
                Json::Arr(
                    self.devices
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("rounds_done", Json::Num(d.rounds_done as f64)),
                                ("last_run", u64_to_json(d.last_run)),
                                ("dead", Json::Bool(d.dead)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("curve", Json::Arr(self.curve.iter().map(|p| p.to_json()).collect())),
        ])
    }

    fn from_json(j: &Json) -> Result<FlCapsule> {
        let devices = j
            .get("devices")?
            .as_arr()?
            .iter()
            .map(|d| {
                Ok(FlDeviceState {
                    rounds_done: d.get("rounds_done")?.as_usize()?,
                    last_run: u64_from_json(d.get("last_run")?)?,
                    dead: d.get("dead")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let curve = j
            .get("curve")?
            .as_arr()?
            .iter()
            .map(CurvePoint::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(FlCapsule {
            round: j.get("round")?.as_usize()?,
            params: f32_list(j.get("params")?)?,
            rng: words_from_json(j.get("rng")?)?,
            devices,
            curve,
        })
    }
}

/// Fault activity of one federated communication round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlRoundFaults {
    /// The comm round.
    pub round: usize,
    /// Sampled devices that did not report, with the fault tag that
    /// dropped them ([`FaultKind::name`]); a permanently crashed device
    /// reappears here every round it is sampled in.
    pub dropped: Vec<(usize, &'static str)>,
    /// Sampled devices that reported and were aggregated.
    pub survivors: usize,
}

/// Run the FL experiment with the paper's default device partition;
/// returns the global-model run record.
pub fn run(cfg: &FlConfig) -> Result<RunRecord> {
    FlBuilder::new(cfg.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Method};
    use crate::coordinator::session::observers::EarlyStop;
    use crate::data::ReplaySource;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny_fl(method: Method) -> FlConfig {
        let mut base = presets::table1("mlp", method);
        base.test_size = 200;
        base.eval_every = 2;
        FlConfig {
            num_devices: 8,
            participation: 0.25,
            classes_per_device: 3,
            local_iters: 2,
            comm_rounds: 4,
            base,
        }
    }

    #[test]
    fn fl_round_trip() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let rec = run(&tiny_fl(Method::Rs)).unwrap();
        assert_eq!(rec.curve.len(), 2);
        assert!(rec.final_accuracy >= 0.0 && rec.final_accuracy <= 1.0);
    }

    #[test]
    fn fl_with_cis_selection() {
        if !have_artifacts() {
            return;
        }
        let rec = run(&tiny_fl(Method::Cis)).unwrap();
        assert!(rec.final_accuracy >= 0.0);
    }

    #[test]
    fn non_iid_partition_covers_all_classes() {
        let cfg = tiny_fl(Method::Rs);
        let num_classes = 6;
        let mut covered = vec![false; num_classes];
        for d in 0..cfg.num_devices {
            for i in 0..cfg.classes_per_device {
                covered[(d + i) % num_classes] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    // source/partition validation precedes artifact loading, so these
    // two need no artifact gate
    #[test]
    fn rejects_bad_partition() {
        let mut cfg = tiny_fl(Method::Rs);
        cfg.classes_per_device = 99;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn rejects_wrong_source_count() {
        let cfg = tiny_fl(Method::Rs);
        let task = SynthTask::for_model("mlp", cfg.base.seed);
        let one: Vec<Box<dyn DataSource>> = vec![Box::new(
            ClassSubsetSource::new(task, vec![0], 1).unwrap(),
        )];
        assert!(FlBuilder::new(cfg).device_sources(one).run().is_err());
    }

    /// Custom per-device data sources through the FL loop: each device
    /// replays a small captured pool (non-default `DataSource` impl).
    #[test]
    fn fl_with_replay_device_sources() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny_fl(Method::Rs);
        let task = SynthTask::for_model("mlp", cfg.base.seed);
        let sources: Vec<Box<dyn DataSource>> = (0..cfg.num_devices)
            .map(|d| {
                let mut sub = ClassSubsetSource::new(
                    task.clone(),
                    vec![(d % 6) as u32, ((d + 1) % 6) as u32],
                    100 + d as u64,
                )
                .unwrap();
                let replay =
                    ReplaySource::capture(&mut sub, cfg.base.stream_per_round).unwrap();
                Box::new(replay) as Box<dyn DataSource>
            })
            .collect();
        let rec = FlBuilder::new(cfg)
            .device_sources(sources)
            .run()
            .unwrap();
        assert_eq!(rec.curve.len(), 2);
        assert!(rec.final_accuracy.is_finite());
    }

    /// Device dispatch runs through the shared host-scheduler policies:
    /// non-default policies complete the identical comm-round structure
    /// (the policy reorders execution within a round, never membership).
    #[test]
    fn fl_dispatches_devices_under_every_policy() {
        if !have_artifacts() {
            return;
        }
        use crate::coordinator::host::{FewestRoundsFirst, StalenessPriority};
        let a = FlBuilder::new(tiny_fl(Method::Rs))
            .policy(FewestRoundsFirst::new())
            .run()
            .unwrap();
        let b = FlBuilder::new(tiny_fl(Method::Rs))
            .policy(StalenessPriority::new())
            .run()
            .unwrap();
        for rec in [&a, &b] {
            assert_eq!(rec.curve.len(), 2);
            assert!(rec.final_accuracy.is_finite());
        }
    }

    /// Storage budget in FL: each device keeps its own byte-budgeted
    /// store; the record carries the merged telemetry, and a zero budget
    /// reproduces the plain run bit-for-bit.
    #[test]
    fn fl_devices_retain_under_a_storage_budget() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = tiny_fl(Method::Rs);
        cfg.base.store_bytes = 1 << 14;
        cfg.base.replay_mix = 0.25;
        let rec = run(&cfg).unwrap();
        let t = rec.retention.as_ref().expect("budgeted FL run reports telemetry");
        assert!(t.offers > 0 && t.admits > 0, "devices offered and admitted: {t:?}");
        assert!(t.bytes_held > 0, "stores hold bytes at the end");

        // zero budget ≡ current behavior, bit for bit
        let plain = run(&tiny_fl(Method::Rs)).unwrap();
        let unbudgeted = run(&tiny_fl(Method::Rs)).unwrap();
        assert!(unbudgeted.retention.is_none());
        assert_eq!(plain.final_accuracy, unbudgeted.final_accuracy);
    }

    /// Observers hook the comm-round loop: an early stop at the first
    /// eval checkpoint halves the run.
    #[test]
    fn fl_observer_early_stop() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny_fl(Method::Rs); // eval_every = 2, comm_rounds = 4
        let rec = FlBuilder::new(cfg)
            .observe(EarlyStop::at_accuracy(0.0))
            .run()
            .unwrap();
        assert_eq!(rec.curve.len(), 1, "stopped at the first checkpoint");
        assert!(rec.final_accuracy.is_finite());
    }

    // bad fault plans are rejected before any artifact loading, so this
    // needs no artifact gate
    #[test]
    fn rejects_bad_fault_plan() {
        let mut plan = FaultPlan::new(1);
        plan.crash_rate = 2.0;
        let err = FlBuilder::new(tiny_fl(Method::Rs)).fault_plan(plan).run().unwrap_err();
        assert!(err.to_string().contains("outside [0, 1]"), "got: {err}");
    }

    /// Zero-rate-plan neutrality, FL flavor: attaching an all-zero plan
    /// must leave the run bit-identical to no plan at all, with an empty
    /// fault log every round.
    #[test]
    fn zero_rate_plan_is_bit_identical() {
        if !have_artifacts() {
            return;
        }
        let plain = FlBuilder::new(tiny_fl(Method::Rs)).run().unwrap();
        let (faulted, log) = FlBuilder::new(tiny_fl(Method::Rs))
            .fault_plan(FaultPlan::new(11))
            .run_with_faults()
            .unwrap();
        assert_eq!(plain.final_accuracy, faulted.final_accuracy);
        assert_eq!(plain.curve.len(), faulted.curve.len());
        for (a, b) in plain.curve.iter().zip(&faulted.curve) {
            assert_eq!(a.test_accuracy, b.test_accuracy);
            assert_eq!(a.test_loss, b.test_loss);
            assert_eq!(a.train_loss, b.train_loss);
        }
        assert_eq!(log.len(), 4);
        assert!(log.iter().all(|r| r.dropped.is_empty()));
    }

    /// Total dropout: with every sampled device crashing, FedAvg never
    /// updates and the global model stays at its deterministic init —
    /// the run completes instead of dividing by zero.
    #[test]
    fn total_dropout_freezes_the_global_model() {
        if !have_artifacts() {
            return;
        }
        let mut plan = FaultPlan::new(2);
        plan.crash_rate = 1.0;
        let (rec, log) = FlBuilder::new(tiny_fl(Method::Rs))
            .fault_plan(plan)
            .run_with_faults()
            .unwrap();
        assert!(log.iter().all(|r| r.survivors == 0 && !r.dropped.is_empty()));
        assert!(log.iter().flat_map(|r| &r.dropped).all(|&(_, kind)| kind == "crash"));
        // frozen model => every eval checkpoint sees identical accuracy
        assert!(rec.curve.windows(2).all(|w| w[0].test_accuracy == w[1].test_accuracy));
        assert!(rec.final_accuracy.is_finite());
    }

    /// The straggler deadline separates tolerated from cut slowdowns:
    /// a generous deadline reproduces the plain run bit-for-bit, a tight
    /// one drops every straggling device.
    #[test]
    fn straggler_deadline_gates_the_cut() {
        if !have_artifacts() {
            return;
        }
        let slow = |deadline: f64| {
            let cfg = tiny_fl(Method::Rs);
            let mut plan = FaultPlan::new(0);
            for d in 0..cfg.num_devices {
                plan = plan.script(d, 0, FaultKind::Straggler { slowdown: 16.0 });
            }
            FlBuilder::new(cfg)
                .fault_plan(plan)
                .straggler_deadline(deadline)
                .run_with_faults()
                .unwrap()
        };
        let plain = FlBuilder::new(tiny_fl(Method::Rs)).run().unwrap();
        let (tolerated, log) = slow(100.0);
        assert!(log.iter().all(|r| r.dropped.is_empty()));
        assert_eq!(plain.final_accuracy, tolerated.final_accuracy);
        let (_cut, log) = slow(2.0);
        assert!(!log[0].dropped.is_empty(), "16x stragglers at round 0 must miss a 2x deadline");
        assert!(log[0].dropped.iter().all(|&(_, kind)| kind == "straggler"));
        assert_eq!(log[0].survivors, 0);
        assert!(log[1..].iter().all(|r| r.dropped.is_empty()), "stragglers recover next round");
    }

    /// The default partition must match the pre-builder orchestrator's
    /// device streams (seed layout preserved): first arrivals of device 0
    /// come from classes {0,1,2} with the documented RNG stream.
    #[test]
    fn default_partition_streams_are_deterministic() {
        let cfg = tiny_fl(Method::Rs);
        let task = SynthTask::for_model("mlp", cfg.base.seed);
        let mut a =
            ClassSubsetSource::new(task.clone(), vec![0, 1, 2], cfg.base.seed ^ 0xD0).unwrap();
        let mut b =
            ClassSubsetSource::new(task, vec![0, 1, 2], cfg.base.seed ^ 0xD0).unwrap();
        let (ra, rb) = (a.next_round(20), b.next_round(20));
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.label, y.label);
            assert_eq!(*x.x, *y.x);
        }
        assert!(ra.iter().all(|s| s.label < 3));
    }

    /// Capsule codec round-trip: params bit-exactly (f32 -> f64 -> f32
    /// is lossless), RNG words at full 64-bit precision, device flags
    /// and the curve all survive compact JSON.
    #[test]
    fn fl_capsule_roundtrips_through_json() {
        let capsule = FlCapsule {
            round: 3,
            params: vec![0.125, -3.5, 1.0e-7, 0.300_000_01],
            rng: [u64::MAX, 1, 0xDEAD_BEEF_DEAD_BEEF, 42],
            devices: vec![
                FlDeviceState { rounds_done: 2, last_run: 1, dead: false },
                FlDeviceState { rounds_done: 0, last_run: 0, dead: true },
            ],
            curve: vec![CurvePoint {
                round: 2,
                device_ms: 0.0,
                host_ms: 12.5,
                train_loss: 0.75,
                test_loss: 1.25,
                test_accuracy: 0.5,
            }],
        };
        let fp = fl_fingerprint(&tiny_fl(Method::Rs));
        let text = capsule.to_json(fp.clone()).to_string_compact();
        let j = Json::parse(&text).unwrap();
        // the embedded config is the frame fingerprint, byte for byte
        assert_eq!(j.get("config").unwrap().to_string_compact(), fp.to_string_compact());
        assert_eq!(j.get("round").unwrap().as_usize().unwrap(), 3);
        let back = FlCapsule::from_json(&j).unwrap();
        assert_eq!(back.round, 3);
        assert_eq!(back.params, capsule.params);
        assert_eq!(back.rng, capsule.rng);
        assert_eq!(back.devices.len(), 2);
        assert_eq!(back.devices[0].rounds_done, 2);
        assert_eq!(back.devices[0].last_run, 1);
        assert!(!back.devices[0].dead && back.devices[1].dead);
        assert_eq!(back.curve.len(), 1);
        assert_eq!(back.curve[0].round, 2);
        assert_eq!(back.curve[0].test_accuracy, 0.5);
    }

    // both guards fire before any artifact loading, so no gate
    #[test]
    fn rejects_resume_without_checkpoint() {
        let err = FlBuilder::new(tiny_fl(Method::Rs)).resume(true).run().unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "got: {err}");
    }

    #[test]
    fn rejects_checkpoint_with_retaining_sources() {
        let mut cfg = tiny_fl(Method::Rs);
        cfg.base.store_bytes = 1 << 14;
        let dir = std::env::temp_dir().join("titan_fl_gate");
        let err = FlBuilder::new(cfg)
            .checkpoint(dir.join("fl.json"), 2, 2)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("retaining"), "got: {err}");
    }

    fn assert_curves_match(a: &RunRecord, b: &RunRecord) {
        assert_eq!(a.curve.len(), b.curve.len());
        for (x, y) in a.curve.iter().zip(&b.curve) {
            assert_eq!(x.round, y.round);
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_loss, y.test_loss);
            assert_eq!(x.test_accuracy, y.test_accuracy);
        }
        assert_eq!(a.final_accuracy, b.final_accuracy);
    }

    /// Kill/resume equivalence: a run halted at its first eval leaves a
    /// round-2 capsule behind; resuming fast-forwards the device streams,
    /// restores the orchestrator RNG, finishes rounds 2..4, and matches
    /// the uninterrupted run on every deterministic field.
    #[test]
    fn fl_checkpoint_resume_matches_uninterrupted() {
        if !have_artifacts() {
            return;
        }
        let dir = std::env::temp_dir().join("titan_fl_resume");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fl.json");
        let full = FlBuilder::new(tiny_fl(Method::Rs)).run().unwrap();
        let halted = FlBuilder::new(tiny_fl(Method::Rs))
            .checkpoint(&path, 2, 2)
            .observe(EarlyStop::at_accuracy(0.0))
            .run()
            .unwrap();
        assert_eq!(halted.curve.len(), 1, "died at the first checkpoint");
        let resumed = FlBuilder::new(tiny_fl(Method::Rs))
            .checkpoint(&path, 2, 2)
            .resume(true)
            .run()
            .unwrap();
        assert!(resumed.recovery.is_none(), "a clean resume is not degraded");
        assert_curves_match(&full, &resumed);
        // resume with nothing on disk is a fresh start, not an error
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let fresh = FlBuilder::new(tiny_fl(Method::Rs))
            .checkpoint(&path, 2, 2)
            .resume(true)
            .run()
            .unwrap();
        assert_curves_match(&full, &fresh);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The vault seam under FL: tear the newest generation (g2, comm
    /// round 4) mid-payload; the resume walk rejects it, falls back to
    /// g1 (comm round 2), replays the lost rounds to the identical
    /// record, and reports the degradation as recovery telemetry.
    #[test]
    fn fl_torn_generation_falls_back_and_recovers() {
        if !have_artifacts() {
            return;
        }
        let dir = std::env::temp_dir().join("titan_fl_torn");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fl.json");
        let full = FlBuilder::new(tiny_fl(Method::Rs))
            .checkpoint(&path, 2, 2)
            .run()
            .unwrap();
        let g2 = CheckpointVault::new(&path, 2).generation_path(2);
        let len = std::fs::metadata(&g2).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&g2).unwrap();
        f.set_len(len / 2).unwrap();
        let resumed = FlBuilder::new(tiny_fl(Method::Rs))
            .checkpoint(&path, 2, 2)
            .resume(true)
            .run()
            .unwrap();
        let rec = resumed.recovery.as_ref().expect("a torn walk is degraded");
        assert_eq!(rec.frames_scanned, 2);
        assert_eq!(rec.torn_frames, 1);
        assert_eq!(rec.crc_failures, 0);
        assert_eq!(rec.generation_used, 1);
        assert_eq!(rec.rounds_lost, 2, "round-4 capsule lost, round-2 generation used");
        assert_curves_match(&full, &resumed);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
