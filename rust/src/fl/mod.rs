//! Federated-learning orchestration (paper Appendix B, Fig. 10).
//!
//! Setting: 50 devices, non-IID local distributions (each device's stream
//! covers only 5 of the task's classes), 20% participation per round,
//! 3 local SGD iterations per selected device, FedAvg aggregation.
//! Each device runs the configured data-selection method locally over its
//! own stream before training — Titan's selection plugs in per-device.
//!
//! Implementation note: devices share one `ModelRuntime` (Full role) and
//! swap parameter vectors in/out — functionally identical to 50 separate
//! processes, and the only tractable layout on a one-core host.

use crate::config::RunConfig;
use crate::data::{Sample, SynthTask};
use crate::metrics::{CurvePoint, RunRecord};
use crate::runtime::model::{ModelRuntime, RuntimeRole};
use crate::selection::{make_strategy, SelectionContext};
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};

/// FL experiment configuration on top of a base RunConfig.
#[derive(Clone, Debug)]
pub struct FlConfig {
    pub base: RunConfig,
    pub num_devices: usize,
    /// Fraction of devices participating per round.
    pub participation: f64,
    /// Classes visible to each device's stream.
    pub classes_per_device: usize,
    /// Local SGD iterations per participating device per round.
    pub local_iters: usize,
    /// Communication rounds.
    pub comm_rounds: usize,
}

impl FlConfig {
    pub fn paper_default(base: RunConfig) -> FlConfig {
        base.validate().expect("base config invalid");
        FlConfig {
            base,
            num_devices: 50,
            participation: 0.2,
            classes_per_device: 5,
            local_iters: 3,
            comm_rounds: 60,
        }
    }
}

/// One simulated device.
struct FlDevice {
    /// Class subset this device's stream draws from (non-IID).
    classes: Vec<u32>,
    seen_per_class: Vec<u64>,
    rng: Xoshiro256,
    next_id: u64,
}

impl FlDevice {
    fn stream_round(&mut self, task: &SynthTask, v: usize) -> Vec<Sample> {
        (0..v)
            .map(|_| {
                let y = self.classes[self.rng.index(self.classes.len())];
                let id = self.next_id;
                self.next_id += 1;
                let s = task.draw_class(id, y, &mut self.rng);
                self.seen_per_class[y as usize] += 1;
                s
            })
            .collect()
    }
}

/// Run the FL experiment; returns the global-model run record.
pub fn run(cfg: &FlConfig) -> Result<RunRecord> {
    let base = &cfg.base;
    let task = SynthTask::for_model(&base.model, base.seed);
    let test = task.test_set(base.test_size, base.seed);
    let num_classes = task.num_classes();
    if cfg.classes_per_device > num_classes {
        return Err(Error::Config(format!(
            "classes_per_device {} > classes {}",
            cfg.classes_per_device, num_classes
        )));
    }

    let mut rt = ModelRuntime::load(&base.artifacts_dir, &base.model, RuntimeRole::Full)?;
    let mut global = rt.set.init_params()?;
    let mut strategy = make_strategy(base.method);
    let mut orchestrator_rng = Xoshiro256::seed_from_u64(base.seed ^ 0xF1_F1);

    // non-IID partition: device d sees classes {d, d+1, .., d+k-1} mod C
    let mut devices: Vec<FlDevice> = (0..cfg.num_devices)
        .map(|d| FlDevice {
            classes: (0..cfg.classes_per_device)
                .map(|i| ((d + i) % num_classes) as u32)
                .collect(),
            seen_per_class: vec![0; num_classes],
            rng: Xoshiro256::seed_from_u64(base.seed ^ (0xD0 + d as u64)),
            next_id: 0,
        })
        .collect();

    let mut record = RunRecord::new(base.method.name(), &base.model);
    let sw = Stopwatch::start();
    let per_round = (cfg.num_devices as f64 * cfg.participation).round().max(1.0) as usize;

    for round in 0..cfg.comm_rounds {
        let chosen = orchestrator_rng.sample_indices(cfg.num_devices, per_round);
        let mut acc: Vec<f64> = vec![0.0; global.len()];
        let mut last_loss = 0.0f32;
        for &d in &chosen {
            let dev = &mut devices[d];
            let arrivals = dev.stream_round(&task, base.stream_per_round);
            // local selection over the device's stream
            let n = arrivals.len().min(rt.set.meta.cand_max);
            let refs: Vec<&Sample> = arrivals[..n].iter().collect();
            rt.set_params(global.clone())?;
            let importance = if base.method.needs_importance() {
                Some(rt.importance(&refs)?)
            } else {
                None
            };
            let probe = if base.method.needs_forward() {
                Some(rt.probe(&refs)?)
            } else {
                None
            };
            let ctx = SelectionContext {
                samples: &refs,
                seen_per_class: &dev.seen_per_class,
                num_classes,
                batch: base.batch_size,
                importance: importance.as_ref(),
                probe: probe.as_ref(),
                features: None,
                feature_dim: 0,
            };
            let sel = strategy.select(&ctx, &mut orchestrator_rng)?;
            let batch: Vec<&Sample> = sel.indices.iter().map(|&i| refs[i]).collect();
            // local training (weighted: unbiased estimator)
            for _ in 0..cfg.local_iters {
                last_loss = rt.train_step_weighted(&batch, &sel.weights, base.lr)?;
            }
            for (a, &p) in acc.iter_mut().zip(rt.params()) {
                *a += p as f64;
            }
        }
        // FedAvg
        for (g, a) in global.iter_mut().zip(&acc) {
            *g = (a / chosen.len() as f64) as f32;
        }

        if base.eval_every > 0 && (round + 1) % base.eval_every == 0 {
            rt.set_params(global.clone())?;
            let rep = rt.evaluate(&test)?;
            record.curve.push(CurvePoint {
                round: round + 1,
                device_ms: 0.0,
                host_ms: sw.elapsed_ms(),
                train_loss: last_loss as f64,
                test_loss: rep.loss,
                test_accuracy: rep.accuracy,
            });
        }
    }

    rt.set_params(global)?;
    let final_eval = rt.evaluate(&test)?;
    record.final_accuracy = final_eval.accuracy;
    record.total_host_ms = sw.elapsed_ms();
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Method};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny_fl(method: Method) -> FlConfig {
        let mut base = presets::table1("mlp", method);
        base.test_size = 200;
        base.eval_every = 2;
        FlConfig {
            num_devices: 8,
            participation: 0.25,
            classes_per_device: 3,
            local_iters: 2,
            comm_rounds: 4,
            base,
        }
    }

    #[test]
    fn fl_round_trip() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let rec = run(&tiny_fl(Method::Rs)).unwrap();
        assert_eq!(rec.curve.len(), 2);
        assert!(rec.final_accuracy >= 0.0 && rec.final_accuracy <= 1.0);
    }

    #[test]
    fn fl_with_cis_selection() {
        if !have_artifacts() {
            return;
        }
        let rec = run(&tiny_fl(Method::Cis)).unwrap();
        assert!(rec.final_accuracy >= 0.0);
    }

    #[test]
    fn non_iid_partition_covers_all_classes() {
        let cfg = tiny_fl(Method::Rs);
        let num_classes = 6;
        let mut covered = vec![false; num_classes];
        for d in 0..cfg.num_devices {
            for i in 0..cfg.classes_per_device {
                covered[(d + i) % num_classes] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn rejects_bad_partition() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = tiny_fl(Method::Rs);
        cfg.classes_per_device = 99;
        assert!(run(&cfg).is_err());
    }
}
