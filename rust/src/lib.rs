//! # Titan — two-stage data selection for on-device training
//!
//! Rust L3 coordinator reproducing *"A Two-Stage Data Selection Framework
//! for Data-Efficient Model Training on Edge Devices"* (KDD '25).
//!
//! The crate owns everything on the request path: the streaming source,
//! the coarse-grained filter, the fine-grained C-IS selector, the training
//! pipeline, the device/energy simulator, the federated orchestrator, the
//! metrics plane, and the experiment harness that regenerates every table
//! and figure of the paper. Model compute (training steps, feature
//! extraction, importance scoring) executes AOT-compiled XLA artifacts
//! produced once by the python build path (`python/compile/aot.py`) via
//! the PJRT CPU client — python is never on this path.
//!
//! Layout:
//! - [`util`] — substrates replacing unavailable crates (PRNG, JSON, CLI,
//!   stats, micro-bench, mini property testing, logging, sync cells).
//! - [`config`] — experiment/run configuration.
//! - [`data`] — synthetic tasks, the pluggable [`data::DataSource`] seam
//!   (stream / replay / non-IID class-subset sources), stores and buffers.
//! - [`runtime`] — PJRT artifact loading and typed model execution.
//! - [`selection`] — C-IS and all paper baselines (RS/IS/LL/HL/CE/OCS/Camel).
//! - [`filter`] — the coarse-grained first stage.
//! - [`coordinator`] — the session API: `SessionBuilder` → `Session`, a
//!   step-driven state machine over one canonical round loop (sequential
//!   or pipelined `ExecBackend`, `RoundObserver` hooks), the
//!   [`coordinator::host`] fleet runtime that interleaves many sessions
//!   round-by-round under pluggable scheduling policies, and
//!   [`coordinator::snapshot`] crash-safe checkpoints — a killed session
//!   or fleet resumes byte-identically from its last on-disk snapshot;
//!   `sequential`/`pipeline` remain as deprecated shims.
//! - [`device`] — edge-device timing, memory and energy simulation.
//! - [`fault`] — the deterministic fault-injection plane: seeded
//!   [`fault::FaultPlan`]s (crash / transient / straggler / brown-out /
//!   checkpoint-corruption) and the fleet's [`fault::SupervisionPolicy`]
//!   (fail-fast / isolate / restart).
//! - [`fl`] — federated-learning orchestration (paper Appendix B), built
//!   on the same data-source/observer seams via `fl::FlBuilder`.
//! - [`retention`] — the **third selection stage**: a byte-budgeted
//!   persistent [`retention::SampleStore`] with pluggable
//!   [`retention::RetentionPolicy`]s (score-weighted / class-balanced /
//!   reservoir) deciding what to keep across rounds; wired into sessions
//!   via [`data::RetainedSource`] and the `--store-bytes` config surface.
//! - [`metrics`] — trackers and result emission.
//! - [`exp`] — one module per paper table/figure, all driving sessions.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod exp;
pub mod fault;
pub mod filter;
pub mod fl;
pub mod metrics;
pub mod retention;
pub mod runtime;
pub mod selection;
pub mod util;

pub use config::RunConfig;

/// Crate-wide error type. Everything fallible funnels into this.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),
    #[error("XLA/PJRT error: {0}")]
    Xla(String),
    #[error("JSON error: {0}")]
    Json(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("data error: {0}")]
    Data(String),
    #[error("checkpoint {path}: {stage}: {detail}")]
    Checkpoint {
        /// The snapshot file that failed to load.
        path: String,
        /// Which stage failed: "read", "parse", "version", "field" or
        /// "fingerprint".
        stage: &'static str,
        detail: String,
    },
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("pipeline error: {0}")]
    Pipeline(String),
    #[error("scheduler error: {0}")]
    Sched(String),
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
