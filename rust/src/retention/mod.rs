//! Retention plane — Titan's **third selection stage**.
//!
//! The paper's two stages (coarse filter, fine C-IS selection) choose
//! from the *current* stream window; this module decides what to **keep**
//! across rounds under a hard on-device storage budget ("To Store or
//! Not?", PAPERS.md). A [`SampleStore`] holds already-seen samples under
//! a byte budget; a pluggable [`RetentionPolicy`] picks eviction victims
//! when an admit would overflow it:
//!
//! | policy | admits by evicting | keeps |
//! |---|---|---|
//! | [`ScoreWeighted`] | the lowest filter-stage score (ties: largest id) | the all-time top scorers |
//! | [`ClassBalanced`] | from the most-overrepresented class | a class-uniform recent set |
//! | [`Reservoir`] | a seeded uniform slot (Algorithm R) | an unbiased stream sample |
//!
//! `ScoreWeighted` consumes the scores the [`crate::filter::CoarseFilter`]
//! already computed for its candidates, which is what makes retention a
//! genuine third stage rather than a second cache. `ClassBalanced`
//! supersedes the fixed `cap_per_class` of [`crate::data::ClassStore`]
//! with a budget-relative balance. `Reservoir` is the baseline: a
//! deterministic ([`Xoshiro256`]-seeded) uniform sample of everything
//! offered.
//!
//! Everything here is deterministic and checkpointable: same seed + same
//! budget ⇒ identical store contents and [`RetentionTelemetry`], including
//! across a kill/resume ([`RetentionState`] travels inside the session
//! snapshot). The store itself never touches the model or the clock.
//!
//! Cost model (see PERF.md): the store is a flat insertion-ordered `Vec`
//! with an id → slot hash index on the side. Duplicate detection and
//! score refresh are O(1) lookups; only under byte pressure does an admit
//! pay O(n) — one victim scan per evicted entry, plus one index rebuild
//! after the eviction compaction (eviction shifts every later slot). The
//! index matters for the fleet host, where thousands of concurrent
//! sessions each offer every round: the old O(n) duplicate scan per offer
//! was the store's only per-offer term that grew with capacity.

use crate::data::buffer::Candidate;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// Modelled per-sample metadata overhead on top of the raw feature bytes:
/// id (8) + label (4) + clean label (4) + retained score (8) + length
/// header (8). The budget charges what a serialized store entry costs,
/// not Rust's in-memory `Arc` bookkeeping.
pub const SAMPLE_OVERHEAD_BYTES: usize = 32;

/// Byte cost of retaining one sample of `dim` f32 features.
pub fn sample_cost(dim: usize) -> usize {
    dim * std::mem::size_of::<f32>() + SAMPLE_OVERHEAD_BYTES
}

/// Which retention policy a store runs (config/CLI surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetentionKind {
    /// Evict the lowest filter-stage score ([`ScoreWeighted`]).
    Score,
    /// Evict from the most-overrepresented class ([`ClassBalanced`]).
    Balanced,
    /// Seeded uniform reservoir baseline ([`Reservoir`]).
    Reservoir,
}

impl RetentionKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "score" => Ok(RetentionKind::Score),
            "balanced" => Ok(RetentionKind::Balanced),
            "reservoir" => Ok(RetentionKind::Reservoir),
            other => Err(Error::Config(format!(
                "unknown retention policy {other:?} (expected score|balanced|reservoir)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RetentionKind::Score => "score",
            RetentionKind::Balanced => "balanced",
            RetentionKind::Reservoir => "reservoir",
        }
    }

    /// Construct the policy this kind names. `seed` feeds the reservoir
    /// RNG; the other policies are stateless and ignore it.
    pub fn policy(self, seed: u64) -> Box<dyn RetentionPolicy> {
        match self {
            RetentionKind::Score => Box::new(ScoreWeighted),
            RetentionKind::Balanced => Box::new(ClassBalanced),
            RetentionKind::Reservoir => Box::new(Reservoir::new(seed)),
        }
    }
}

/// Serialized policy state. Only [`Reservoir`] carries any: its RNG words
/// and the stream-position counter Algorithm R draws against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicyState {
    pub rng: [u64; 4],
    pub seen: u64,
}

/// Cumulative retention counters — the telemetry surface that rides
/// `SelectorReport` per round and lands in `RunRecord`/`FleetRecord`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RetentionTelemetry {
    /// Candidates offered to the store (admits + refreshes + rejects).
    pub offers: u64,
    /// Offers admitted as new entries.
    pub admits: u64,
    /// Offers whose id was already retained (score refreshed in place).
    pub refreshes: u64,
    /// Offers turned away (budget, policy verdict, oversize, bad label,
    /// non-finite score).
    pub rejects: u64,
    /// Evictions charged to [`ScoreWeighted`].
    pub evicts_score: u64,
    /// Evictions charged to [`ClassBalanced`].
    pub evicts_balanced: u64,
    /// Evictions charged to [`Reservoir`].
    pub evicts_reservoir: u64,
    /// Bytes currently held (latest value, not a sum).
    pub bytes_held: u64,
    /// Samples emitted into training rounds from the store.
    pub retained_emitted: u64,
    /// Total samples emitted into training rounds (retained + fresh).
    pub emitted_total: u64,
}

impl RetentionTelemetry {
    pub fn evicts_total(&self) -> u64 {
        self.evicts_score + self.evicts_balanced + self.evicts_reservoir
    }

    /// Retained-batch hit rate: fraction of emitted training samples that
    /// came out of the store rather than the fresh stream.
    pub fn hit_rate(&self) -> f64 {
        if self.emitted_total == 0 {
            0.0
        } else {
            self.retained_emitted as f64 / self.emitted_total as f64
        }
    }

    fn bump_evict(&mut self, kind: RetentionKind) {
        match kind {
            RetentionKind::Score => self.evicts_score += 1,
            RetentionKind::Balanced => self.evicts_balanced += 1,
            RetentionKind::Reservoir => self.evicts_reservoir += 1,
        }
    }

    /// Component-wise sum (fleet aggregation; `bytes_held` sums too — the
    /// aggregate reads as total bytes held across members).
    pub fn merge(&mut self, other: &RetentionTelemetry) {
        self.offers += other.offers;
        self.admits += other.admits;
        self.refreshes += other.refreshes;
        self.rejects += other.rejects;
        self.evicts_score += other.evicts_score;
        self.evicts_balanced += other.evicts_balanced;
        self.evicts_reservoir += other.evicts_reservoir;
        self.bytes_held += other.bytes_held;
        self.retained_emitted += other.retained_emitted;
        self.emitted_total += other.emitted_total;
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("offers", Json::Num(self.offers as f64)),
            ("admits", Json::Num(self.admits as f64)),
            ("refreshes", Json::Num(self.refreshes as f64)),
            ("rejects", Json::Num(self.rejects as f64)),
            (
                "evicts",
                Json::obj(vec![
                    ("score", Json::Num(self.evicts_score as f64)),
                    ("balanced", Json::Num(self.evicts_balanced as f64)),
                    ("reservoir", Json::Num(self.evicts_reservoir as f64)),
                ]),
            ),
            ("bytes_held", Json::Num(self.bytes_held as f64)),
            ("retained_emitted", Json::Num(self.retained_emitted as f64)),
            ("emitted_total", Json::Num(self.emitted_total as f64)),
            // derived, emitted for human/tooling consumption; from_json
            // recomputes it from the counters
            ("hit_rate", Json::Num(self.hit_rate())),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Result<RetentionTelemetry> {
        let evicts = j.get("evicts")?;
        Ok(RetentionTelemetry {
            offers: j.get("offers")?.as_usize()? as u64,
            admits: j.get("admits")?.as_usize()? as u64,
            refreshes: j.get("refreshes")?.as_usize()? as u64,
            rejects: j.get("rejects")?.as_usize()? as u64,
            evicts_score: evicts.get("score")?.as_usize()? as u64,
            evicts_balanced: evicts.get("balanced")?.as_usize()? as u64,
            evicts_reservoir: evicts.get("reservoir")?.as_usize()? as u64,
            bytes_held: j.get("bytes_held")?.as_usize()? as u64,
            retained_emitted: j.get("retained_emitted")?.as_usize()? as u64,
            emitted_total: j.get("emitted_total")?.as_usize()? as u64,
        })
    }
}

/// Everything a retaining [`crate::data::DataSource`] must carry through
/// a checkpoint to resume bit-identically: the store contents in slot
/// order, the cumulative telemetry, the policy state (reservoir RNG +
/// counter), and the source's blend RNG (the draw stream that picks which
/// retained samples each round replays).
#[derive(Clone, Debug, PartialEq)]
pub struct RetentionState {
    pub entries: Vec<Candidate>,
    pub telemetry: RetentionTelemetry,
    pub policy: Option<PolicyState>,
    pub blend_rng: [u64; 4],
}

/// Outcome of one [`SampleStore::offer`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offer {
    /// Stored as a new entry (possibly after evictions).
    Admitted,
    /// The id was already retained; its score was updated in place.
    Refreshed,
    /// Turned away — the entry never entered the store.
    Rejected,
}

/// Eviction decision seam. Policies see the store in slot (admission)
/// order and pick victims one at a time; the store only applies the
/// evictions once enough bytes are freed, so a rejected offer leaves the
/// entries untouched (policy RNG state still advances — that is what
/// keeps two same-seed runs aligned regardless of outcome).
pub trait RetentionPolicy: Send {
    /// Which [`RetentionKind`] this policy implements (telemetry key).
    fn kind(&self) -> RetentionKind;

    /// Per-offer bookkeeping, called once per non-refresh offer *before*
    /// any victim query (the reservoir stream counter).
    fn on_offer(&mut self) {}

    /// Pick the next victim slot among `entries`, skipping slots already
    /// in `excluded` (sorted ascending), to make room for `incoming`.
    /// `None` rejects the incoming candidate instead.
    fn victim(
        &mut self,
        entries: &[Candidate],
        excluded: &[usize],
        num_classes: usize,
        incoming: &Candidate,
    ) -> Option<usize>;

    /// Serialized policy state; stateless policies return `None`.
    fn export(&self) -> Option<PolicyState> {
        None
    }

    /// Restore from [`RetentionPolicy::export`]'s output. The default
    /// (stateless) impl accepts only `None`.
    fn restore(&mut self, st: Option<PolicyState>) -> Result<()> {
        match st {
            None => Ok(()),
            Some(_) => Err(Error::Data(format!(
                "retention policy {:?} is stateless but the snapshot carries policy state",
                self.kind()
            ))),
        }
    }
}

/// Is `a` evicted before `b`? The pinned eviction order of
/// [`ScoreWeighted`]: score **ascending**, id **descending** within score
/// ties — among equal scores the largest id goes first, so the incoming
/// candidate (always the newest, largest id) loses ties against anything
/// already stored and the surviving set is arrival-independent. This
/// mirrors the tie discipline [`crate::data::CandidateBuffer`] pins for
/// its cuts (`score_weighted_tie_break_is_pinned` regression-tests it).
fn evict_before(a: &Candidate, b: &Candidate) -> bool {
    a.score < b.score || (a.score == b.score && a.sample.id > b.sample.id)
}

/// Keep the all-time best filter scores: the victim is the worst stored
/// entry under [`evict_before`], and an incoming candidate that is itself
/// the worst is rejected. With equal-size samples the surviving set is
/// exactly the top-`capacity` offers by (score desc, id asc), whatever
/// order they arrived in.
pub struct ScoreWeighted;

impl RetentionPolicy for ScoreWeighted {
    fn kind(&self) -> RetentionKind {
        RetentionKind::Score
    }

    fn victim(
        &mut self,
        entries: &[Candidate],
        excluded: &[usize],
        _num_classes: usize,
        incoming: &Candidate,
    ) -> Option<usize> {
        let mut worst: Option<usize> = None;
        for (i, e) in entries.iter().enumerate() {
            if excluded.binary_search(&i).is_ok() {
                continue;
            }
            let worse = match worst {
                None => true,
                Some(w) => evict_before(e, &entries[w]),
            };
            if worse {
                worst = Some(i);
            }
        }
        let w = worst?;
        if evict_before(&entries[w], incoming) {
            Some(w)
        } else {
            None
        }
    }
}

/// Keep the classes level: the victim comes from the class with the most
/// stored entries (ties: smallest class index), and within that class the
/// lowest score goes first (ties: smallest id). Always admits while
/// anything is stored — the store churns toward a class-uniform,
/// recency-biased set, superseding `ClassStore`'s fixed `cap_per_class`
/// with a budget-relative balance.
pub struct ClassBalanced;

impl RetentionPolicy for ClassBalanced {
    fn kind(&self) -> RetentionKind {
        RetentionKind::Balanced
    }

    fn victim(
        &mut self,
        entries: &[Candidate],
        excluded: &[usize],
        num_classes: usize,
        _incoming: &Candidate,
    ) -> Option<usize> {
        let mut counts = vec![0usize; num_classes];
        for (i, e) in entries.iter().enumerate() {
            if excluded.binary_search(&i).is_ok() {
                continue;
            }
            counts[e.sample.label as usize] += 1;
        }
        // most-overrepresented class; strict > keeps the smallest index
        // on ties
        let mut cls: Option<usize> = None;
        let mut best = 0usize;
        for (c, &n) in counts.iter().enumerate() {
            if n > best {
                best = n;
                cls = Some(c);
            }
        }
        let cls = cls?;
        let mut pick: Option<usize> = None;
        for (i, e) in entries.iter().enumerate() {
            if e.sample.label as usize != cls || excluded.binary_search(&i).is_ok() {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => {
                    let q = &entries[p];
                    e.score < q.score || (e.score == q.score && e.sample.id < q.sample.id)
                }
            };
            if better {
                pick = Some(i);
            }
        }
        pick
    }
}

/// Seeded uniform reservoir (Algorithm R adapted to slot eviction): the
/// `i`-th non-refresh offer draws `j ∈ [0, i)`; if `j` lands on a live
/// slot, that slot is evicted and the offer admitted (appended at the
/// end), else the offer is rejected. Eviction slots are uniform over the
/// residents, so membership stays a uniform sample of the offer stream —
/// `reservoir_matches_brute_force_oracle` pins the exact retained set
/// against an independent re-implementation, and the frequency test
/// checks per-class uniformity over 10k offers.
pub struct Reservoir {
    rng: Xoshiro256,
    /// Non-refresh offers observed so far (Algorithm R's stream index).
    seen: u64,
}

impl Reservoir {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            seen: 0,
        }
    }
}

impl RetentionPolicy for Reservoir {
    fn kind(&self) -> RetentionKind {
        RetentionKind::Reservoir
    }

    fn on_offer(&mut self) {
        self.seen += 1;
    }

    fn victim(
        &mut self,
        entries: &[Candidate],
        excluded: &[usize],
        _num_classes: usize,
        _incoming: &Candidate,
    ) -> Option<usize> {
        let live = entries.len() - excluded.len();
        if live == 0 || self.seen == 0 {
            return None;
        }
        let j = self.rng.next_below(self.seen);
        if (j as usize) >= live {
            return None;
        }
        // map j onto the j-th live (non-excluded) slot
        let mut k = j as usize;
        for i in 0..entries.len() {
            if excluded.binary_search(&i).is_ok() {
                continue;
            }
            if k == 0 {
                return Some(i);
            }
            k -= 1;
        }
        None // unreachable: live > j was checked above
    }

    fn export(&self) -> Option<PolicyState> {
        Some(PolicyState {
            rng: self.rng.state(),
            seen: self.seen,
        })
    }

    fn restore(&mut self, st: Option<PolicyState>) -> Result<()> {
        let st = st.ok_or_else(|| {
            Error::Data("reservoir retention needs policy state in the snapshot".into())
        })?;
        self.rng = Xoshiro256::from_state(st.rng)?;
        self.seen = st.seen;
        Ok(())
    }
}

/// The byte-budgeted persistent sample store. Entries are kept in
/// admission order (the slot order policies and snapshots see); the
/// budget is checked on every admit with [`sample_cost`] per entry. A
/// sample-id → slot hash index rides alongside `entries` for O(1)
/// duplicate detection and refresh; the `Vec` stays the source of truth
/// (the index is derived state, rebuilt wholesale after any slot-shifting
/// mutation).
pub struct SampleStore {
    budget: usize,
    num_classes: usize,
    entries: Vec<Candidate>,
    /// sample id → slot in `entries`. Invariant: `index[entries[i].id] ==
    /// i` for every slot, and the two have equal lengths (ids are unique).
    index: std::collections::HashMap<u64, usize>,
    bytes: usize,
    policy: Box<dyn RetentionPolicy>,
    telemetry: RetentionTelemetry,
}

impl SampleStore {
    pub fn new(budget_bytes: usize, num_classes: usize, kind: RetentionKind, seed: u64) -> Self {
        Self {
            budget: budget_bytes,
            num_classes,
            entries: Vec::new(),
            index: std::collections::HashMap::new(),
            bytes: 0,
            policy: kind.policy(seed),
            telemetry: RetentionTelemetry::default(),
        }
    }

    /// Recompute the id → slot index from `entries` — after evictions
    /// (removal shifts every later slot) and restores. O(n), but both
    /// callers already paid O(n) for the mutation itself.
    fn rebuild_index(&mut self) {
        self.index.clear();
        self.index
            .extend(self.entries.iter().enumerate().map(|(i, e)| (e.sample.id, i)));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes_held(&self) -> usize {
        self.bytes
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn kind(&self) -> RetentionKind {
        self.policy.kind()
    }

    /// Retained entries in slot (admission) order.
    pub fn entries(&self) -> &[Candidate] {
        &self.entries
    }

    pub fn telemetry(&self) -> &RetentionTelemetry {
        &self.telemetry
    }

    /// Count samples emitted into a training round (`retained` of them
    /// drawn from this store, `total` overall) — the hit-rate inputs.
    pub fn note_emitted(&mut self, retained: u64, total: u64) {
        self.telemetry.retained_emitted += retained;
        self.telemetry.emitted_total += total;
    }

    /// Offer one scored candidate. Duplicate ids refresh the stored score
    /// in place (no byte movement). Non-finite scores, out-of-range
    /// labels, and entries that could never fit the budget are rejected
    /// outright; otherwise the policy picks victims until the entry fits
    /// or refuses, in which case nothing is evicted and the offer is
    /// rejected (two-phase: a refusal midway must not half-empty the
    /// store).
    pub fn offer(&mut self, c: Candidate) -> Offer {
        self.telemetry.offers += 1;
        let cost = sample_cost(c.sample.dim());
        if !c.score.is_finite() || (c.sample.label as usize) >= self.num_classes || cost > self.budget
        {
            self.telemetry.rejects += 1;
            return Offer::Rejected;
        }
        if let Some(&slot) = self.index.get(&c.sample.id) {
            debug_assert_eq!(self.entries[slot].sample.id, c.sample.id, "index out of sync");
            self.entries[slot].score = c.score;
            self.telemetry.refreshes += 1;
            return Offer::Refreshed;
        }
        self.policy.on_offer();
        let mut excluded: Vec<usize> = Vec::new();
        let mut freed = 0usize;
        while self.bytes + cost - freed > self.budget {
            match self
                .policy
                .victim(&self.entries, &excluded, self.num_classes, &c)
            {
                Some(i) => {
                    debug_assert!(i < self.entries.len());
                    debug_assert!(excluded.binary_search(&i).is_err());
                    freed += sample_cost(self.entries[i].sample.dim());
                    let pos = excluded.partition_point(|&e| e < i);
                    excluded.insert(pos, i);
                }
                None => {
                    self.telemetry.rejects += 1;
                    return Offer::Rejected;
                }
            }
        }
        let kind = self.policy.kind();
        for &i in excluded.iter().rev() {
            self.entries.remove(i);
            self.telemetry.bump_evict(kind);
        }
        self.bytes = self.bytes + cost - freed;
        self.entries.push(c);
        if excluded.is_empty() {
            // pressure-free admit (the common path): one O(1) insert
            self.index.insert(
                // detlint: allow(R001) invariant: entries.push(c) on the previous line
                self.entries.last().expect("just pushed").sample.id,
                self.entries.len() - 1,
            );
        } else {
            // eviction shifted the slots after each removal point
            self.rebuild_index();
        }
        self.telemetry.admits += 1;
        self.telemetry.bytes_held = self.bytes as u64;
        Offer::Admitted
    }

    /// Offer a whole drained candidate batch in order.
    pub fn offer_all(&mut self, cs: Vec<Candidate>) {
        for c in cs {
            self.offer(c);
        }
    }

    pub fn export_entries(&self) -> Vec<Candidate> {
        self.entries.clone()
    }

    pub fn export_policy(&self) -> Option<PolicyState> {
        self.policy.export()
    }

    /// Restore store contents + telemetry + policy state from a snapshot.
    /// Validates what [`SampleStore::offer`] could never have produced:
    /// non-finite scores, out-of-range labels, duplicate ids, and a byte
    /// total over the budget.
    pub fn restore(
        &mut self,
        entries: Vec<Candidate>,
        telemetry: RetentionTelemetry,
        policy: Option<PolicyState>,
    ) -> Result<()> {
        let mut bytes = 0usize;
        for c in &entries {
            if !c.score.is_finite() {
                return Err(Error::Data(format!(
                    "store restore: non-finite score on sample {}",
                    c.sample.id
                )));
            }
            if (c.sample.label as usize) >= self.num_classes {
                return Err(Error::Data(format!(
                    "store restore: label {} out of range (num_classes {})",
                    c.sample.label, self.num_classes
                )));
            }
            bytes += sample_cost(c.sample.dim());
        }
        if bytes > self.budget {
            return Err(Error::Data(format!(
                "store restore: {bytes} bytes exceed the {}-byte budget",
                self.budget
            )));
        }
        let mut ids: Vec<u64> = entries.iter().map(|c| c.sample.id).collect();
        ids.sort_unstable();
        if ids.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Data("store restore: duplicate sample id".into()));
        }
        self.policy.restore(policy)?;
        self.entries = entries;
        self.rebuild_index();
        self.bytes = bytes;
        self.telemetry = telemetry;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sample;

    /// dim-1 candidate: every entry costs `sample_cost(1)` = 36 bytes.
    fn c(id: u64, label: u32, score: f64) -> Candidate {
        Candidate {
            sample: Sample::new(id, label, vec![0.5]),
            score,
        }
    }

    /// Budget that fits exactly `n` dim-1 entries.
    fn fit(n: usize) -> usize {
        n * sample_cost(1)
    }

    fn ids(store: &SampleStore) -> Vec<u64> {
        store.entries().iter().map(|e| e.sample.id).collect()
    }

    #[test]
    fn cost_model_is_features_plus_overhead() {
        assert_eq!(sample_cost(0), SAMPLE_OVERHEAD_BYTES);
        assert_eq!(sample_cost(64), 64 * 4 + SAMPLE_OVERHEAD_BYTES);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            RetentionKind::Score,
            RetentionKind::Balanced,
            RetentionKind::Reservoir,
        ] {
            assert_eq!(RetentionKind::parse(k.name()).unwrap(), k);
        }
        assert!(RetentionKind::parse("lru").is_err());
    }

    /// THE index-vs-scan equivalence pin: across randomized offer
    /// streams (duplicates, evictions, every policy) and a snapshot
    /// round-trip, the hash index must agree with a linear scan of the
    /// entries at every step — same duplicate verdict per offer, and
    /// `index[entries[i].id] == i` as a standing invariant.
    #[test]
    fn index_matches_scan_under_random_offers() {
        for kind in [
            RetentionKind::Score,
            RetentionKind::Balanced,
            RetentionKind::Reservoir,
        ] {
            for seed in 0..4u64 {
                let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x1DCE5);
                let mut st = SampleStore::new(fit(8), 4, kind, seed);
                for step in 0..400 {
                    // small id universe forces frequent duplicate offers
                    let id = rng.index(24) as u64;
                    let label = rng.index(4) as u32;
                    let scan_hit = st.entries().iter().any(|e| e.sample.id == id);
                    let offer = st.offer(c(id, label, rng.index(1000) as f64 / 10.0));
                    assert_eq!(
                        offer == Offer::Refreshed,
                        scan_hit,
                        "{} seed={seed} step={step}: index and scan disagree on id {id}",
                        kind.name()
                    );
                    assert_index_invariant(&st);
                }
                // a restored store rebuilds the index from the entries
                let entries = st.export_entries();
                let telemetry = st.telemetry().clone();
                let policy = st.export_policy();
                let mut thawed = SampleStore::new(fit(8), 4, kind, seed);
                thawed.restore(entries, telemetry, policy).unwrap();
                assert_index_invariant(&thawed);
                assert_eq!(ids(&thawed), ids(&st));
            }
        }
    }

    fn assert_index_invariant(st: &SampleStore) {
        assert_eq!(st.index.len(), st.entries.len(), "index/entries length drift");
        for (i, e) in st.entries.iter().enumerate() {
            assert_eq!(st.index.get(&e.sample.id), Some(&i), "slot drift for id {}", e.sample.id);
        }
    }

    #[test]
    fn zero_budget_store_rejects_everything() {
        let mut st = SampleStore::new(0, 10, RetentionKind::Score, 1);
        for i in 0..5 {
            assert_eq!(st.offer(c(i, 0, i as f64)), Offer::Rejected);
        }
        assert!(st.is_empty());
        assert_eq!(st.bytes_held(), 0);
        assert_eq!(st.telemetry().rejects, 5);
        assert_eq!(st.telemetry().admits, 0);
    }

    #[test]
    fn admits_until_budget_then_policy_decides() {
        let mut st = SampleStore::new(fit(3), 10, RetentionKind::Score, 1);
        assert_eq!(st.offer(c(0, 0, 1.0)), Offer::Admitted);
        assert_eq!(st.offer(c(1, 1, 3.0)), Offer::Admitted);
        assert_eq!(st.offer(c(2, 2, 2.0)), Offer::Admitted);
        assert_eq!(st.bytes_held(), fit(3));
        // worse than everything stored -> rejected, store untouched
        assert_eq!(st.offer(c(3, 0, 0.5)), Offer::Rejected);
        assert_eq!(ids(&st), vec![0, 1, 2]);
        // better than the worst (score 1.0 at id 0) -> evicts it
        assert_eq!(st.offer(c(4, 0, 5.0)), Offer::Admitted);
        assert_eq!(ids(&st), vec![1, 2, 4]);
        let t = st.telemetry();
        assert_eq!(
            (t.offers, t.admits, t.rejects, t.evicts_score),
            (5, 4, 1, 1)
        );
        assert_eq!(t.bytes_held, fit(3) as u64);
    }

    #[test]
    fn score_weighted_tie_break_is_pinned() {
        // eviction order: score asc / id desc — among equal scores the
        // LARGEST id is evicted first (the incoming candidate, having the
        // largest id of all, loses ties against anything stored)
        let mut st = SampleStore::new(fit(2), 10, RetentionKind::Score, 1);
        st.offer(c(1, 0, 1.0));
        st.offer(c(2, 0, 1.0));
        // equal score, larger id than both stored -> rejected
        assert_eq!(st.offer(c(3, 0, 1.0)), Offer::Rejected);
        assert_eq!(ids(&st), vec![1, 2]);
        // equal score, SMALLER id than the stored worst (id 2) -> id 2,
        // the largest equal-score id, is evicted first
        assert_eq!(st.offer(c(0, 0, 1.0)), Offer::Admitted);
        assert_eq!(ids(&st), vec![1, 0]);
    }

    #[test]
    fn score_weighted_survivors_are_arrival_independent() {
        // same offer set in different orders -> same surviving id set
        let offers = [
            (10u64, 0.9),
            (11, 0.1),
            (12, 0.5),
            (13, 0.5),
            (14, 0.7),
            (15, 0.2),
        ];
        let survivors = |order: &[usize]| -> Vec<u64> {
            let mut st = SampleStore::new(fit(3), 4, RetentionKind::Score, 1);
            for &i in order {
                let (id, s) = offers[i];
                st.offer(c(id, (id % 4) as u32, s));
            }
            let mut v = ids(&st);
            v.sort_unstable();
            v
        };
        let want = survivors(&[0, 1, 2, 3, 4, 5]);
        // top-3 by (score desc, id asc): 10 (0.9), 14 (0.7), 12 (0.5 —
        // beats the equal-scored 13 by smaller id)
        assert_eq!(want, vec![10, 12, 14]);
        assert_eq!(survivors(&[5, 4, 3, 2, 1, 0]), want);
        assert_eq!(survivors(&[2, 0, 5, 3, 1, 4]), want);
        assert_eq!(survivors(&[3, 2, 4, 0, 1, 5]), want);
    }

    #[test]
    fn class_balanced_evicts_most_overrepresented_class() {
        let mut st = SampleStore::new(fit(4), 3, RetentionKind::Balanced, 1);
        st.offer(c(0, 0, 0.9));
        st.offer(c(1, 0, 0.2));
        st.offer(c(2, 0, 0.5));
        st.offer(c(3, 1, 0.1));
        // class 0 holds 3 of 4 slots; its lowest score (id 1) goes first
        assert_eq!(st.offer(c(4, 2, 0.0)), Offer::Admitted);
        assert_eq!(ids(&st), vec![0, 2, 3, 4]);
        assert_eq!(st.telemetry().evicts_balanced, 1);
        // now classes hold 2/1/1 -> class 0 again; equal scores would tie
        // by smallest id, here lowest score is id 2 (0.5)
        assert_eq!(st.offer(c(5, 1, 0.0)), Offer::Admitted);
        assert_eq!(ids(&st), vec![0, 3, 4, 5]);
    }

    #[test]
    fn class_balanced_class_tie_picks_smallest_class() {
        let mut st = SampleStore::new(fit(2), 4, RetentionKind::Balanced, 1);
        st.offer(c(0, 2, 0.5));
        st.offer(c(1, 1, 0.5));
        // classes 1 and 2 tied at one entry each -> class 1 (smaller
        // index) loses its only entry
        assert_eq!(st.offer(c(2, 3, 0.5)), Offer::Admitted);
        assert_eq!(ids(&st), vec![0, 2]);
    }

    #[test]
    fn refresh_updates_score_without_bytes() {
        let mut st = SampleStore::new(fit(2), 10, RetentionKind::Score, 1);
        st.offer(c(7, 0, 1.0));
        let before = st.bytes_held();
        assert_eq!(st.offer(c(7, 0, 9.0)), Offer::Refreshed);
        assert_eq!(st.bytes_held(), before);
        assert_eq!(st.len(), 1);
        assert_eq!(st.entries()[0].score, 9.0);
        assert_eq!(st.telemetry().refreshes, 1);
        // the refreshed score now wins evictions
        st.offer(c(8, 0, 2.0));
        assert_eq!(st.offer(c(9, 0, 3.0)), Offer::Admitted);
        assert_eq!(ids(&st), vec![7, 9]);
    }

    #[test]
    fn rejects_bad_label_and_non_finite_score() {
        let mut st = SampleStore::new(fit(4), 3, RetentionKind::Score, 1);
        assert_eq!(st.offer(c(0, 3, 1.0)), Offer::Rejected);
        assert_eq!(st.offer(c(1, 0, f64::NAN)), Offer::Rejected);
        assert_eq!(st.offer(c(2, 0, f64::INFINITY)), Offer::Rejected);
        assert!(st.is_empty());
        assert_eq!(st.telemetry().rejects, 3);
    }

    #[test]
    fn oversize_sample_is_rejected_not_evicting() {
        let mut st = SampleStore::new(fit(2), 10, RetentionKind::Score, 1);
        st.offer(c(0, 0, 1.0));
        st.offer(c(1, 0, 2.0));
        // a sample bigger than the whole budget must not drain the store
        let big = Candidate {
            sample: Sample::new(9, 0, vec![0.0; 1000]),
            score: 99.0,
        };
        assert_eq!(st.offer(big), Offer::Rejected);
        assert_eq!(st.len(), 2);
    }

    #[test]
    fn heterogeneous_dims_evict_multiple_victims_atomically() {
        // budget fits 4 small entries; a double-size offer with a top
        // score must evict TWO victims, or none at all on refusal
        let mut st = SampleStore::new(4 * sample_cost(2), 10, RetentionKind::Score, 1);
        for i in 0..4u64 {
            st.offer(Candidate {
                sample: Sample::new(i, 0, vec![0.0; 2]),
                score: i as f64,
            });
        }
        assert_eq!(st.len(), 4);
        let wide = |id: u64, score: f64| Candidate {
            sample: Sample::new(id, 0, vec![0.0; 2 + SAMPLE_OVERHEAD_BYTES / 4]),
            score,
        };
        // worth less than the second victim (score 1.0) -> the policy
        // refuses midway and the first victim must NOT have been evicted
        assert_eq!(st.offer(wide(10, 0.5)), Offer::Rejected);
        assert_eq!(st.len(), 4);
        assert_eq!(st.bytes_held(), 4 * sample_cost(2));
        // worth more than both victims -> evicts scores 0.0 and 1.0
        assert_eq!(st.offer(wide(11, 9.0)), Offer::Admitted);
        assert_eq!(ids(&st), vec![2, 3, 11]);
        assert_eq!(st.telemetry().evicts_score, 2);
        assert_eq!(st.bytes_held(), 4 * sample_cost(2));
    }

    /// Independent re-implementation of the documented reservoir
    /// semantics: i-th offer draws j ∈ [0, i); j < len evicts slot j and
    /// appends, else rejects.
    fn reservoir_oracle(seed: u64, cap: usize, offers: &[(u64, u32)]) -> Vec<u64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut kept: Vec<u64> = Vec::new();
        let mut seen = 0u64;
        for &(id, _label) in offers {
            seen += 1;
            if kept.len() < cap {
                kept.push(id);
                continue;
            }
            let j = rng.next_below(seen);
            if (j as usize) < kept.len() {
                kept.remove(j as usize);
                kept.push(id);
            }
        }
        kept
    }

    #[test]
    fn reservoir_matches_brute_force_oracle() {
        crate::util::prop::forall(
            0x4E5E_4701,
            30,
            |rng| {
                vec![
                    1 + rng.index(20) as f64,  // capacity in entries
                    50 + rng.index(400) as f64, // offer count
                    rng.next_u64() as f64,      // truncated seed (fine)
                ]
            },
            |params| {
                if params.len() < 3 {
                    return Ok(()); // shrunk below the parameter arity
                }
                let cap = (params[0] as usize).max(1);
                let n = params[1] as usize;
                let seed = params[2] as u64;
                let offers: Vec<(u64, u32)> =
                    (0..n as u64).map(|i| (i, (i % 7) as u32)).collect();
                let mut st = SampleStore::new(fit(cap), 7, RetentionKind::Reservoir, seed);
                for &(id, label) in &offers {
                    st.offer(c(id, label, 0.0));
                }
                let got = ids(&st);
                let want = reservoir_oracle(seed, cap, &offers);
                if got != want {
                    return Err(format!("store {got:?} != oracle {want:?}"));
                }
                // same seed, fresh store -> identical retained set
                let mut st2 = SampleStore::new(fit(cap), 7, RetentionKind::Reservoir, seed);
                for &(id, label) in &offers {
                    st2.offer(c(id, label, 0.0));
                }
                if ids(&st2) != got {
                    return Err("same seed diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn reservoir_per_class_frequencies_are_uniform() {
        // 10k offers cycling 10 classes into a 200-entry reservoir: each
        // class should hold ~20 slots; aggregate over seeds to bound the
        // variance and require every class within ±50% of fair share
        let classes = 10u32;
        let cap = 200usize;
        let mut totals = vec![0u64; classes as usize];
        for seed in 0..5u64 {
            let mut st = SampleStore::new(fit(cap), classes as usize, RetentionKind::Reservoir, seed);
            for i in 0..10_000u64 {
                st.offer(c(i, (i % classes as u64) as u32, 0.0));
            }
            assert_eq!(st.len(), cap);
            for e in st.entries() {
                totals[e.sample.label as usize] += 1;
            }
        }
        let fair = (5 * cap) as f64 / classes as f64; // 100 per class
        for (cls, &n) in totals.iter().enumerate() {
            assert!(
                (n as f64) > fair * 0.5 && (n as f64) < fair * 1.5,
                "class {cls} holds {n} of ~{fair} expected slots"
            );
        }
    }

    #[test]
    fn export_restore_continues_identically() {
        for kind in [
            RetentionKind::Score,
            RetentionKind::Balanced,
            RetentionKind::Reservoir,
        ] {
            let mut live = SampleStore::new(fit(5), 4, kind, 42);
            for i in 0..12u64 {
                live.offer(c(i, (i % 4) as u32, (i % 5) as f64));
            }
            let mut resumed = SampleStore::new(fit(5), 4, kind, 999); // seed overwritten by restore
            resumed
                .restore(
                    live.export_entries(),
                    live.telemetry().clone(),
                    live.export_policy(),
                )
                .unwrap();
            for i in 12..30u64 {
                let offer = c(i, (i % 4) as u32, (i % 5) as f64);
                assert_eq!(live.offer(offer.clone()), resumed.offer(offer), "{kind:?} @ {i}");
            }
            assert_eq!(ids(&live), ids(&resumed), "{kind:?}");
            assert_eq!(live.telemetry(), resumed.telemetry(), "{kind:?}");
        }
    }

    #[test]
    fn restore_rejects_invalid_state() {
        let mut st = SampleStore::new(fit(2), 3, RetentionKind::Score, 1);
        let t = RetentionTelemetry::default();
        // over budget
        let too_many = vec![c(0, 0, 0.0), c(1, 0, 0.0), c(2, 0, 0.0)];
        assert!(st.restore(too_many, t.clone(), None).is_err());
        // duplicate ids
        assert!(st.restore(vec![c(5, 0, 0.0), c(5, 1, 1.0)], t.clone(), None).is_err());
        // bad label
        assert!(st.restore(vec![c(0, 7, 0.0)], t.clone(), None).is_err());
        // non-finite score
        assert!(st.restore(vec![c(0, 0, f64::NAN)], t.clone(), None).is_err());
        // stateless policy handed policy state
        let snap = PolicyState { rng: [1, 2, 3, 4], seen: 9 };
        assert!(st.restore(vec![], t.clone(), Some(snap)).is_err());
        // reservoir without policy state
        let mut rs = SampleStore::new(fit(2), 3, RetentionKind::Reservoir, 1);
        assert!(rs.restore(vec![], t, None).is_err());
    }

    #[test]
    fn telemetry_json_roundtrip_and_merge() {
        let mut t = RetentionTelemetry {
            offers: 100,
            admits: 60,
            refreshes: 5,
            rejects: 35,
            evicts_score: 40,
            evicts_balanced: 0,
            evicts_reservoir: 0,
            bytes_held: 720,
            retained_emitted: 30,
            emitted_total: 120,
        };
        assert_eq!(t.hit_rate(), 0.25);
        assert_eq!(t.evicts_total(), 40);
        let j = crate::util::json::Json::parse(&t.to_json().to_string_compact()).unwrap();
        assert_eq!(RetentionTelemetry::from_json(&j).unwrap(), t);
        let u = t.clone();
        t.merge(&u);
        assert_eq!(t.offers, 200);
        assert_eq!(t.bytes_held, 1440);
        assert_eq!(t.hit_rate(), 0.25);
        assert_eq!(RetentionTelemetry::default().hit_rate(), 0.0);
    }
}
