//! Data plane: sample types, synthetic task generators (the stand-ins for
//! CIFAR-10 / Speech Commands / HARBOX — see DESIGN.md §Substitutions),
//! the streaming source with noise injection, the class-indexed sample
//! store and the capped candidate priority buffer.

pub mod buffer;
pub mod sample;
pub mod store;
pub mod stream;
pub mod synth;

pub use buffer::CandidateBuffer;
pub use sample::Sample;
pub use store::ClassStore;
pub use stream::{StreamSource, StreamStats};
pub use synth::{SynthTask, TaskSpec};
