//! Data plane: sample types, synthetic task generators (the stand-ins for
//! CIFAR-10 / Speech Commands / HARBOX — see DESIGN.md §Substitutions),
//! the streaming source with noise injection, the class-indexed sample
//! store, the capped candidate ring (lazy-threshold top-k), and the object-safe
//! [`DataSource`] seam the coordinator session pulls rounds through
//! (stream / replay / non-IID class subset / drifting class mix /
//! byte-budget-retaining [`RetainedSource`]).

pub mod buffer;
pub mod retained;
pub mod sample;
pub mod source;
pub mod store;
pub mod stream;
pub mod synth;

pub use buffer::CandidateBuffer;
pub use retained::RetainedSource;
pub use sample::Sample;
pub use source::{ClassSubsetSource, DataSource, DriftSource, ReplaySource};
pub use store::ClassStore;
pub use stream::{StreamSource, StreamStats};
pub use synth::{SynthTask, TaskSpec};
