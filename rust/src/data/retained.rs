//! [`RetainedSource`] — the data-plane face of the retention stage: wraps
//! any [`DataSource`] with a byte-budgeted [`SampleStore`] and blends
//! retained samples back into each round's arrivals.
//!
//! Per round with stream velocity `v` and replay mix `m ∈ [0, 1]`:
//! `k = min(⌊m·v⌋, stored)` retained samples (drawn without replacement on
//! a dedicated blend RNG) lead the round, followed by the first `v − k`
//! fresh arrivals. The displaced fresh tail is **dropped, not deferred** —
//! the stream is transient; deciding which arrivals never get looked at is
//! exactly the storage-budget trade the retention stage models. The inner
//! source always consumes a full `v`-sample round, so its cursor position
//! is a pure function of the round count and [`DataSource::fast_forward`]
//! stays O(1) whenever the inner source's is.
//!
//! Resume contract: `fast_forward` replays only the inner cursor. The
//! store contents and the blend RNG depend on past *selection outcomes*
//! (which candidates the filter scored and offered), not on the stream, so
//! a resumed session must pair `fast_forward` with
//! [`DataSource::restore_retention`] from the snapshot — the session's
//! `Running::start` does, and `resume_matches_uninterrupted` below pins
//! the pairing.

use crate::data::buffer::Candidate;
use crate::data::sample::Sample;
use crate::data::source::DataSource;
use crate::data::synth::SynthTask;
use crate::retention::{RetentionKind, RetentionState, RetentionTelemetry, SampleStore};
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// Blends a [`SampleStore`] with a wrapped fresh source. See the module
/// docs for the emission and resume contracts.
pub struct RetainedSource {
    inner: Box<dyn DataSource>,
    store: SampleStore,
    mix: f64,
    /// Dedicated blend RNG: which retained samples replay each round.
    /// Separate from every other RNG stream so retention draws never
    /// shift selection or stream randomness.
    rng: Xoshiro256,
}

impl RetainedSource {
    /// Wrap `inner` with a `store_bytes`-budget store under `kind`.
    /// `mix` is the replayed fraction of each round, validated into
    /// [0, 1]. `seed` should be the run seed; the store policy and blend
    /// RNGs derive their own streams from it.
    pub fn new(
        inner: Box<dyn DataSource>,
        store_bytes: usize,
        kind: RetentionKind,
        mix: f64,
        seed: u64,
    ) -> Result<RetainedSource> {
        if !mix.is_finite() || !(0.0..=1.0).contains(&mix) {
            return Err(Error::Config(format!(
                "replay mix {mix} outside [0, 1]"
            )));
        }
        let num_classes = inner.task().num_classes();
        Ok(RetainedSource {
            // stage-3 constant next to the selector's 0x5E1E_C70A
            store: SampleStore::new(store_bytes, num_classes, kind, seed ^ 0x5E1E_C703),
            inner,
            mix,
            rng: Xoshiro256::seed_from_u64(seed ^ 0xB1E4_D411),
        })
    }

    pub fn store(&self) -> &SampleStore {
        &self.store
    }

    pub fn mix(&self) -> f64 {
        self.mix
    }
}

impl DataSource for RetainedSource {
    fn task(&self) -> &SynthTask {
        self.inner.task()
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        // always pull the full fresh round first (cursor invariance)
        let mut fresh = self.inner.next_round(v);
        let k = ((self.mix * v as f64).floor() as usize).min(self.store.len());
        let mut out: Vec<Sample> = Vec::with_capacity(fresh.len());
        if k > 0 {
            let picks = self.rng.sample_indices(self.store.len(), k);
            out.extend(picks.iter().map(|&i| self.store.entries()[i].sample.clone()));
            fresh.truncate(fresh.len().saturating_sub(k));
        }
        let total = (out.len() + fresh.len()) as u64;
        self.store.note_emitted(out.len() as u64, total);
        out.extend(fresh);
        out
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.inner.test_set(n, seed)
    }

    fn fast_forward(&mut self, rounds: usize, v: usize) {
        // inner cursor only — store + blend RNG come from the snapshot
        // via restore_retention (module docs: the resume contract)
        self.inner.fast_forward(rounds, v);
    }

    fn retains(&self) -> bool {
        true
    }

    fn offer_retention(&mut self, scored: Vec<Candidate>) {
        self.store.offer_all(scored);
    }

    fn retention_stats(&self) -> Option<RetentionTelemetry> {
        Some(self.store.telemetry().clone())
    }

    fn export_retention(&self) -> Option<RetentionState> {
        Some(RetentionState {
            entries: self.store.export_entries(),
            telemetry: self.store.telemetry().clone(),
            policy: self.store.export_policy(),
            blend_rng: self.rng.state(),
        })
    }

    fn restore_retention(&mut self, st: RetentionState) -> Result<()> {
        self.store.restore(st.entries, st.telemetry, st.policy)?;
        self.rng = Xoshiro256::from_state(st.blend_rng)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NoiseKind;
    use crate::data::stream::StreamSource;
    use crate::data::synth::TaskSpec;

    fn task() -> SynthTask {
        SynthTask::new(TaskSpec::Har, 3, 0.2, 0.1)
    }

    fn stream() -> Box<dyn DataSource> {
        Box::new(StreamSource::new(task(), 5, NoiseKind::None))
    }

    fn wrap(store_bytes: usize, mix: f64) -> RetainedSource {
        RetainedSource::new(stream(), store_bytes, RetentionKind::Score, mix, 7).unwrap()
    }

    fn cand(id: u64, label: u32, score: f64) -> Candidate {
        Candidate {
            sample: Sample::new(id, label, vec![0.0; 4]),
            score,
        }
    }

    fn assert_rounds_eq(a: &[Sample], b: &[Sample], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: lengths");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.id, y.id, "{ctx}");
            assert_eq!(x.label, y.label, "{ctx}");
            assert_eq!(*x.x, *y.x, "{ctx}");
        }
    }

    #[test]
    fn mix_is_validated() {
        for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
            assert!(RetainedSource::new(stream(), 1024, RetentionKind::Score, bad, 7).is_err());
        }
        assert!(RetainedSource::new(stream(), 1024, RetentionKind::Score, 0.0, 7).is_ok());
        assert!(RetainedSource::new(stream(), 1024, RetentionKind::Score, 1.0, 7).is_ok());
    }

    #[test]
    fn zero_budget_wrapper_is_a_pass_through() {
        // determinism pin (a) at the source level: an empty store never
        // replays, so the wrapper emits exactly the inner stream
        let mut plain = stream();
        let mut wrapped = wrap(0, 0.5);
        for r in 0..4 {
            // offers are all rejected at budget 0
            wrapped.offer_retention(vec![cand(1000 + r, 0, 1.0)]);
            let (a, b) = (plain.next_round(20), wrapped.next_round(20));
            assert_rounds_eq(&a, &b, &format!("round {r}"));
        }
        assert_eq!(wrapped.store().len(), 0);
        let t = wrapped.retention_stats().unwrap();
        assert_eq!(t.rejects, 4);
        assert_eq!(t.retained_emitted, 0);
        assert_eq!(t.emitted_total, 80);
    }

    #[test]
    fn blend_emits_floor_mix_v_retained_then_fresh() {
        let mut src = wrap(1 << 20, 0.25);
        // retain 10 candidates with ids the stream will never emit again
        src.offer_retention((0..10).map(|i| cand(5000 + i, 0, i as f64)).collect());
        assert_eq!(src.store().len(), 10);
        let round = src.next_round(20); // k = floor(0.25 * 20) = 5
        assert_eq!(round.len(), 20);
        let retained: Vec<&Sample> = round.iter().filter(|s| s.id >= 5000).collect();
        assert_eq!(retained.len(), 5, "floor(mix*v) retained samples");
        assert!(
            round[..5].iter().all(|s| s.id >= 5000),
            "retained samples lead the round"
        );
        // without-replacement draw: distinct ids
        let mut ids: Vec<u64> = retained.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5);
        let t = src.retention_stats().unwrap();
        assert_eq!(t.retained_emitted, 5);
        assert_eq!(t.emitted_total, 20);
        assert_eq!(t.hit_rate(), 0.25);
    }

    #[test]
    fn small_store_caps_the_replay_share() {
        let mut src = wrap(1 << 20, 1.0); // wants all-retained rounds
        src.offer_retention(vec![cand(9000, 0, 1.0), cand(9001, 1, 2.0)]);
        let round = src.next_round(10); // k = min(10, 2) = 2
        assert_eq!(round.len(), 10);
        assert_eq!(round.iter().filter(|s| s.id >= 9000).count(), 2);
    }

    #[test]
    fn same_seed_same_offers_is_bit_identical() {
        let run = || {
            let mut src = wrap(1 << 12, 0.5);
            let mut rounds = Vec::new();
            for r in 0..6u64 {
                let round = src.next_round(12);
                // offer a deterministic slice of the round back
                let scored: Vec<Candidate> = round
                    .iter()
                    .take(4)
                    .map(|s| Candidate { sample: s.clone(), score: (s.id % 7) as f64 })
                    .collect();
                src.offer_retention(scored);
                let _ = r;
                rounds.push(round);
            }
            (rounds, src.retention_stats().unwrap(), {
                let mut v: Vec<u64> =
                    src.store().entries().iter().map(|e| e.sample.id).collect();
                v.sort_unstable();
                v
            })
        };
        let (ra, ta, sa) = run();
        let (rb, tb, sb) = run();
        for (i, (a, b)) in ra.iter().zip(&rb).enumerate() {
            assert_rounds_eq(a, b, &format!("round {i}"));
        }
        assert_eq!(ta, tb, "telemetry");
        assert_eq!(sa, sb, "store contents");
    }

    #[test]
    fn resume_matches_uninterrupted() {
        // the documented resume pairing: fast_forward (inner cursor) +
        // restore_retention (store, policy state, blend RNG) must land on
        // the uninterrupted trajectory bit-for-bit, for every policy
        for kind in [
            RetentionKind::Score,
            RetentionKind::Balanced,
            RetentionKind::Reservoir,
        ] {
            let mk = || RetainedSource::new(stream(), 1 << 12, kind, 0.5, 7).unwrap();
            let drive = |src: &mut RetainedSource, rounds: std::ops::Range<usize>| -> Vec<Vec<Sample>> {
                rounds
                    .map(|_| {
                        let round = src.next_round(12);
                        let scored: Vec<Candidate> = round
                            .iter()
                            .take(4)
                            .map(|s| Candidate {
                                sample: s.clone(),
                                score: (s.id % 5) as f64,
                            })
                            .collect();
                        src.offer_retention(scored);
                        round
                    })
                    .collect()
            };
            let mut live = mk();
            let _ = drive(&mut live, 0..5);
            let snap = live.export_retention().unwrap();

            let mut resumed = mk();
            resumed.fast_forward(5, 12);
            resumed.restore_retention(snap).unwrap();

            let a = drive(&mut live, 5..9);
            let b = drive(&mut resumed, 5..9);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_rounds_eq(x, y, &format!("{kind:?} post-resume round {i}"));
            }
            assert_eq!(
                live.retention_stats().unwrap(),
                resumed.retention_stats().unwrap(),
                "{kind:?} telemetry"
            );
        }
    }

    #[test]
    fn plain_sources_reject_retention_state() {
        let mut plain = stream();
        assert!(!plain.retains());
        assert!(plain.retention_stats().is_none());
        assert!(plain.export_retention().is_none());
        let mut src = wrap(1 << 12, 0.5);
        let st = src.export_retention().unwrap();
        match plain.restore_retention(st) {
            Err(crate::Error::Data(_)) => {}
            other => panic!("expected Error::Data, got {other:?}"),
        }
        // and offering to a plain source is a silent no-op
        plain.offer_retention(vec![cand(1, 0, 1.0)]);
        let _ = src;
    }
}
