//! The unit of the data plane: one labelled sample.

use std::sync::Arc;

/// One labelled training sample. `x` is the flattened input in the exact
/// layout the AOT artifacts expect ([input_dim] f32, row-major). Inputs are
//  shared behind `Arc` — samples are cloned freely between the filter,
/// the candidate buffer, and the trainer without copying the payload.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Monotone id assigned by the stream source (unique per run).
    pub id: u64,
    /// Class label in [0, num_classes).
    pub label: u32,
    /// Flattened input features.
    pub x: Arc<Vec<f32>>,
    /// True label before noise injection (for noise-robustness analysis;
    /// equals `label` on clean streams).
    pub clean_label: u32,
}

impl Sample {
    pub fn new(id: u64, label: u32, x: Vec<f32>) -> Self {
        Self {
            id,
            label,
            clean_label: label,
            x: Arc::new(x),
        }
    }

    pub fn dim(&self) -> usize {
        self.x.len()
    }

    /// Whether the label was corrupted by noise injection.
    pub fn label_is_noisy(&self) -> bool {
        self.label != self.clean_label
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_cheap_clone() {
        let s = Sample::new(7, 2, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.dim(), 3);
        assert!(!s.label_is_noisy());
        let t = s.clone();
        assert!(Arc::ptr_eq(&s.x, &t.x), "clone must share the payload");
    }

    #[test]
    fn noisy_label_flag() {
        let mut s = Sample::new(1, 0, vec![0.0]);
        s.label = 3;
        assert!(s.label_is_noisy());
    }
}
