//! Capped priority candidate buffer — the coarse filter's output.
//!
//! Keeps the top-`cap` samples by filter score (a min-heap on score: the
//! worst retained candidate sits at the top and is evicted first). The
//! fine-grained stage drains the buffer once per round.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::data::sample::Sample;

/// A buffered candidate: sample + its coarse-filter score.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub sample: Sample,
    pub score: f64,
}

/// The one canonical consumption order: score descending, id ascending
/// within score ties. [`CandidateBuffer::drain_sorted`] and
/// [`CandidateBuffer::snapshot`] must sort identically — the checkpoint
/// serialization order is pinned to what the fine stage consumes — so
/// both call this instead of carrying private copies that could drift.
fn best_first(a: &Candidate, b: &Candidate) -> Ordering {
    b.score
        .partial_cmp(&a.score)
        .unwrap_or(Ordering::Equal)
        .then_with(|| a.sample.id.cmp(&b.sample.id))
}

// Min-heap ordering on score (reverse of natural), tie-broken by id so the
// ordering is total and deterministic.
impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.sample.id == other.sample.id
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smaller score = "greater" for the BinaryHeap max-heap,
        // so the heap top is the WORST candidate.
        other
            .score
            .partial_cmp(&self.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.sample.id.cmp(&self.sample.id))
    }
}

/// Capped priority buffer.
#[derive(Debug)]
pub struct CandidateBuffer {
    heap: BinaryHeap<Candidate>,
    cap: usize,
}

impl CandidateBuffer {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "buffer cap must be positive");
        Self {
            heap: BinaryHeap::with_capacity(cap + 1),
            cap,
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Re-cap the buffer **in place** (idle-resource adaptation happens
    /// every round, so this must not reallocate). Shrinking pops the worst
    /// retained candidates straight off the heap — O((len−cap)·log len),
    /// no drain/re-offer churn; growing just raises the limit. Score ties
    /// at the cut follow [`CandidateBuffer::offer`]'s eviction order
    /// (smallest id evicted first).
    pub fn set_cap(&mut self, cap: usize) {
        assert!(cap > 0, "buffer cap must be positive");
        while self.heap.len() > cap {
            self.heap.pop(); // heap top is the worst retained candidate
        }
        self.cap = cap;
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer a scored sample. Returns true if retained (possibly evicting
    /// the current worst).
    ///
    /// Non-finite scores are rejected outright: a NaN (or ±∞ colliding
    /// with the `unwrap_or(Equal)` fallback in the heap comparator) would
    /// poison the ordering and make every later eviction undefined, so
    /// they must never enter the heap.
    pub fn offer(&mut self, sample: Sample, score: f64) -> bool {
        if !score.is_finite() {
            return false;
        }
        if self.heap.len() < self.cap {
            self.heap.push(Candidate { sample, score });
            return true;
        }
        // full: compare with the worst retained
        if let Some(worst) = self.heap.peek() {
            if score > worst.score {
                self.heap.pop();
                self.heap.push(Candidate { sample, score });
                return true;
            }
        }
        false
    }

    /// Current worst retained score (None if empty).
    pub fn worst_score(&self) -> Option<f64> {
        self.heap.peek().map(|c| c.score)
    }

    /// Drain all candidates, best-score-first (score ties: smaller id
    /// first — the order `drain_order_is_pinned` regression-tests).
    ///
    /// In-place extraction: the heap's backing `Vec` is taken and sorted
    /// directly with `sort_unstable_by` — no candidate clone and no
    /// stable-merge-sort scratch buffer; the per-round drain allocates
    /// nothing beyond the returned `Vec` it already owns. (A pop-then-
    /// reverse extraction would avoid the sort but flips the id order
    /// within score ties, so the owned-`Vec` sort is the variant that
    /// keeps the historical tie-break.) Unstable sort is safe here: the
    /// (score, id) key is total for the finite scores the filter emits,
    /// and candidates comparing equal are interchangeable duplicates.
    pub fn drain_sorted(&mut self) -> Vec<Candidate> {
        let mut v: Vec<Candidate> = std::mem::take(&mut self.heap).into_vec();
        v.sort_unstable_by(best_first);
        v
    }

    /// Peek at the retained candidates (unsorted).
    pub fn iter(&self) -> impl Iterator<Item = &Candidate> {
        self.heap.iter()
    }

    /// Deterministic snapshot of the retained candidates, best-first
    /// (same order as [`CandidateBuffer::drain_sorted`]) — the
    /// serialization order for session checkpoints. Non-destructive;
    /// sample payloads are `Arc`-shared, so the clones are cheap.
    pub fn snapshot(&self) -> Vec<Candidate> {
        let mut v: Vec<Candidate> = self.heap.iter().cloned().collect();
        v.sort_unstable_by(best_first);
        v
    }

    /// Replace the retained candidates with a [`CandidateBuffer::snapshot`]
    /// (checkpoint restore). Heap layout is irrelevant to behaviour — the
    /// comparator is a total order, so drains and evictions only depend on
    /// the retained set. Errors on more items than `cap` or non-finite
    /// scores (which [`CandidateBuffer::offer`] could never have admitted).
    pub fn restore(&mut self, items: Vec<Candidate>) -> crate::Result<()> {
        if items.len() > self.cap {
            return Err(crate::Error::Config(format!(
                "buffer restore: {} candidates > cap {}",
                items.len(),
                self.cap
            )));
        }
        if items.iter().any(|c| !c.score.is_finite()) {
            return Err(crate::Error::Config(
                "buffer restore: non-finite candidate score".into(),
            ));
        }
        self.heap.clear();
        self.heap.extend(items);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(id: u64) -> Sample {
        Sample::new(id, 0, vec![0.0])
    }

    #[test]
    fn keeps_top_k() {
        let mut b = CandidateBuffer::new(3);
        for (id, score) in [(0, 1.0), (1, 5.0), (2, 3.0), (3, 4.0), (4, 0.5)] {
            b.offer(s(id), score);
        }
        let drained = b.drain_sorted();
        let ids: Vec<u64> = drained.iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![1, 3, 2]); // scores 5, 4, 3
    }

    #[test]
    fn rejects_below_worst_when_full() {
        let mut b = CandidateBuffer::new(2);
        assert!(b.offer(s(0), 2.0));
        assert!(b.offer(s(1), 3.0));
        assert!(!b.offer(s(2), 1.0));
        assert_eq!(b.len(), 2);
        assert_eq!(b.worst_score(), Some(2.0));
    }

    #[test]
    fn eviction_updates_worst() {
        let mut b = CandidateBuffer::new(2);
        b.offer(s(0), 1.0);
        b.offer(s(1), 2.0);
        assert!(b.offer(s(2), 5.0)); // evicts score 1.0
        assert_eq!(b.worst_score(), Some(2.0));
    }

    #[test]
    fn deterministic_tiebreak_by_id() {
        let mut b = CandidateBuffer::new(2);
        b.offer(s(5), 1.0);
        b.offer(s(3), 1.0);
        b.offer(s(4), 1.0); // equal score: not better than worst -> rejected
        let ids: Vec<u64> = b.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![3, 5]);
    }

    #[test]
    fn drain_order_is_pinned() {
        // regression pin for the in-place drain: strict score descent,
        // id ascending within score ties — exactly what the fine stage
        // has always consumed. Mixed offer order exercises both the heap
        // path (under cap) and eviction (over cap).
        let mut b = CandidateBuffer::new(6);
        for (id, score) in [
            (9u64, 2.0),
            (1, 3.0),
            (7, 2.0),
            (3, 3.0),
            (5, 1.0),
            (2, 2.0),
            (4, 0.5), // rejected: below the worst retained
        ] {
            b.offer(s(id), score);
        }
        let drained = b.drain_sorted();
        let order: Vec<(u64, f64)> = drained.iter().map(|c| (c.sample.id, c.score)).collect();
        assert_eq!(
            order,
            vec![(1, 3.0), (3, 3.0), (2, 2.0), (7, 2.0), (9, 2.0), (5, 1.0)]
        );
    }

    #[test]
    fn drain_empties() {
        let mut b = CandidateBuffer::new(4);
        b.offer(s(0), 1.0);
        assert_eq!(b.drain_sorted().len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_cap_panics() {
        CandidateBuffer::new(0);
    }

    #[test]
    fn rejects_non_finite_scores() {
        // regression: NaN/∞ used to enter the heap and poison the
        // partial_cmp().unwrap_or(Equal) ordering
        let mut b = CandidateBuffer::new(3);
        assert!(!b.offer(s(0), f64::NAN));
        assert!(!b.offer(s(1), f64::INFINITY));
        assert!(!b.offer(s(2), f64::NEG_INFINITY));
        assert!(b.is_empty());
        // a finite stream around the rejects behaves exactly as before
        assert!(b.offer(s(3), 2.0));
        assert!(!b.offer(s(4), f64::NAN));
        assert!(b.offer(s(5), 3.0));
        assert!(b.offer(s(6), 1.0)); // fills to cap
        assert!(!b.offer(s(7), f64::INFINITY)); // would evict if admitted
        assert_eq!(b.worst_score(), Some(1.0));
        let ids: Vec<u64> = b.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![5, 3, 6]);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut b = CandidateBuffer::new(4);
        for (id, score) in [(3u64, 2.0), (1, 5.0), (2, 2.0), (9, 4.0), (5, 1.0)] {
            b.offer(s(id), score);
        }
        let snap = b.snapshot();
        let order: Vec<u64> = snap.iter().map(|c| c.sample.id).collect();
        assert_eq!(order, vec![1, 9, 2, 3], "best-first, id-tiebroken");
        assert_eq!(b.len(), 4, "snapshot is non-destructive");

        let mut restored = CandidateBuffer::new(4);
        restored.restore(snap.clone()).unwrap();
        assert_eq!(restored.len(), 4);
        // restored buffer evicts and drains exactly like the original
        assert!(restored.offer(s(7), 3.0));
        assert!(b.offer(s(7), 3.0));
        let a: Vec<(u64, f64)> = b.drain_sorted().iter().map(|c| (c.sample.id, c.score)).collect();
        let r: Vec<(u64, f64)> =
            restored.drain_sorted().iter().map(|c| (c.sample.id, c.score)).collect();
        assert_eq!(a, r);

        // over-cap and non-finite snapshots are rejected
        let mut tiny = CandidateBuffer::new(2);
        assert!(tiny.restore(snap).is_err());
        let bad = vec![Candidate { sample: s(0), score: f64::NAN }];
        assert!(tiny.restore(bad).is_err());
    }

    #[test]
    fn set_cap_shrinks_to_best_in_place() {
        let mut b = CandidateBuffer::new(5);
        for (id, score) in [(0, 1.0), (1, 5.0), (2, 3.0), (3, 4.0), (4, 0.5)] {
            b.offer(s(id), score);
        }
        b.set_cap(2);
        assert_eq!(b.cap(), 2);
        assert_eq!(b.len(), 2);
        let ids: Vec<u64> = b.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![1, 3]); // scores 5, 4 survive
    }

    #[test]
    fn set_cap_grow_keeps_entries_and_accepts_more() {
        let mut b = CandidateBuffer::new(2);
        b.offer(s(0), 1.0);
        b.offer(s(1), 2.0);
        assert!(!b.offer(s(2), 0.5));
        b.set_cap(3);
        assert_eq!(b.len(), 2);
        assert!(b.offer(s(3), 0.25)); // room again
        assert_eq!(b.len(), 3);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn set_cap_zero_panics() {
        let mut b = CandidateBuffer::new(2);
        b.set_cap(0);
    }
}
