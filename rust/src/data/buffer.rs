//! Capped candidate buffer — the coarse filter's output — as an
//! **O(1)-evict ring with a lazy admission threshold**.
//!
//! Logically the buffer keeps the top-`cap` samples by filter score. The
//! previous implementation was a binary heap (O(log cap) per admitted
//! offer, plus a full sort of everything retained on every per-round
//! drain). Exact top-k maintenance fundamentally costs Ω(log k)
//! comparisons per element, so this version relaxes *when* the cut is
//! taken, not *what* survives it:
//!
//! - Offers append into a fixed-capacity ring (2·cap slots, allocated
//!   up front). While fewer than `cap` candidates are retained, every
//!   finite-scored offer is admitted — exactly the old behaviour.
//! - Once `cap` is reached, a **lazy threshold** τ gates admission: τ is
//!   the exact worst retained score at the last *exact point* (the first
//!   saturated offer, a compaction, or a shrink), and offers score ≤ τ
//!   are rejected in O(1). Offers above τ append in O(1).
//! - When the ring fills its 2·cap slots, one **compaction** quickselects
//!   the top-`cap` (Floyd–Rivest via `select_nth_unstable_by`), discards
//!   the rest, and re-tightens τ — amortized O(1) per admitted offer.
//! - The per-round drain quickselects the winners and **sorts only
//!   them**, instead of sorting everything the heap happened to hold.
//!
//! Because τ lags the true k-th best between exact points, a borderline
//! offer (score in `(τ, true worst]`) can be provisionally admitted where
//! the heap rejected it outright; it then loses at the next
//! compaction/drain. For distinct scores the **drained set and order are
//! provably identical to the heap's** (τ never exceeds the true worst, so
//! nothing that belongs in the top-`cap` is ever rejected, and nothing
//! discarded by a compaction could re-enter it) — `ring_matches_heap_
//! oracle` property-pins this against a reference heap. Under score
//! *ties* the heap's outcome depended on arrival order (a tie arriving
//! while full was rejected; a tie evicted under pressure dropped the
//! smallest id); the ring resolves every tie at the cut deterministically
//! by the same pinned orders — drains consume score-descending /
//! id-ascending, compactions evict smallest-id-first among equal scores —
//! independent of arrival interleaving.
//!
//! Checkpoints carry the ring verbatim: [`CandidateBuffer::snapshot`]
//! exposes every slot (provisional entries included) plus the threshold
//! ([`CandidateBuffer::thresh`]), and [`CandidateBuffer::restore`] takes
//! both back, so a resumed buffer continues bit-identically. At round
//! boundaries the fine stage has drained everything, so session
//! snapshots carry an empty ring and no threshold.

use std::cmp::Ordering;

use crate::data::sample::Sample;

/// A buffered candidate: sample + its coarse-filter score.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub sample: Sample,
    pub score: f64,
}

/// The one canonical consumption order: score descending, id ascending
/// within score ties. [`CandidateBuffer::drain_sorted`] and
/// [`CandidateBuffer::snapshot`] must sort identically — the checkpoint
/// serialization order is pinned to what the fine stage consumes — so
/// both call this instead of carrying private copies that could drift.
fn best_first(a: &Candidate, b: &Candidate) -> Ordering {
    b.score
        .partial_cmp(&a.score)
        .unwrap_or(Ordering::Equal)
        .then_with(|| a.sample.id.cmp(&b.sample.id))
}

/// Keep-priority order for the eviction cut: score descending, then id
/// **descending**. Taking the top-`cap` under this order reproduces the
/// pinned heap eviction sequence — repeatedly dropping the worst score
/// with the *smallest* id first (see `set_cap`'s historical contract) —
/// as one selection.
fn keep_first(a: &Candidate, b: &Candidate) -> Ordering {
    b.score
        .partial_cmp(&a.score)
        .unwrap_or(Ordering::Equal)
        .then_with(|| b.sample.id.cmp(&a.sample.id))
}

// Equality retained for tests and dedup-style callers; ordering semantics
// live in the named comparators above.
impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.sample.id == other.sample.id
    }
}
impl Eq for Candidate {}

/// Capped candidate ring (see the module docs for the cost model).
#[derive(Debug)]
pub struct CandidateBuffer {
    /// Retained + provisionally admitted candidates, unordered. Holds at
    /// most `physical(cap) - 1` entries between calls (a push to
    /// `physical` triggers an immediate compaction back to `cap`).
    ring: Vec<Candidate>,
    cap: usize,
    /// Lazy admission threshold: the exact worst retained score at the
    /// last exact point; `None` until the buffer first saturates (or
    /// after any event that may have lowered the true worst — an
    /// under-cap admission, a cap grow, a drain).
    thresh: Option<f64>,
}

/// Ring slots for a logical capacity: one compaction per `cap` admitted
/// offers (amortized O(1)), bounded memory at 2× the retained set.
fn physical(cap: usize) -> usize {
    cap * 2
}

/// Worst retained score of a candidate set (∞ for empty) — the one
/// definition the threshold, the compaction cut, and the diagnostic
/// accessor all share.
fn min_score(items: &[Candidate]) -> f64 {
    crate::util::stats::fold_min(items.iter().map(|c| c.score), f64::INFINITY)
}

impl CandidateBuffer {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "buffer cap must be positive");
        Self {
            ring: Vec::with_capacity(physical(cap)),
            cap,
            thresh: None,
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Current admission threshold (`None` until first saturation) — part
    /// of the exported state; see [`CandidateBuffer::restore`].
    pub fn thresh(&self) -> Option<f64> {
        self.thresh
    }

    /// Re-cap the buffer **in place** (idle-resource adaptation happens
    /// every round). A same-cap call is a no-op and must not disturb the
    /// ring. Shrinking below the retained count quickselects the best
    /// `cap` (score ties at the cut evict the smallest id first — the
    /// pinned eviction order); growing raises the limit and drops the
    /// stale threshold (the larger retained set has a lower true worst,
    /// which a stale τ would wrongly gate).
    ///
    /// Growing while provisional over-admissions are in flight promotes
    /// them into the larger retained set (the heap had destructively
    /// evicted at the old cap; the ring hadn't cut yet). The coordinator
    /// re-caps only at round boundaries, where the buffer is freshly
    /// drained, so the two never differ there.
    pub fn set_cap(&mut self, cap: usize) {
        assert!(cap > 0, "buffer cap must be positive");
        match cap.cmp(&self.cap) {
            Ordering::Equal => {}
            Ordering::Less => {
                self.cap = cap;
                if self.ring.len() > cap {
                    self.compact();
                }
                // len ≤ cap: τ (exact at the old, larger cap) can only
                // under-estimate the new true worst — still a safe lower
                // bound for the strict admission test, so it stands.
            }
            Ordering::Greater => {
                self.cap = cap;
                self.thresh = None;
                let want = physical(cap);
                if self.ring.capacity() < want {
                    self.ring.reserve_exact(want - self.ring.len());
                }
            }
        }
    }

    /// Retained candidates (provisional over-admissions count at most
    /// `cap` — the cut just hasn't been materialized yet).
    pub fn len(&self) -> usize {
        self.ring.len().min(self.cap)
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Offer a scored sample. Returns true if admitted — possibly
    /// provisionally: a borderline admission may still lose the next
    /// compaction cut (the heap answered against the exact worst; the
    /// ring answers against the lazy threshold).
    ///
    /// Non-finite scores are rejected outright: a NaN (or ±∞ colliding
    /// with the `unwrap_or(Equal)` fallback in the comparators) would
    /// poison the ordering and make every later cut undefined, so they
    /// must never enter the ring.
    pub fn offer(&mut self, sample: Sample, score: f64) -> bool {
        if !score.is_finite() {
            return false;
        }
        if self.ring.len() < self.cap {
            // under cap: unconditional admission, exactly the heap's
            // behaviour — and the admitted score may sit below τ, so the
            // cached threshold is no longer a valid bound
            self.thresh = None;
            self.ring.push(Candidate { sample, score });
            return true;
        }
        let t = match self.thresh {
            Some(t) => t,
            None => self.establish_thresh(),
        };
        if score > t {
            self.ring.push(Candidate { sample, score });
            if self.ring.len() == physical(self.cap) {
                self.compact();
            }
            true
        } else {
            false
        }
    }

    /// Recompute the exact worst retained score (first saturated offer
    /// after a lazy stretch). O(len) once per refill cycle.
    fn establish_thresh(&mut self) -> f64 {
        debug_assert!(self.ring.len() >= self.cap);
        if self.ring.len() > self.cap {
            self.compact();
        } else {
            self.thresh = Some(min_score(&self.ring));
        }
        // detlint: allow(R001) invariant: both branches above set self.thresh to Some
        self.thresh.expect("threshold just established")
    }

    /// Quickselect the top-`cap` under [`keep_first`], discard the rest,
    /// re-tighten τ to the exact new worst. O(len) select + O(cap) scan.
    fn compact(&mut self) {
        debug_assert!(self.ring.len() > self.cap);
        self.ring.select_nth_unstable_by(self.cap, keep_first);
        self.ring.truncate(self.cap);
        self.thresh = Some(min_score(&self.ring));
    }

    /// Current worst retained score (None if empty). Exact — when
    /// provisional over-admissions are in flight this selects the
    /// would-be-kept top-`cap` first, so it is O(len) with a scratch
    /// allocation: a diagnostic/test accessor, not a hot-path one (the
    /// hot admission test uses the lazy τ instead).
    pub fn worst_score(&self) -> Option<f64> {
        if self.ring.is_empty() {
            return None;
        }
        if self.ring.len() <= self.cap {
            return Some(min_score(&self.ring));
        }
        let mut view: Vec<Candidate> = self.ring.clone();
        view.select_nth_unstable_by(self.cap, keep_first);
        Some(min_score(&view[..self.cap]))
    }

    /// Drain all retained candidates, best-score-first (score ties:
    /// smaller id first — the order `drain_order_is_pinned`
    /// regression-tests). Materializes the eviction cut if provisional
    /// admissions are in flight, then sorts **only the winners** — the
    /// per-round cost is O(len) select + O(cap log cap) sort, independent
    /// of how many borderline offers passed through the slack.
    pub fn drain_sorted(&mut self) -> Vec<Candidate> {
        self.drain_top(usize::MAX)
    }

    /// Drain the best `min(k, len)` candidates in the canonical order and
    /// discard the rest — exactly the first `k` entries of
    /// [`CandidateBuffer::drain_sorted`], but sorting only what the
    /// caller will consume (the fine stage's importance window is capped
    /// at the artifact's `cand_max`, so anything past it was never
    /// selectable). Empties the buffer either way.
    pub fn drain_top(&mut self, k: usize) -> Vec<Candidate> {
        if self.ring.len() > self.cap {
            self.compact();
        }
        self.thresh = None;
        let mut v = std::mem::take(&mut self.ring);
        if k < v.len() {
            // winners under the canonical order = the drain prefix
            v.select_nth_unstable_by(k, best_first);
            v.truncate(k);
        }
        v.sort_unstable_by(best_first);
        v
    }

    /// Peek at the retained candidates (unsorted; may include provisional
    /// over-admissions that the next cut will discard).
    pub fn iter(&self) -> impl Iterator<Item = &Candidate> {
        self.ring.iter()
    }

    /// Deterministic snapshot of every ring slot, best-first (same
    /// comparator as [`CandidateBuffer::drain_sorted`]) — the
    /// serialization order for session checkpoints. Provisional entries
    /// are included: together with [`CandidateBuffer::thresh`] they make
    /// restore-then-continue bit-identical to never having snapshotted.
    /// Non-destructive; sample payloads are `Arc`-shared, so the clones
    /// are cheap.
    pub fn snapshot(&self) -> Vec<Candidate> {
        let mut v: Vec<Candidate> = self.ring.iter().cloned().collect();
        v.sort_unstable_by(best_first);
        v
    }

    /// Replace the ring contents with a [`CandidateBuffer::snapshot`] and
    /// its exported threshold (checkpoint restore). Storage order inside
    /// the ring never affects behaviour — every cut is a selection under
    /// a total order — so the sorted snapshot restores faithfully.
    /// Errors on more items than the ring could ever hold live
    /// (`2·cap - 1`), non-finite scores, or a non-finite threshold (none
    /// of which [`CandidateBuffer::offer`] could have produced).
    pub fn restore(&mut self, items: Vec<Candidate>, thresh: Option<f64>) -> crate::Result<()> {
        if items.len() >= physical(self.cap) {
            return Err(crate::Error::Config(format!(
                "buffer restore: {} candidates ≥ ring capacity {} (cap {})",
                items.len(),
                physical(self.cap),
                self.cap
            )));
        }
        if items.iter().any(|c| !c.score.is_finite()) {
            return Err(crate::Error::Config(
                "buffer restore: non-finite candidate score".into(),
            ));
        }
        if let Some(t) = thresh {
            if !t.is_finite() {
                return Err(crate::Error::Config(
                    "buffer restore: non-finite admission threshold".into(),
                ));
            }
        }
        self.ring.clear();
        self.ring.extend(items);
        self.thresh = thresh;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(id: u64) -> Sample {
        Sample::new(id, 0, vec![0.0])
    }

    #[test]
    fn keeps_top_k() {
        let mut b = CandidateBuffer::new(3);
        for (id, score) in [(0, 1.0), (1, 5.0), (2, 3.0), (3, 4.0), (4, 0.5)] {
            b.offer(s(id), score);
        }
        let drained = b.drain_sorted();
        let ids: Vec<u64> = drained.iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![1, 3, 2]); // scores 5, 4, 3
    }

    #[test]
    fn rejects_below_worst_when_full() {
        let mut b = CandidateBuffer::new(2);
        assert!(b.offer(s(0), 2.0));
        assert!(b.offer(s(1), 3.0));
        assert!(!b.offer(s(2), 1.0));
        assert_eq!(b.len(), 2);
        assert_eq!(b.worst_score(), Some(2.0));
    }

    #[test]
    fn eviction_updates_worst() {
        let mut b = CandidateBuffer::new(2);
        b.offer(s(0), 1.0);
        b.offer(s(1), 2.0);
        assert!(b.offer(s(2), 5.0)); // displaces score 1.0 from the top-2
        assert_eq!(b.worst_score(), Some(2.0));
    }

    #[test]
    fn deterministic_tiebreak_by_id() {
        let mut b = CandidateBuffer::new(2);
        b.offer(s(5), 1.0);
        b.offer(s(3), 1.0);
        b.offer(s(4), 1.0); // equal score: not above the threshold -> rejected
        let ids: Vec<u64> = b.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![3, 5]);
    }

    #[test]
    fn drain_order_is_pinned() {
        // regression pin: strict score descent, id ascending within score
        // ties — exactly what the fine stage has always consumed. Mixed
        // offer order exercises both the under-cap path and threshold
        // rejection.
        let mut b = CandidateBuffer::new(6);
        for (id, score) in [
            (9u64, 2.0),
            (1, 3.0),
            (7, 2.0),
            (3, 3.0),
            (5, 1.0),
            (2, 2.0),
            (4, 0.5), // rejected: below the worst retained
        ] {
            b.offer(s(id), score);
        }
        let drained = b.drain_sorted();
        let order: Vec<(u64, f64)> = drained.iter().map(|c| (c.sample.id, c.score)).collect();
        assert_eq!(
            order,
            vec![(1, 3.0), (3, 3.0), (2, 2.0), (7, 2.0), (9, 2.0), (5, 1.0)]
        );
    }

    #[test]
    fn drain_empties() {
        let mut b = CandidateBuffer::new(4);
        b.offer(s(0), 1.0);
        assert_eq!(b.drain_sorted().len(), 1);
        assert!(b.is_empty());
        assert_eq!(b.thresh(), None, "drain resets the lazy threshold");
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_cap_panics() {
        CandidateBuffer::new(0);
    }

    #[test]
    fn rejects_non_finite_scores() {
        // regression: NaN/∞ used to enter the heap and poison the
        // partial_cmp().unwrap_or(Equal) ordering
        let mut b = CandidateBuffer::new(3);
        assert!(!b.offer(s(0), f64::NAN));
        assert!(!b.offer(s(1), f64::INFINITY));
        assert!(!b.offer(s(2), f64::NEG_INFINITY));
        assert!(b.is_empty());
        // a finite stream around the rejects behaves exactly as before
        assert!(b.offer(s(3), 2.0));
        assert!(!b.offer(s(4), f64::NAN));
        assert!(b.offer(s(5), 3.0));
        assert!(b.offer(s(6), 1.0)); // fills to cap
        assert!(!b.offer(s(7), f64::INFINITY)); // would displace if admitted
        assert_eq!(b.worst_score(), Some(1.0));
        let ids: Vec<u64> = b.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![5, 3, 6]);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut b = CandidateBuffer::new(4);
        for (id, score) in [(3u64, 2.0), (1, 5.0), (2, 2.0), (9, 4.0), (5, 1.0)] {
            b.offer(s(id), score);
        }
        let snap = b.snapshot();
        let order: Vec<u64> = snap.iter().map(|c| c.sample.id).collect();
        assert_eq!(order, vec![1, 9, 2, 3], "best-first, id-tiebroken");
        assert_eq!(b.len(), 4, "snapshot is non-destructive");
        assert_eq!(b.thresh(), Some(2.0), "rejecting (5, 1.0) established τ");

        let mut restored = CandidateBuffer::new(4);
        restored.restore(snap.clone(), b.thresh()).unwrap();
        assert_eq!(restored.len(), 4);
        assert_eq!(restored.thresh(), b.thresh());
        // restored buffer admits, cuts and drains exactly like the original
        assert!(restored.offer(s(7), 3.0));
        assert!(b.offer(s(7), 3.0));
        let a: Vec<(u64, f64)> = b.drain_sorted().iter().map(|c| (c.sample.id, c.score)).collect();
        let r: Vec<(u64, f64)> =
            restored.drain_sorted().iter().map(|c| (c.sample.id, c.score)).collect();
        assert_eq!(a, r);
        assert_eq!(
            a,
            vec![(1, 5.0), (9, 4.0), (7, 3.0), (3, 2.0)],
            "score-2 tie at the cut evicts the smaller id (2) first"
        );

        // over-capacity and non-finite snapshots are rejected
        let mut tiny = CandidateBuffer::new(2);
        assert!(tiny.restore(snap, None).is_err(), "4 items ≥ 2·cap");
        let bad = vec![Candidate { sample: s(0), score: f64::NAN }];
        assert!(tiny.restore(bad, None).is_err());
        assert!(tiny.restore(Vec::new(), Some(f64::NAN)).is_err());
    }

    #[test]
    fn mid_slack_snapshot_restores_bit_identically() {
        // snapshot taken while provisional admissions are in flight must
        // carry them + τ so the restored ring continues identically
        let mut live = CandidateBuffer::new(2);
        live.offer(s(0), 1.0);
        live.offer(s(1), 2.0);
        assert!(live.offer(s(2), 3.0)); // saturated admit -> slack, τ = 1.0
        assert_eq!(live.thresh(), Some(1.0));
        assert_eq!(live.snapshot().len(), 3, "provisional entry included");

        let mut restored = CandidateBuffer::new(2);
        restored.restore(live.snapshot(), live.thresh()).unwrap();
        // identical behaviour on the borderline offer τ < 1.5 < true worst
        assert_eq!(restored.offer(s(3), 1.5), live.offer(s(3), 1.5));
        let a: Vec<u64> = live.drain_sorted().iter().map(|c| c.sample.id).collect();
        let b: Vec<u64> = restored.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(a, b);
        assert_eq!(a, vec![2, 1], "borderline 1.5 lost the cut");
    }

    #[test]
    fn set_cap_shrinks_to_best_in_place() {
        let mut b = CandidateBuffer::new(5);
        for (id, score) in [(0, 1.0), (1, 5.0), (2, 3.0), (3, 4.0), (4, 0.5)] {
            b.offer(s(id), score);
        }
        b.set_cap(2);
        assert_eq!(b.cap(), 2);
        assert_eq!(b.len(), 2);
        let ids: Vec<u64> = b.drain_sorted().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids, vec![1, 3]); // scores 5, 4 survive
    }

    #[test]
    fn set_cap_grow_keeps_entries_and_accepts_more() {
        let mut b = CandidateBuffer::new(2);
        b.offer(s(0), 1.0);
        b.offer(s(1), 2.0);
        assert!(!b.offer(s(2), 0.5));
        b.set_cap(3);
        assert_eq!(b.len(), 2);
        assert!(b.offer(s(3), 0.25)); // room again, sub-τ scores included
        assert_eq!(b.len(), 3);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn set_cap_zero_panics() {
        let mut b = CandidateBuffer::new(2);
        b.set_cap(0);
    }

    #[test]
    fn same_cap_recap_is_a_no_op() {
        // the idle-budget adaptation re-caps every round; an unchanged
        // budget must not disturb the ring, the threshold, or the drain
        let mut a = CandidateBuffer::new(4);
        let mut b = CandidateBuffer::new(4);
        for (id, score) in [(0u64, 2.0), (1, 7.0), (2, 4.0), (3, 1.0), (4, 6.0), (5, 3.0)] {
            a.offer(s(id), score);
            b.offer(s(id), score);
            b.set_cap(4); // no-op re-cap between every offer
        }
        assert_eq!(b.cap(), 4);
        assert_eq!(a.thresh(), b.thresh());
        assert_eq!(a.len(), b.len());
        let da: Vec<(u64, f64)> = a.drain_sorted().iter().map(|c| (c.sample.id, c.score)).collect();
        let db: Vec<(u64, f64)> = b.drain_sorted().iter().map(|c| (c.sample.id, c.score)).collect();
        assert_eq!(da, db);
    }

    #[test]
    fn drain_top_is_prefix_of_drain_sorted() {
        let offers = [
            (0u64, 2.0),
            (1, 7.0),
            (2, 4.0),
            (3, 1.0),
            (4, 6.0),
            (5, 3.0),
            (6, 4.0),
            (7, 5.5),
        ];
        for k in 0..=6usize {
            let mut full = CandidateBuffer::new(4);
            let mut top = CandidateBuffer::new(4);
            for &(id, score) in &offers {
                full.offer(s(id), score);
                top.offer(s(id), score);
            }
            let want: Vec<(u64, f64)> = full
                .drain_sorted()
                .iter()
                .take(k)
                .map(|c| (c.sample.id, c.score))
                .collect();
            let got: Vec<(u64, f64)> =
                top.drain_top(k).iter().map(|c| (c.sample.id, c.score)).collect();
            assert_eq!(got, want, "k = {k}");
            assert!(top.is_empty(), "drain_top empties the ring");
        }
    }

    /// The pre-ring implementation, verbatim, as the equivalence oracle:
    /// a capped min-heap on (score, id) with strict-greater admission.
    struct HeapOracle {
        heap: std::collections::BinaryHeap<OracleEntry>,
        cap: usize,
    }

    /// Max-heap entry whose "greatest" element is the worst retained
    /// candidate (smallest score, then smallest id) — the old Ord.
    struct OracleEntry(Candidate);

    impl PartialEq for OracleEntry {
        fn eq(&self, other: &Self) -> bool {
            self.0 == other.0
        }
    }
    impl Eq for OracleEntry {}
    impl PartialOrd for OracleEntry {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for OracleEntry {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .0
                .score
                .partial_cmp(&self.0.score)
                .unwrap_or(Ordering::Equal)
                .then_with(|| other.0.sample.id.cmp(&self.0.sample.id))
        }
    }

    impl HeapOracle {
        fn new(cap: usize) -> Self {
            Self { heap: std::collections::BinaryHeap::new(), cap }
        }

        fn offer(&mut self, sample: Sample, score: f64) {
            if !score.is_finite() {
                return;
            }
            if self.heap.len() < self.cap {
                self.heap.push(OracleEntry(Candidate { sample, score }));
                return;
            }
            if let Some(worst) = self.heap.peek() {
                if score > worst.0.score {
                    self.heap.pop();
                    self.heap.push(OracleEntry(Candidate { sample, score }));
                }
            }
        }

        fn set_cap(&mut self, cap: usize) {
            while self.heap.len() > cap {
                self.heap.pop();
            }
            self.cap = cap;
        }

        fn drain_sorted(&mut self) -> Vec<Candidate> {
            let mut v: Vec<Candidate> =
                std::mem::take(&mut self.heap).into_iter().map(|e| e.0).collect();
            v.sort_unstable_by(best_first);
            v
        }

        fn worst_score(&self) -> Option<f64> {
            self.heap.peek().map(|e| e.0.score)
        }
    }

    /// Distinct-score streams: the ring's drains, worst scores and
    /// retained sets must match the heap exactly, through interleaved
    /// re-caps and multi-round drains. (Per-offer return values may
    /// legitimately differ — provisional admissions — so they are not
    /// compared.)
    #[test]
    fn ring_matches_heap_oracle_on_distinct_scores() {
        crate::util::prop::forall(
            313,
            40,
            |rng| crate::util::prop::gen::f64_vec(rng, 3, 3, 0.0, 1.0),
            |seedvec| {
                let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(
                    (seedvec.iter().sum::<f64>() * 1e6) as u64 ^ 0x21F6,
                );
                let cap = 1 + rng.index(12);
                let mut ring = CandidateBuffer::new(cap);
                let mut oracle = HeapOracle::new(cap);
                let mut next_id = 0u64;
                for _round in 0..3 {
                    // occasional symmetric re-cap (idle-budget shape)
                    if rng.next_f64() < 0.4 {
                        let new_cap = 1 + rng.index(12);
                        ring.set_cap(new_cap);
                        oracle.set_cap(new_cap);
                    }
                    let offers = 1 + rng.index(5 * cap + 10);
                    for _ in 0..offers {
                        // a tiny id-proportional offset keeps scores
                        // distinct (ties are the documented divergence)
                        let score = rng.next_f64() * 100.0 + next_id as f64 * 1e-6;
                        ring.offer(s(next_id), score);
                        oracle.offer(s(next_id), score);
                        next_id += 1;
                    }
                    let (rw, ow) = (ring.worst_score(), oracle.worst_score());
                    if rw != ow {
                        return Err(format!("worst {rw:?} != oracle {ow:?}"));
                    }
                    let rd: Vec<(u64, u64)> = ring
                        .drain_sorted()
                        .iter()
                        .map(|c| (c.sample.id, c.score.to_bits()))
                        .collect();
                    let od: Vec<(u64, u64)> = oracle
                        .drain_sorted()
                        .iter()
                        .map(|c| (c.sample.id, c.score.to_bits()))
                        .collect();
                    if rd != od {
                        return Err(format!("drain {rd:?} != oracle {od:?}"));
                    }
                }
                Ok(())
            },
        );
    }
}
