//! Class-indexed sample store — the on-device storage `S` with per-class
//! shards `S_y` from the paper's formulation. Bounded capacity with
//! reservoir-style eviction (devices cannot keep the whole stream).

use crate::data::sample::Sample;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// Bounded, class-indexed sample store.
///
/// `|S_y|` counts track *all* samples ever offered per class (the stream
/// frequencies the C-IS allocation uses), while the retained samples are a
/// uniform reservoir per class — matching the paper's setting where
/// storage holds a subset but class frequencies are observable.
#[derive(Debug)]
pub struct ClassStore {
    per_class: Vec<Vec<Sample>>,
    seen_per_class: Vec<u64>,
    cap_per_class: usize,
    rng: Xoshiro256,
}

impl ClassStore {
    pub fn new(num_classes: usize, cap_per_class: usize, seed: u64) -> Self {
        Self {
            per_class: vec![Vec::new(); num_classes],
            seen_per_class: vec![0; num_classes],
            cap_per_class,
            rng: Xoshiro256::seed_from_u64(seed ^ 0x5708_E0),
        }
    }

    pub fn num_classes(&self) -> usize {
        self.per_class.len()
    }

    /// Offer a sample; reservoir-evict if the class shard is full.
    ///
    /// An out-of-range label is a data-plane error (a corrupted stream or
    /// a misconfigured `num_classes`), not a programming invariant — it
    /// returns [`Error::Data`] instead of panicking, leaving the store
    /// untouched. (For budget-relative balancing across classes see
    /// [`crate::retention::ClassBalanced`], which supersedes this fixed
    /// `cap_per_class` scheme for cross-round retention.)
    pub fn offer(&mut self, s: Sample) -> Result<()> {
        let y = s.label as usize;
        if y >= self.per_class.len() {
            return Err(Error::Data(format!(
                "ClassStore::offer: label {y} out of range (num_classes {})",
                self.per_class.len()
            )));
        }
        self.seen_per_class[y] += 1;
        let shard = &mut self.per_class[y];
        if shard.len() < self.cap_per_class {
            shard.push(s);
        } else {
            // classic reservoir: replace with prob cap/seen
            let seen = self.seen_per_class[y];
            let j = self.rng.next_below(seen);
            if (j as usize) < self.cap_per_class {
                shard[j as usize] = s;
            }
        }
        Ok(())
    }

    /// Samples currently stored for class y.
    pub fn class(&self, y: usize) -> &[Sample] {
        &self.per_class[y]
    }

    /// Total samples ever seen for class y (the |S_y| of Eq. 2).
    pub fn seen(&self, y: usize) -> u64 {
        self.seen_per_class[y]
    }

    pub fn stored_total(&self) -> usize {
        self.per_class.iter().map(|v| v.len()).sum()
    }

    /// All stored samples, flattened (class-major order).
    pub fn all(&self) -> Vec<&Sample> {
        self.per_class.iter().flatten().collect()
    }

    /// Memory footprint of the stored payloads in bytes (for Fig. 6c).
    pub fn payload_bytes(&self) -> usize {
        self.per_class
            .iter()
            .flatten()
            .map(|s| s.dim() * std::mem::size_of::<f32>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(id: u64, label: u32) -> Sample {
        Sample::new(id, label, vec![id as f32; 4])
    }

    #[test]
    fn fills_then_reservoir_evicts() {
        let mut st = ClassStore::new(2, 5, 1);
        for i in 0..50 {
            st.offer(sample(i, 0)).unwrap();
        }
        assert_eq!(st.class(0).len(), 5);
        assert_eq!(st.seen(0), 50);
        assert_eq!(st.class(1).len(), 0);
        // reservoir keeps a spread, not just the first 5
        assert!(
            st.class(0).iter().any(|s| s.id >= 5),
            "no late sample retained: {:?}",
            st.class(0).iter().map(|s| s.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn reservoir_is_roughly_uniform() {
        // each of 100 offered ids should be retained ~ cap/100 of the time
        let mut hits = vec![0usize; 100];
        for seed in 0..300 {
            let mut st = ClassStore::new(1, 10, seed);
            for i in 0..100 {
                st.offer(sample(i, 0)).unwrap();
            }
            for s in st.class(0) {
                hits[s.id as usize] += 1;
            }
        }
        // expected 30 hits per id (300 trials * 10/100); allow wide slack
        for (i, &h) in hits.iter().enumerate() {
            assert!((5..80).contains(&h), "id {i}: {h} retentions");
        }
    }

    #[test]
    fn totals_and_payload() {
        let mut st = ClassStore::new(3, 4, 2);
        for i in 0..6 {
            st.offer(sample(i, (i % 3) as u32)).unwrap();
        }
        assert_eq!(st.stored_total(), 6);
        assert_eq!(st.all().len(), 6);
        assert_eq!(st.payload_bytes(), 6 * 4 * 4);
    }

    #[test]
    fn bad_label_is_a_typed_error_not_a_panic() {
        // regression: this used to assert!-panic; a corrupted stream must
        // surface as Error::Data and leave the store untouched
        let mut st = ClassStore::new(2, 4, 3);
        match st.offer(sample(0, 9)) {
            Err(crate::Error::Data(msg)) => assert!(msg.contains("out of range"), "{msg}"),
            other => panic!("expected Error::Data, got {other:?}"),
        }
        assert_eq!(st.stored_total(), 0);
        assert_eq!(st.seen(0) + st.seen(1), 0, "rejected offer must not count");
        // the store still works after the rejection
        st.offer(sample(1, 1)).unwrap();
        assert_eq!(st.stored_total(), 1);
    }
}
