//! Pluggable round-based data sources — the seam between the data plane
//! and the coordinator session loop.
//!
//! The paper evaluates Titan against one deployment shape (a synthetic
//! stream at fixed velocity), but the selection machinery only ever needs
//! three things from its data: the task geometry, one round of arrivals,
//! and a held-out test set. [`DataSource`] is that contract, object-safe
//! so a session can own `Box<dyn DataSource>` and ship it across the
//! pipeline's selector thread.
//!
//! Implementations here:
//! - [`StreamSource`] (in `stream.rs`) — the default velocity-controlled
//!   synthetic stream with noise injection.
//! - [`ReplaySource`] — cyclic replay of a captured sample pool (the
//!   "to store or not" on-device store shape: a bounded buffer replayed
//!   across rounds instead of fresh arrivals).
//! - [`ClassSubsetSource`] — a non-IID stream restricted to a class
//!   subset (the federated Appendix-B device shape).

use crate::data::sample::Sample;
use crate::data::stream::StreamSource;
use crate::data::synth::SynthTask;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// A round-based data source feeding one training run.
///
/// Object-safe: sessions hold `Box<dyn DataSource>` and the pipelined
/// backend moves it onto the selector thread, hence the `Send` bound.
pub trait DataSource: Send {
    /// The synthetic task this source draws from. Fixes input dims and
    /// class count; the engines validate artifact compatibility against
    /// it at session start.
    fn task(&self) -> &SynthTask;

    /// Pull one round's worth of arrivals (`v` samples).
    fn next_round(&mut self, v: usize) -> Vec<Sample>;

    /// Deterministic held-out test set (drawn from the clean
    /// distribution, on an RNG stream independent of the arrivals).
    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample>;
}

impl DataSource for StreamSource {
    fn task(&self) -> &SynthTask {
        StreamSource::task(self)
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        StreamSource::next_round(self, v)
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        StreamSource::task(self).test_set(n, seed)
    }
}

/// Cyclic replay over a fixed sample pool.
///
/// Models the on-device store deployment: a bounded set of retained
/// samples is replayed round after round (data-scarce regime), instead of
/// fresh stream arrivals. Deterministic: round `r` starts where round
/// `r-1`'s cursor stopped, wrapping over the pool.
pub struct ReplaySource {
    task: SynthTask,
    pool: Vec<Sample>,
    cursor: usize,
}

impl ReplaySource {
    /// Build from an explicit pool. Errors on an empty pool.
    pub fn new(task: SynthTask, pool: Vec<Sample>) -> Result<ReplaySource> {
        if pool.is_empty() {
            return Err(Error::Config("ReplaySource needs a non-empty pool".into()));
        }
        Ok(ReplaySource { task, pool, cursor: 0 })
    }

    /// Capture `n` samples from another source into a replay pool.
    pub fn capture(source: &mut dyn DataSource, n: usize) -> Result<ReplaySource> {
        let pool = source.next_round(n);
        ReplaySource::new(source.task().clone(), pool)
    }

    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }
}

impl DataSource for ReplaySource {
    fn task(&self) -> &SynthTask {
        &self.task
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        (0..v)
            .map(|_| {
                let s = self.pool[self.cursor].clone();
                self.cursor = (self.cursor + 1) % self.pool.len();
                s
            })
            .collect()
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.task.test_set(n, seed)
    }
}

/// Non-IID stream restricted to a class subset — one federated device's
/// local distribution (paper Appendix B: each device sees 5 of C classes).
///
/// Draw order per sample (pick class, then draw from it, one shared RNG)
/// matches the original FL orchestrator's device streams bit-for-bit, so
/// migrating `fl::run` onto this source preserved its results.
pub struct ClassSubsetSource {
    task: SynthTask,
    classes: Vec<u32>,
    rng: Xoshiro256,
    next_id: u64,
}

impl ClassSubsetSource {
    /// `seed` is used verbatim (no internal xor) so callers control the
    /// exact RNG stream.
    pub fn new(task: SynthTask, classes: Vec<u32>, seed: u64) -> Result<ClassSubsetSource> {
        if classes.is_empty() {
            return Err(Error::Config("ClassSubsetSource needs >= 1 class".into()));
        }
        let c = task.num_classes() as u32;
        if let Some(&bad) = classes.iter().find(|&&y| y >= c) {
            return Err(Error::Config(format!(
                "ClassSubsetSource class {bad} out of range (task has {c} classes)"
            )));
        }
        Ok(ClassSubsetSource {
            task,
            classes,
            rng: Xoshiro256::seed_from_u64(seed),
            next_id: 0,
        })
    }
}

impl DataSource for ClassSubsetSource {
    fn task(&self) -> &SynthTask {
        &self.task
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        (0..v)
            .map(|_| {
                let y = self.classes[self.rng.index(self.classes.len())];
                let id = self.next_id;
                self.next_id += 1;
                self.task.draw_class(id, y, &mut self.rng)
            })
            .collect()
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.task.test_set(n, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NoiseKind;
    use crate::data::synth::TaskSpec;

    fn task() -> SynthTask {
        SynthTask::new(TaskSpec::Har, 3, 0.2, 0.1)
    }

    #[test]
    fn stream_source_is_a_data_source() {
        let mut boxed: Box<dyn DataSource> =
            Box::new(StreamSource::new(task(), 5, NoiseKind::None));
        let round = boxed.next_round(20);
        assert_eq!(round.len(), 20);
        assert_eq!(boxed.task().num_classes(), 6);
        // trait test_set matches the task's directly
        let a = boxed.test_set(10, 5);
        let b = task().test_set(10, 5);
        assert_eq!(a.len(), b.len());
        assert_eq!(*a[3].x, *b[3].x);
    }

    #[test]
    fn replay_cycles_deterministically() {
        let mut stream = StreamSource::new(task(), 7, NoiseKind::None);
        let mut replay = ReplaySource::capture(&mut stream, 5).unwrap();
        assert_eq!(replay.pool_len(), 5);
        let r1 = replay.next_round(7); // wraps: ids 0..5 then 0,1
        assert_eq!(r1.len(), 7);
        assert_eq!(r1[0].id, r1[5].id);
        assert_eq!(r1[1].id, r1[6].id);
        // the cursor persists across rounds
        let r2 = replay.next_round(3); // continues at pool index 2
        assert_eq!(r2[0].id, r1[2].id);
    }

    #[test]
    fn replay_rejects_empty_pool() {
        assert!(ReplaySource::new(task(), Vec::new()).is_err());
    }

    #[test]
    fn class_subset_only_emits_its_classes() {
        let mut src = ClassSubsetSource::new(task(), vec![1, 4], 42).unwrap();
        for s in src.next_round(200) {
            assert!(s.label == 1 || s.label == 4, "label {}", s.label);
        }
    }

    #[test]
    fn class_subset_deterministic_under_seed() {
        let mut a = ClassSubsetSource::new(task(), vec![0, 2, 3], 9).unwrap();
        let mut b = ClassSubsetSource::new(task(), vec![0, 2, 3], 9).unwrap();
        let (ra, rb) = (a.next_round(30), b.next_round(30));
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.label, y.label);
            assert_eq!(*x.x, *y.x);
        }
    }

    #[test]
    fn class_subset_validates_classes() {
        assert!(ClassSubsetSource::new(task(), vec![], 1).is_err());
        assert!(ClassSubsetSource::new(task(), vec![6], 1).is_err());
        assert!(ClassSubsetSource::new(task(), vec![5], 1).is_ok());
    }
}
