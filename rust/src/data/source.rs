//! Pluggable round-based data sources — the seam between the data plane
//! and the coordinator session loop.
//!
//! The paper evaluates Titan against one deployment shape (a synthetic
//! stream at fixed velocity), but the selection machinery only ever needs
//! three things from its data: the task geometry, one round of arrivals,
//! and a held-out test set. [`DataSource`] is that contract, object-safe
//! so a session can own `Box<dyn DataSource>` and ship it across the
//! pipeline's selector thread.
//!
//! Implementations here:
//! - [`StreamSource`] (in `stream.rs`) — the default velocity-controlled
//!   synthetic stream with noise injection.
//! - [`ReplaySource`] — cyclic replay of a captured sample pool (the
//!   "to store or not" on-device store shape: a bounded buffer replayed
//!   across rounds instead of fresh arrivals).
//! - [`ClassSubsetSource`] — a non-IID stream restricted to a class
//!   subset (the federated Appendix-B device shape).
//! - [`DriftSource`] — a time-varying class mix (linear interpolation
//!   between two class distributions over rounds), the continual-learning
//!   stream shape.

use crate::data::buffer::Candidate;
use crate::data::sample::Sample;
use crate::data::stream::StreamSource;
use crate::data::synth::SynthTask;
use crate::retention::{RetentionState, RetentionTelemetry};
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// A round-based data source feeding one training run.
///
/// Object-safe: sessions hold `Box<dyn DataSource>` and the pipelined
/// backend moves it onto the selector thread, hence the `Send` bound.
pub trait DataSource: Send {
    /// The synthetic task this source draws from. Fixes input dims and
    /// class count; the engines validate artifact compatibility against
    /// it at session start.
    fn task(&self) -> &SynthTask;

    /// Pull one round's worth of arrivals (`v` samples).
    fn next_round(&mut self, v: usize) -> Vec<Sample>;

    /// Deterministic held-out test set (drawn from the clean
    /// distribution, on an RNG stream independent of the arrivals).
    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample>;

    /// Skip `rounds` rounds of `v` arrivals each — checkpoint resume
    /// brings a freshly built source to its mid-run cursor this way.
    ///
    /// The default draws and discards, which is exact for every
    /// deterministic source (it replays precisely the RNG consumption and
    /// counter advances of the completed rounds). Sources with a cheap
    /// explicit cursor (e.g. [`ReplaySource`]) override it with O(1)
    /// arithmetic.
    fn fast_forward(&mut self, rounds: usize, v: usize) {
        for _ in 0..rounds {
            // detlint: allow(R002) draw-and-discard IS the fast-forward: only the RNG advance matters
            let _ = self.next_round(v);
        }
    }

    // ---- retention seam (third selection stage) --------------------------
    //
    // Default no-ops keep every plain source oblivious to retention; only
    // [`crate::data::RetainedSource`] overrides these. The session feed
    // calls them after each round's selection, on whichever thread owns
    // the source — sequentially in both backends, so no locking is
    // involved.

    /// Whether this source retains samples across rounds. The session
    /// uses this to decide whether to capture scored candidates after
    /// selection — a non-retaining run must not pay for the clone.
    fn retains(&self) -> bool {
        false
    }

    /// Offer one round's scored candidates (the filter-stage output, or
    /// the candidate window at score 0 for methods without a filter) to
    /// the retention store. Default: drop them.
    fn offer_retention(&mut self, _scored: Vec<Candidate>) {}

    /// Cumulative [`RetentionTelemetry`], if this source retains.
    fn retention_stats(&self) -> Option<RetentionTelemetry> {
        None
    }

    /// Export the retention state (store contents + policy state + blend
    /// RNG) for a session checkpoint.
    fn export_retention(&self) -> Option<RetentionState> {
        None
    }

    /// Restore retention state from a checkpoint. `fast_forward` alone
    /// cannot rebuild a retaining source — the store depends on past
    /// selection outcomes, not just the stream — so resume pairs the two.
    /// Errors on sources that do not retain.
    fn restore_retention(&mut self, _st: RetentionState) -> Result<()> {
        Err(Error::Data(
            "this data source does not retain samples (no retention state expected)".into(),
        ))
    }
}

impl DataSource for StreamSource {
    fn task(&self) -> &SynthTask {
        StreamSource::task(self)
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        StreamSource::next_round(self, v)
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        StreamSource::task(self).test_set(n, seed)
    }

    fn fast_forward(&mut self, rounds: usize, v: usize) {
        StreamSource::skip_rounds(self, rounds, v)
    }
}

/// Cyclic replay over a fixed sample pool.
///
/// Models the on-device store deployment: a bounded set of retained
/// samples is replayed round after round (data-scarce regime), instead of
/// fresh stream arrivals. Deterministic: round `r` starts where round
/// `r-1`'s cursor stopped, wrapping over the pool.
pub struct ReplaySource {
    task: SynthTask,
    pool: Vec<Sample>,
    cursor: usize,
}

impl ReplaySource {
    /// Build from an explicit pool. Errors on an empty pool.
    pub fn new(task: SynthTask, pool: Vec<Sample>) -> Result<ReplaySource> {
        if pool.is_empty() {
            return Err(Error::Config("ReplaySource needs a non-empty pool".into()));
        }
        Ok(ReplaySource { task, pool, cursor: 0 })
    }

    /// Capture `n` samples from another source into a replay pool.
    ///
    /// Cursor contract: this consumes exactly one `next_round(n)` from
    /// `source` — its stream position advances by `n` samples and nothing
    /// else about it changes, so the caller can keep drawing from it and
    /// the first post-capture sample is the `n+1`-th of its stream
    /// (`capture_advances_the_source_by_exactly_n` pins this). `n == 0`
    /// is rejected here as a typed error — it used to fall through to
    /// [`ReplaySource::new`]'s misleading "non-empty pool" failure.
    pub fn capture(source: &mut dyn DataSource, n: usize) -> Result<ReplaySource> {
        if n == 0 {
            return Err(Error::Data(
                "ReplaySource::capture: n == 0 captures nothing (need n > 0)".into(),
            ));
        }
        let pool = source.next_round(n);
        ReplaySource::new(source.task().clone(), pool)
    }

    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }
}

impl DataSource for ReplaySource {
    fn task(&self) -> &SynthTask {
        &self.task
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        (0..v)
            .map(|_| {
                let s = self.pool[self.cursor].clone();
                self.cursor = (self.cursor + 1) % self.pool.len();
                s
            })
            .collect()
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.task.test_set(n, seed)
    }

    fn fast_forward(&mut self, rounds: usize, v: usize) {
        // cursor arithmetic replaces rounds × v sample clones — replay is
        // the case the trait docs mean by "a cursor is cheaper"
        self.cursor = (self.cursor + rounds * v) % self.pool.len();
    }
}

/// Non-IID stream restricted to a class subset — one federated device's
/// local distribution (paper Appendix B: each device sees 5 of C classes).
///
/// Draw order per sample (pick class, then draw from it, one shared RNG)
/// matches the original FL orchestrator's device streams bit-for-bit, so
/// migrating `fl::run` onto this source preserved its results.
pub struct ClassSubsetSource {
    task: SynthTask,
    classes: Vec<u32>,
    rng: Xoshiro256,
    next_id: u64,
}

impl ClassSubsetSource {
    /// `seed` is used verbatim (no internal xor) so callers control the
    /// exact RNG stream.
    pub fn new(task: SynthTask, classes: Vec<u32>, seed: u64) -> Result<ClassSubsetSource> {
        if classes.is_empty() {
            return Err(Error::Config("ClassSubsetSource needs >= 1 class".into()));
        }
        let c = task.num_classes() as u32;
        if let Some(&bad) = classes.iter().find(|&&y| y >= c) {
            return Err(Error::Config(format!(
                "ClassSubsetSource class {bad} out of range (task has {c} classes)"
            )));
        }
        Ok(ClassSubsetSource {
            task,
            classes,
            rng: Xoshiro256::seed_from_u64(seed),
            next_id: 0,
        })
    }
}

impl DataSource for ClassSubsetSource {
    fn task(&self) -> &SynthTask {
        &self.task
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        (0..v)
            .map(|_| {
                let y = self.classes[self.rng.index(self.classes.len())];
                let id = self.next_id;
                self.next_id += 1;
                self.task.draw_class(id, y, &mut self.rng)
            })
            .collect()
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.task.test_set(n, seed)
    }
}

/// Time-varying class mix — the continual-learning stream shape.
///
/// Per-class sampling weights interpolate linearly from `start` to `end`
/// over the first `drift_rounds` calls to `next_round`, then hold at
/// `end`. Each sample draws its class from the interpolated categorical
/// and its input from that class's clean mixture, so the stream's class
/// marginal drifts while the class-conditional distributions stay fixed —
/// the regime where a static candidate buffer goes stale and selection
/// has to re-balance (cf. the "To Store or Not" online-selection setting).
///
/// Deterministic under `seed`: the round counter alone decides the mix.
pub struct DriftSource {
    task: SynthTask,
    start: Vec<f64>,
    end: Vec<f64>,
    drift_rounds: usize,
    round: usize,
    rng: Xoshiro256,
    next_id: u64,
    /// Reused interpolated-weight buffer (no per-round allocation).
    weights: Vec<f64>,
}

impl DriftSource {
    /// `start`/`end` are unnormalized per-class weights (one per task
    /// class, non-negative, positive total mass); `drift_rounds` > 0 is
    /// the interpolation horizon; `seed` is used verbatim.
    pub fn new(
        task: SynthTask,
        start: Vec<f64>,
        end: Vec<f64>,
        drift_rounds: usize,
        seed: u64,
    ) -> Result<DriftSource> {
        let c = task.num_classes();
        for (name, w) in [("start", &start), ("end", &end)] {
            if w.len() != c {
                return Err(Error::Config(format!(
                    "DriftSource {name} mix has {} weights, task has {c} classes",
                    w.len()
                )));
            }
            if w.iter().any(|&x| x < 0.0 || !x.is_finite()) {
                return Err(Error::Config(format!(
                    "DriftSource {name} mix must be non-negative and finite"
                )));
            }
            if crate::util::stats::sum(w) <= 0.0 {
                return Err(Error::Config(format!(
                    "DriftSource {name} mix must have positive total mass"
                )));
            }
        }
        if drift_rounds == 0 {
            return Err(Error::Config("DriftSource drift_rounds must be > 0".into()));
        }
        Ok(DriftSource {
            weights: vec![0.0; c],
            task,
            start,
            end,
            drift_rounds,
            round: 0,
            rng: Xoshiro256::seed_from_u64(seed),
            next_id: 0,
        })
    }

    /// Interpolation progress at `round`, in [0, 1].
    pub fn progress(&self, round: usize) -> f64 {
        (round as f64 / self.drift_rounds as f64).min(1.0)
    }

    /// Rounds emitted so far.
    pub fn rounds_emitted(&self) -> usize {
        self.round
    }
}

impl DataSource for DriftSource {
    fn task(&self) -> &SynthTask {
        &self.task
    }

    fn next_round(&mut self, v: usize) -> Vec<Sample> {
        // lerp of two non-negative mixes with positive mass keeps positive
        // mass for every t in [0, 1], so the categorical is always valid
        let t = self.progress(self.round);
        for (w, (&a, &b)) in self.weights.iter_mut().zip(self.start.iter().zip(&self.end)) {
            *w = a + (b - a) * t;
        }
        self.round += 1;
        (0..v)
            .map(|_| {
                let y = self.rng.categorical(&self.weights) as u32;
                let id = self.next_id;
                self.next_id += 1;
                self.task.draw_class(id, y, &mut self.rng)
            })
            .collect()
    }

    fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        self.task.test_set(n, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NoiseKind;
    use crate::data::synth::TaskSpec;

    fn task() -> SynthTask {
        SynthTask::new(TaskSpec::Har, 3, 0.2, 0.1)
    }

    #[test]
    fn stream_source_is_a_data_source() {
        let mut boxed: Box<dyn DataSource> =
            Box::new(StreamSource::new(task(), 5, NoiseKind::None));
        let round = boxed.next_round(20);
        assert_eq!(round.len(), 20);
        assert_eq!(boxed.task().num_classes(), 6);
        // trait test_set matches the task's directly
        let a = boxed.test_set(10, 5);
        let b = task().test_set(10, 5);
        assert_eq!(a.len(), b.len());
        assert_eq!(*a[3].x, *b[3].x);
    }

    #[test]
    fn replay_cycles_deterministically() {
        let mut stream = StreamSource::new(task(), 7, NoiseKind::None);
        let mut replay = ReplaySource::capture(&mut stream, 5).unwrap();
        assert_eq!(replay.pool_len(), 5);
        let r1 = replay.next_round(7); // wraps: ids 0..5 then 0,1
        assert_eq!(r1.len(), 7);
        assert_eq!(r1[0].id, r1[5].id);
        assert_eq!(r1[1].id, r1[6].id);
        // the cursor persists across rounds
        let r2 = replay.next_round(3); // continues at pool index 2
        assert_eq!(r2[0].id, r1[2].id);
    }

    #[test]
    fn replay_rejects_empty_pool() {
        assert!(ReplaySource::new(task(), Vec::new()).is_err());
    }

    #[test]
    fn capture_rejects_zero_n_with_a_typed_error() {
        // regression: n == 0 used to reach ReplaySource::new and fail
        // there with a misleading "non-empty pool" config error
        let mut stream = StreamSource::new(task(), 7, NoiseKind::None);
        match ReplaySource::capture(&mut stream, 0) {
            Err(crate::Error::Data(msg)) => assert!(msg.contains("n == 0"), "{msg}"),
            other => panic!("expected Error::Data, got {other:?}"),
        }
        // the failed capture consumed nothing from the source
        assert_eq!(stream.next_round(1)[0].id, 0);
    }

    #[test]
    fn capture_advances_the_source_by_exactly_n() {
        // the documented cursor contract: capture consumes one
        // next_round(n), so the source's stream resumes at sample n
        let mut captured = StreamSource::new(task(), 7, NoiseKind::None);
        let mut reference = StreamSource::new(task(), 7, NoiseKind::None);
        let replay = ReplaySource::capture(&mut captured, 13).unwrap();
        assert_eq!(replay.pool_len(), 13);
        let _ = reference.next_round(13);
        let (a, b) = (captured.next_round(9), reference.next_round(9));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.label, y.label);
            assert_eq!(*x.x, *y.x);
        }
    }

    #[test]
    fn class_subset_only_emits_its_classes() {
        let mut src = ClassSubsetSource::new(task(), vec![1, 4], 42).unwrap();
        for s in src.next_round(200) {
            assert!(s.label == 1 || s.label == 4, "label {}", s.label);
        }
    }

    #[test]
    fn class_subset_deterministic_under_seed() {
        let mut a = ClassSubsetSource::new(task(), vec![0, 2, 3], 9).unwrap();
        let mut b = ClassSubsetSource::new(task(), vec![0, 2, 3], 9).unwrap();
        let (ra, rb) = (a.next_round(30), b.next_round(30));
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.label, y.label);
            assert_eq!(*x.x, *y.x);
        }
    }

    #[test]
    fn class_subset_validates_classes() {
        assert!(ClassSubsetSource::new(task(), vec![], 1).is_err());
        assert!(ClassSubsetSource::new(task(), vec![6], 1).is_err());
        assert!(ClassSubsetSource::new(task(), vec![5], 1).is_ok());
    }

    #[test]
    fn drift_moves_from_start_mix_to_end_mix() {
        // degenerate mixes make the drift fully observable: round 0 is
        // pure class 0, rounds >= drift_rounds are pure class 5
        let mut start = vec![0.0; 6];
        start[0] = 1.0;
        let mut end = vec![0.0; 6];
        end[5] = 1.0;
        let mut src = DriftSource::new(task(), start, end, 4, 11).unwrap();
        assert_eq!(src.progress(0), 0.0);
        assert_eq!(src.progress(4), 1.0);
        assert_eq!(src.progress(40), 1.0);
        let first = src.next_round(50);
        assert!(first.iter().all(|s| s.label == 0), "round 0 must be pure start");
        let mut mid_seen_both = (false, false);
        for _ in 1..4 {
            for s in src.next_round(50) {
                match s.label {
                    0 => mid_seen_both.0 = true,
                    5 => mid_seen_both.1 = true,
                    other => panic!("mid-drift label {other} outside mix support"),
                }
            }
        }
        assert!(mid_seen_both.0 && mid_seen_both.1, "mid-drift must blend both mixes");
        assert_eq!(src.rounds_emitted(), 4);
        let last = src.next_round(50);
        assert!(last.iter().all(|s| s.label == 5), "post-drift must be pure end");
    }

    #[test]
    fn drift_deterministic_under_seed() {
        let mk = || {
            DriftSource::new(task(), vec![1.0; 6], vec![3.0, 1.0, 1.0, 1.0, 1.0, 0.2], 10, 7)
                .unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..12 {
            let (ra, rb) = (a.next_round(20), b.next_round(20));
            for (x, y) in ra.iter().zip(&rb) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.label, y.label);
                assert_eq!(*x.x, *y.x);
            }
        }
    }

    /// `fast_forward(r, v)` must land every source on exactly the state
    /// that r draw-and-discarded rounds produce — the property checkpoint
    /// resume relies on.
    #[test]
    fn fast_forward_matches_drawn_rounds_for_every_source() {
        let sources: Vec<fn() -> Box<dyn DataSource>> = vec![
            || Box::new(StreamSource::new(task(), 5, NoiseKind::Label { frac: 0.3 })),
            || {
                let mut stream = StreamSource::new(task(), 7, NoiseKind::None);
                Box::new(ReplaySource::capture(&mut stream, 13).unwrap())
            },
            || Box::new(ClassSubsetSource::new(task(), vec![0, 2, 5], 9).unwrap()),
            || {
                let mut end = vec![0.25; 6];
                end[1] = 4.0;
                Box::new(DriftSource::new(task(), vec![1.0; 6], end, 5, 3).unwrap())
            },
            // a RetainedSource that was never offered candidates is a pure
            // pass-through (empty store -> no blend-RNG draws), so the
            // inner-cursor-only fast_forward is exact here; the retaining
            // case needs restore_retention and is pinned in retained.rs
            || {
                let inner = Box::new(StreamSource::new(task(), 5, NoiseKind::None));
                Box::new(
                    crate::data::RetainedSource::new(
                        inner,
                        1 << 20,
                        crate::retention::RetentionKind::Score,
                        0.5,
                        7,
                    )
                    .unwrap(),
                )
            },
        ];
        for (i, mk) in sources.iter().enumerate() {
            let mut drawn = mk();
            let mut skipped = mk();
            for _ in 0..4 {
                let _ = drawn.next_round(20);
            }
            skipped.fast_forward(4, 20);
            for r in 0..3 {
                let (a, b) = (drawn.next_round(20), skipped.next_round(20));
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.id, y.id, "source {i} round {r}");
                    assert_eq!(x.label, y.label, "source {i} round {r}");
                    assert_eq!(*x.x, *y.x, "source {i} round {r}");
                }
            }
        }
    }

    #[test]
    fn drift_validates_mixes() {
        let t = task(); // 6 classes
        assert!(DriftSource::new(t.clone(), vec![1.0; 5], vec![1.0; 6], 4, 1).is_err());
        assert!(DriftSource::new(t.clone(), vec![1.0; 6], vec![1.0; 7], 4, 1).is_err());
        let neg = vec![-1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        assert!(DriftSource::new(t.clone(), neg, vec![1.0; 6], 4, 1).is_err());
        assert!(DriftSource::new(t.clone(), vec![0.0; 6], vec![1.0; 6], 4, 1).is_err());
        assert!(DriftSource::new(t.clone(), vec![1.0; 6], vec![1.0; 6], 0, 1).is_err());
        assert!(DriftSource::new(t, vec![1.0; 6], vec![1.0; 6], 4, 1).is_ok());
    }
}
