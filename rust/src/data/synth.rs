//! Synthetic task generators — the stand-ins for the paper's datasets
//! (CIFAR-10, Google Speech Commands, HARBOX), per DESIGN.md
//! §Substitutions.
//!
//! Each class is a Gaussian mixture over a small number of intra-class
//! *modes* in input space. The knobs below expose exactly the structure
//! Titan's selection mechanics react to:
//!
//! - `modes_per_class` — intra-class diversity. More modes → larger
//!   per-class *gradient variance* → C-IS allocates this class more slots
//!   (Eq. 2). Classes get different mode counts so importance differs.
//! - `class_skew` — class imbalance of the stream (|S_y| in Eq. 2).
//! - `quality_noise` — per-sample heterogeneous quality (sensor noise),
//!   i.e. a random per-sample noise level, giving a heavy tail of
//!   low-quality samples.
//! - `input_dim` / spatial layout — matched to each model variant.
//!
//! Everything is deterministic under the task seed; the held-out test set
//! is drawn from the *clean* distribution (noise only affects the stream).

use crate::data::sample::Sample;
use crate::util::rng::Xoshiro256;

/// Which paper task a generator emulates (fixes dims/classes to match the
/// model variants' artifact contracts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskSpec {
    /// Image classification: 3x32x32 inputs, 10 classes (CIFAR-10 shape).
    ImageCls,
    /// Audio recognition: 1x40x40 log-mel-like inputs, 20 classes.
    AudioCls,
    /// Human activity recognition: 900-dim IMU windows, 6 classes.
    Har,
}

impl TaskSpec {
    pub fn input_dim(&self) -> usize {
        match self {
            TaskSpec::ImageCls => 3 * 32 * 32,
            TaskSpec::AudioCls => 40 * 40,
            TaskSpec::Har => 900,
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            TaskSpec::ImageCls => 10,
            TaskSpec::AudioCls => 20,
            TaskSpec::Har => 6,
        }
    }

    /// The task a model variant trains on (matches the artifact dims).
    pub fn for_model(model: &str) -> TaskSpec {
        match model {
            "mlp" => TaskSpec::Har,
            "resnet_ar" => TaskSpec::AudioCls,
            _ => TaskSpec::ImageCls,
        }
    }
}

/// One intra-class mode: a center direction + spread.
#[derive(Clone, Debug)]
struct Mode {
    center: Vec<f32>,
    spread: f32,
}

/// Seeded synthetic task: a Gaussian mixture per class.
#[derive(Clone, Debug)]
pub struct SynthTask {
    pub spec: TaskSpec,
    /// modes[class] -> intra-class modes.
    modes: Vec<Vec<Mode>>,
    /// Unnormalized class frequencies for the stream.
    class_weights: Vec<f64>,
    /// Std of the per-sample quality-noise level distribution.
    quality_noise: f32,
    /// Fraction of samples drawn from a *neighboring class's* mode while
    /// keeping their own label. High-dimensional Gaussians are otherwise
    /// trivially separable; this injects irreducible (Bayes) error so test
    /// accuracy plateaus CIFAR-10-like (~75-85%) and per-sample importance
    /// is genuinely heterogeneous (confusable samples = large gradients).
    confusion: f32,
}

impl SynthTask {
    /// Build the default task for a model variant. Class y gets
    /// `1 + (y mod 3)` modes so classes differ in gradient diversity, and a
    /// mild Zipf-ish skew so |S_y| differs — both inputs to Eq. 2.
    pub fn for_model(model: &str, seed: u64) -> SynthTask {
        Self::new(TaskSpec::for_model(model), seed, 0.35, 0.25)
    }

    /// `class_skew` in [0,1]: 0 = uniform classes, 1 = strong imbalance.
    /// `quality_noise`: std of per-sample noise levels (0 = homogeneous).
    pub fn new(spec: TaskSpec, seed: u64, class_skew: f64, quality_noise: f32) -> SynthTask {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5EED_7A5C);
        let c = spec.num_classes();
        let mut modes = Vec::with_capacity(c);
        for y in 0..c {
            // strong intra-class-diversity contrast across classes: this is
            // the structure C-IS's inter-class allocation exploits (Eq. 2)
            let n_modes = 1 + (y % 4);
            let mut class_modes = Vec::with_capacity(n_modes);
            for mode_i in 0..n_modes {
                let center = Self::mode_center(spec, y, mode_i, &mut rng);
                let spread = 1.2 + 0.8 * rng.next_f32();
                class_modes.push(Mode { center, spread });
            }
            modes.push(class_modes);
        }
        let class_weights: Vec<f64> = (0..c)
            .map(|y| 1.0 / (1.0 + class_skew * y as f64))
            .collect();
        SynthTask {
            spec,
            modes,
            class_weights,
            quality_noise,
            // modest Bayes error: enough to keep test accuracy off the
            // ceiling, small enough that high-gradient samples remain
            // predominantly hard-but-learnable (the clean-data regime the
            // paper evaluates in; cf. Fig. 11 for the noisy regime)
            confusion: 0.06,
        }
    }

    /// Override the class-overlap rate (0 = fully separable task).
    pub fn with_confusion(mut self, confusion: f32) -> Self {
        self.confusion = confusion;
        self
    }

    /// Per-spec mode center. HAR uses a flat-index frequency signature
    /// (MLP-friendly); the image/audio tasks use *spatial* 2-D gratings
    /// per channel — structure a convolution + GAP trunk can detect,
    /// which a flat-index pattern is not (it aliases across rows).
    fn mode_center(spec: TaskSpec, y: usize, mode_i: usize, rng: &mut Xoshiro256) -> Vec<f32> {
        let d = spec.input_dim();
        match spec {
            TaskSpec::Har => (0..d)
                .map(|j| {
                    let base = rng.normal_f32(0.0, 1.0);
                    let sig = ((j as f32 * (y as f32 + 1.0) * 0.013).sin()) * 0.45;
                    base + sig
                })
                .collect(),
            TaskSpec::ImageCls | TaskSpec::AudioCls => {
                let (ch, hh, ww) = match spec {
                    TaskSpec::ImageCls => (3usize, 32usize, 32usize),
                    _ => (1, 40, 40),
                };
                // class-specific orientation/frequency; modes shift phase
                // and tilt so intra-class diversity is genuinely spatial
                let theta = y as f32 * 0.61 + mode_i as f32 * 0.37;
                let freq = 1.5 + (y % 3) as f32 + mode_i as f32 * 0.5;
                let phase = rng.next_f32() * std::f32::consts::TAU;
                let (fx, fy) = (theta.cos() * freq, theta.sin() * freq);
                let amp = 1.5f32;
                let mut center = Vec::with_capacity(d);
                for c in 0..ch {
                    let ch_gain = 1.0 + 0.3 * c as f32; // mild channel signature
                    // per-class channel DC bias: global-average-pool trunks
                    // (mobilenet/squeeze/resnet) are phase-blind, so the
                    // class signal must also live in channel statistics
                    let dc = 0.9 * ((y as f32 * 1.3 + c as f32 * 2.1 + mode_i as f32 * 0.5).sin());
                    for h in 0..hh {
                        // class-dependent row-energy envelope: for the
                        // 1-channel audio task this is the mel-band energy
                        // profile of the "command", and it is what makes 20
                        // classes separable through a GAP head
                        let env = 1.0
                            + 0.8
                                * ((h as f32 / hh as f32) * std::f32::consts::TAU
                                    * (1.0 + (y % 5) as f32)
                                    + y as f32 * 0.7)
                                    .sin();
                        for w in 0..ww {
                            let arg = std::f32::consts::TAU
                                * (fx * h as f32 / hh as f32 + fy * w as f32 / ww as f32)
                                + phase;
                            let noise = rng.normal_f32(0.0, 0.3);
                            center.push(amp * ch_gain * env * arg.sin() + dc + noise);
                        }
                    }
                }
                center
            }
        }
    }

    pub fn num_classes(&self) -> usize {
        self.spec.num_classes()
    }

    pub fn input_dim(&self) -> usize {
        self.spec.input_dim()
    }

    pub fn class_weights(&self) -> &[f64] {
        &self.class_weights
    }

    /// Draw one clean sample (label + input) using `rng`.
    pub fn draw(&self, id: u64, rng: &mut Xoshiro256) -> Sample {
        let y = rng.categorical(&self.class_weights) as u32;
        self.draw_class(id, y, rng)
    }

    /// Draw a sample of a specific class (used by the FL non-IID partition).
    pub fn draw_class(&self, id: u64, y: u32, rng: &mut Xoshiro256) -> Sample {
        // confusable draw: sample from a neighboring class's mode but keep
        // this label — the irreducible-error mass
        let src_class = if rng.next_f32() < self.confusion {
            let c = self.num_classes() as u32;
            (y + 1 + rng.next_below(c as u64 - 1) as u32) % c
        } else {
            y
        };
        let class_modes = &self.modes[src_class as usize];
        let m = &class_modes[rng.index(class_modes.len())];
        // heterogeneous per-sample quality: noise level itself is random
        let extra = (rng.normal_f32(0.0, self.quality_noise)).abs();
        let sigma = m.spread + extra;
        let x: Vec<f32> = m
            .center
            .iter()
            .map(|&c| c + rng.normal_f32(0.0, sigma))
            .collect();
        Sample::new(id, y, x)
    }

    /// Deterministic held-out test set, balanced across classes, drawn from
    /// the clean distribution. Its RNG stream is independent of the
    /// training stream.
    pub fn test_set(&self, n: usize, seed: u64) -> Vec<Sample> {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x7E57_5E7);
        let c = self.num_classes() as u64;
        (0..n)
            .map(|i| self.draw_class(u64::MAX - i as u64, (i as u64 % c) as u32, &mut rng))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_specs() {
        for (spec, d, c) in [
            (TaskSpec::ImageCls, 3072, 10),
            (TaskSpec::AudioCls, 1600, 20),
            (TaskSpec::Har, 900, 6),
        ] {
            assert_eq!(spec.input_dim(), d);
            assert_eq!(spec.num_classes(), c);
        }
    }

    #[test]
    fn model_task_mapping() {
        assert_eq!(TaskSpec::for_model("mlp"), TaskSpec::Har);
        assert_eq!(TaskSpec::for_model("resnet_ar"), TaskSpec::AudioCls);
        assert_eq!(TaskSpec::for_model("tinyalex"), TaskSpec::ImageCls);
        assert_eq!(TaskSpec::for_model("squeeze"), TaskSpec::ImageCls);
    }

    #[test]
    fn deterministic_under_seed() {
        let t1 = SynthTask::for_model("mlp", 5);
        let t2 = SynthTask::for_model("mlp", 5);
        let mut r1 = Xoshiro256::seed_from_u64(9);
        let mut r2 = Xoshiro256::seed_from_u64(9);
        let a = t1.draw(0, &mut r1);
        let b = t2.draw(0, &mut r2);
        assert_eq!(a.label, b.label);
        assert_eq!(*a.x, *b.x);
    }

    #[test]
    fn samples_have_right_shape_and_finite() {
        let t = SynthTask::for_model("tinyalex", 1);
        let mut rng = Xoshiro256::seed_from_u64(2);
        for i in 0..50 {
            let s = t.draw(i, &mut rng);
            assert_eq!(s.dim(), 3072);
            assert!((s.label as usize) < 10);
            assert!(s.x.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn class_skew_shows_in_draws() {
        let t = SynthTask::new(TaskSpec::Har, 3, 0.8, 0.0);
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut counts = vec![0usize; 6];
        for i in 0..6000 {
            counts[t.draw(i, &mut rng).label as usize] += 1;
        }
        assert!(
            counts[0] > counts[5] + 200,
            "skew should make class 0 much more frequent: {counts:?}"
        );
    }

    #[test]
    fn classes_are_separated_in_input_space() {
        // same-class samples (same mode seedline) must be closer on average
        // than cross-class ones — otherwise no model could learn the task.
        let t = SynthTask::new(TaskSpec::Har, 7, 0.0, 0.0);
        let mut rng = Xoshiro256::seed_from_u64(8);
        let a: Vec<Sample> = (0..40).map(|i| t.draw_class(i, 0, &mut rng)).collect();
        let b: Vec<Sample> = (0..40).map(|i| t.draw_class(i, 3, &mut rng)).collect();
        let centroid = |ss: &[Sample]| -> Vec<f32> {
            let d = ss[0].dim();
            let mut m = vec![0.0f32; d];
            for s in ss {
                for (mm, &v) in m.iter_mut().zip(s.x.iter()) {
                    *mm += v / ss.len() as f32;
                }
            }
            m
        };
        let ca = centroid(&a);
        let cb = centroid(&b);
        let sep = crate::util::stats::dist2(&ca, &cb);
        assert!(sep > 10.0, "class centroids too close: {sep}");
    }

    #[test]
    fn test_set_balanced_and_deterministic() {
        let t = SynthTask::for_model("mlp", 11);
        let ts1 = t.test_set(60, 1);
        let ts2 = t.test_set(60, 1);
        assert_eq!(ts1.len(), 60);
        for (a, b) in ts1.iter().zip(&ts2) {
            assert_eq!(a.label, b.label);
            assert_eq!(*a.x, *b.x);
        }
        let mut counts = vec![0usize; 6];
        for s in &ts1 {
            counts[s.label as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }
}
