//! Streaming data source: velocity-controlled sample arrival with noise
//! injection (paper Fig. 11) and arrival bookkeeping.
//!
//! The paper's setting: data arrives continuously; `v` samples arrive per
//! training round (default 100) and only a small candidate buffer may be
//! kept. `StreamSource` is the single producer; the coordinator pulls one
//! round's chunk at a time (pull keeps the pipeline deterministic — the
//! device simulator accounts for the arrival timing instead).

use crate::config::NoiseKind;
use crate::data::sample::Sample;
use crate::data::synth::SynthTask;
use crate::util::rng::Xoshiro256;

/// Arrival statistics, used by metrics and the noise experiments.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub emitted: u64,
    pub feature_noisy: u64,
    pub label_noisy: u64,
}

/// Seeded streaming source over a synthetic task.
pub struct StreamSource {
    task: SynthTask,
    rng: Xoshiro256,
    noise: NoiseKind,
    next_id: u64,
    stats: StreamStats,
}

impl StreamSource {
    pub fn new(task: SynthTask, seed: u64, noise: NoiseKind) -> Self {
        Self {
            task,
            rng: Xoshiro256::seed_from_u64(seed ^ 0x57AE_AA11),
            noise,
            next_id: 0,
            stats: StreamStats::default(),
        }
    }

    pub fn task(&self) -> &SynthTask {
        &self.task
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    /// Pull the next streaming sample (with noise applied per config).
    pub fn next_sample(&mut self) -> Sample {
        let id = self.next_id;
        self.next_id += 1;
        let mut s = self.task.draw(id, &mut self.rng);
        match self.noise {
            NoiseKind::None => {}
            NoiseKind::Feature { frac, sigma } => {
                if self.rng.next_f32() < frac {
                    let noisy: Vec<f32> = s
                        .x
                        .iter()
                        .map(|&v| v + self.rng.normal_f32(0.0, sigma))
                        .collect();
                    s.x = std::sync::Arc::new(noisy);
                    self.stats.feature_noisy += 1;
                }
            }
            NoiseKind::Label { frac } => {
                if self.rng.next_f32() < frac {
                    let c = self.task.num_classes() as u32;
                    // uniform over *other* labels so frac is the true error rate
                    let mut y = self.rng.next_below(c as u64 - 1) as u32;
                    if y >= s.label {
                        y += 1;
                    }
                    s.label = y;
                    self.stats.label_noisy += 1;
                }
            }
        }
        self.stats.emitted += 1;
        s
    }

    /// Pull one round's worth of arrivals (`v` samples).
    pub fn next_round(&mut self, v: usize) -> Vec<Sample> {
        (0..v).map(|_| self.next_sample()).collect()
    }

    /// Advance past `rounds` rounds of `v` arrivals without materializing
    /// the round vectors (checkpoint resume fast-forward). Draws every
    /// sample — RNG consumption, id counters and noise stats advance
    /// exactly as if the rounds had been pulled and used.
    pub fn skip_rounds(&mut self, rounds: usize, v: usize) {
        for _ in 0..rounds * v {
            // detlint: allow(R002) draw-and-discard IS the fast-forward: only the RNG advance matters
            let _ = self.next_sample();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::TaskSpec;

    fn task() -> SynthTask {
        SynthTask::new(TaskSpec::Har, 3, 0.2, 0.1)
    }

    #[test]
    fn deterministic_stream() {
        let mut s1 = StreamSource::new(task(), 5, NoiseKind::None);
        let mut s2 = StreamSource::new(task(), 5, NoiseKind::None);
        for _ in 0..20 {
            let a = s1.next_sample();
            let b = s2.next_sample();
            assert_eq!(a.id, b.id);
            assert_eq!(a.label, b.label);
            assert_eq!(*a.x, *b.x);
        }
    }

    #[test]
    fn ids_are_monotone_unique() {
        let mut s = StreamSource::new(task(), 1, NoiseKind::None);
        let round = s.next_round(50);
        let ids: Vec<u64> = round.iter().map(|x| x.id).collect();
        for (i, w) in ids.windows(2).enumerate() {
            assert!(w[1] > w[0], "at {i}: {w:?}");
        }
    }

    #[test]
    fn label_noise_rate_and_flag() {
        let mut s = StreamSource::new(task(), 7, NoiseKind::Label { frac: 0.4 });
        let n = 5000;
        let mut noisy = 0;
        for _ in 0..n {
            let smp = s.next_sample();
            if smp.label_is_noisy() {
                noisy += 1;
                assert_ne!(smp.label, smp.clean_label);
            }
        }
        let rate = noisy as f64 / n as f64;
        assert!((rate - 0.4).abs() < 0.03, "rate {rate}");
        assert_eq!(s.stats().label_noisy, noisy as u64);
    }

    #[test]
    fn feature_noise_perturbs_inputs() {
        let mut clean = StreamSource::new(task(), 9, NoiseKind::None);
        let mut noisy = StreamSource::new(
            task(),
            9,
            NoiseKind::Feature { frac: 1.0, sigma: 2.0 },
        );
        // same underlying draw stream -> labels match, features differ
        let a = clean.next_sample();
        let b = noisy.next_sample();
        assert_eq!(a.label, b.label);
        assert!(crate::util::stats::dist2(&a.x, &b.x) > 1.0);
        assert_eq!(b.clean_label, b.label, "feature noise keeps labels");
    }

    #[test]
    fn skip_rounds_matches_drawing() {
        let mut drawn = StreamSource::new(task(), 4, NoiseKind::Feature { frac: 0.5, sigma: 1.0 });
        let mut skipped = StreamSource::new(task(), 4, NoiseKind::Feature { frac: 0.5, sigma: 1.0 });
        for _ in 0..3 {
            let _ = drawn.next_round(15);
        }
        skipped.skip_rounds(3, 15);
        assert_eq!(drawn.stats().emitted, skipped.stats().emitted);
        assert_eq!(drawn.stats().feature_noisy, skipped.stats().feature_noisy);
        let (a, b) = (drawn.next_sample(), skipped.next_sample());
        assert_eq!(a.id, b.id);
        assert_eq!(*a.x, *b.x);
    }

    #[test]
    fn round_size() {
        let mut s = StreamSource::new(task(), 2, NoiseKind::None);
        assert_eq!(s.next_round(100).len(), 100);
        assert_eq!(s.stats().emitted, 100);
    }
}
