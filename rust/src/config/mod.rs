//! Run/experiment configuration.
//!
//! A [`RunConfig`] fully determines one training run: the model variant,
//! the selection method, stream parameters, filter parameters, and the
//! training schedule. Configs are built from presets (`presets.rs`),
//! overridden from CLI args, and can be (de)serialized as JSON so every
//! experiment records the exact configuration next to its results.

pub mod presets;

use crate::retention::RetentionKind;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::{Error, Result};

/// Which data-selection method drives the training batch choice.
/// These are the Table-1 columns of the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Random selection (the paper's normalization baseline).
    Rs,
    /// Importance sampling: P(x) ∝ ‖∇l‖ over everything, allocation by
    /// mean gradient norm (Katharopoulos & Fleuret '18).
    Is,
    /// Heuristic: lowest per-sample loss first (Shah et al.).
    Ll,
    /// Heuristic: highest per-sample loss first (selection-via-proxy).
    Hl,
    /// Heuristic: highest output entropy (active-learning style "CE").
    Ce,
    /// Heuristic: representativeness + diversity (online coreset, OCS).
    Ocs,
    /// Coreset by raw-input distance, greedy (Camel, SIGMOD'22).
    Camel,
    /// Titan's classified importance sampling (fine stage only).
    Cis,
    /// Full Titan: coarse filter + C-IS + pipeline.
    Titan,
}

impl Method {
    pub const ALL: [Method; 9] = [
        Method::Rs,
        Method::Is,
        Method::Ll,
        Method::Hl,
        Method::Ce,
        Method::Ocs,
        Method::Camel,
        Method::Cis,
        Method::Titan,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rs => "rs",
            Method::Is => "is",
            Method::Ll => "ll",
            Method::Hl => "hl",
            Method::Ce => "ce",
            Method::Ocs => "ocs",
            Method::Camel => "camel",
            Method::Cis => "cis",
            Method::Titan => "titan",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.name() == s)
            .ok_or_else(|| Error::Config(format!("unknown method {s:?}")))
    }

    /// Does this method need per-sample gradient information (the
    /// importance artifact) on its selection path?
    pub fn needs_importance(&self) -> bool {
        matches!(self, Method::Is | Method::Cis | Method::Titan)
    }

    /// Does this method need a forward pass (loss/entropy) per candidate?
    pub fn needs_forward(&self) -> bool {
        matches!(self, Method::Ll | Method::Hl | Method::Ce)
    }
}

/// Stream noise settings (paper Fig. 11).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoiseKind {
    None,
    /// Gaussian noise added to the input features of a fraction of samples.
    Feature { frac: f32, sigma: f32 },
    /// Labels of a fraction of samples replaced uniformly at random.
    Label { frac: f32 },
}

/// One run, fully specified.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Model variant (artifact directory name), e.g. "mlp".
    pub model: String,
    /// Selection method.
    pub method: Method,
    /// RNG seed for everything stochastic in the run.
    pub seed: u64,
    /// Number of training rounds.
    pub rounds: usize,
    /// Streaming samples arriving per round (paper: v = 100).
    pub stream_per_round: usize,
    /// Training batch size |B| (paper: 10). Must match the artifact's
    /// train_batch (checked at load).
    pub batch_size: usize,
    /// Candidate buffer budget for the coarse filter (paper: 30).
    pub candidate_size: usize,
    /// Number of model blocks used for filter features (paper Fig. 8; 1).
    pub filter_blocks: usize,
    /// Rep weight λ in the filter score (see DESIGN.md §Discrepancies).
    pub filter_lambda: f32,
    /// Initial learning rate (paper: 0.1 light models, 0.005 large).
    pub lr: f32,
    /// LR decay factor applied every `lr_decay_every` rounds (paper: 0.95/100).
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    /// Evaluate on the held-out set every this many rounds (0 = never).
    pub eval_every: usize,
    /// Test-set size (generated synthetically alongside the stream).
    pub test_size: usize,
    /// Stream noise (Fig. 11).
    pub noise: NoiseKind,
    /// Run the pipelined coordinator (one-round-delay co-execution) instead
    /// of the sequential one.
    pub pipeline: bool,
    /// Worker threads for the selection-side Gram triangle sweep
    /// (`--select-threads`; default 1 = no spawned threads). Purely a
    /// wall-clock lever: the sweep's block partition depends only on the
    /// candidate count, so results are bit-identical for every value —
    /// which is why this field is deliberately **excluded** from the
    /// serialized config and the resume fingerprint (a snapshot taken at
    /// one thread count resumes safely at another).
    pub select_threads: usize,
    /// Storage budget (bytes) for the retention stage's persistent sample
    /// store (`--store-bytes`; 0 = no retention plane at all — the run is
    /// byte-identical to a pre-retention build).
    pub store_bytes: usize,
    /// Eviction policy for the retention store (`--retention`). Ignored
    /// when `store_bytes` is 0.
    pub retention: RetentionKind,
    /// Fraction of each round's arrivals replayed from the retention
    /// store (`--replay-mix`, in [0, 1]). Ignored when `store_bytes` is 0.
    pub replay_mix: f64,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "mlp".into(),
            method: Method::Titan,
            seed: 17,
            rounds: 300,
            stream_per_round: 100,
            batch_size: 10,
            candidate_size: 30,
            filter_blocks: 1,
            // Rep-dominant: pure diversity (λ→0) buffers outliers, the
            // paper's literal λ=0.5 cancels (DESIGN.md §Discrepancies);
            // 0.7 keeps the candidate pool representative with a diversity
            // tail, which is what makes the C-IS stage effective.
            filter_lambda: 0.7,
            lr: 0.1,
            lr_decay: 0.95,
            lr_decay_every: 100,
            eval_every: 20,
            test_size: 1000,
            noise: NoiseKind::None,
            pipeline: true,
            select_threads: 1,
            store_bytes: 0,
            retention: RetentionKind::Score,
            replay_mix: 0.5,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl RunConfig {
    /// Apply CLI overrides (only the options present are touched).
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(m) = args.get("method") {
            self.method = Method::parse(m)?;
        }
        self.seed = args.get_u64("seed", self.seed)?;
        self.rounds = args.get_usize("rounds", self.rounds)?;
        self.stream_per_round = args.get_usize("stream", self.stream_per_round)?;
        self.batch_size = args.get_usize("batch", self.batch_size)?;
        self.candidate_size = args.get_usize("candidates", self.candidate_size)?;
        self.filter_blocks = args.get_usize("filter-blocks", self.filter_blocks)?;
        self.filter_lambda = args.get_f32("filter-lambda", self.filter_lambda)?;
        self.lr = args.get_f32("lr", self.lr)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        self.test_size = args.get_usize("test-size", self.test_size)?;
        self.select_threads = args.get_usize("select-threads", self.select_threads)?;
        self.store_bytes = args.get_usize("store-bytes", self.store_bytes)?;
        if let Some(p) = args.get("retention") {
            self.retention = RetentionKind::parse(p)?;
        }
        self.replay_mix = args.get_f64("replay-mix", self.replay_mix)?;
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = d.to_string();
        }
        if args.has_flag("sequential") {
            self.pipeline = false;
        }
        if let Some(n) = args.get("feature-noise") {
            let frac: f32 = n
                .parse()
                .map_err(|e| Error::Config(format!("--feature-noise={n}: {e}")))?;
            self.noise = NoiseKind::Feature { frac, sigma: 1.0 };
        }
        if let Some(n) = args.get("label-noise") {
            let frac: f32 = n
                .parse()
                .map_err(|e| Error::Config(format!("--label-noise={n}: {e}")))?;
            self.noise = NoiseKind::Label { frac };
        }
        Ok(self)
    }

    /// Serialize for the run record next to results.
    pub fn to_json(&self) -> Json {
        let noise = match self.noise {
            NoiseKind::None => Json::Str("none".into()),
            NoiseKind::Feature { frac, sigma } => Json::obj(vec![
                ("kind", Json::Str("feature".into())),
                ("frac", Json::Num(frac as f64)),
                ("sigma", Json::Num(sigma as f64)),
            ]),
            NoiseKind::Label { frac } => Json::obj(vec![
                ("kind", Json::Str("label".into())),
                ("frac", Json::Num(frac as f64)),
            ]),
        };
        let mut fields = vec![
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.method.name().into())),
            ("seed", Json::Num(self.seed as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("stream_per_round", Json::Num(self.stream_per_round as f64)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("candidate_size", Json::Num(self.candidate_size as f64)),
            ("filter_blocks", Json::Num(self.filter_blocks as f64)),
            ("filter_lambda", Json::Num(self.filter_lambda as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("lr_decay", Json::Num(self.lr_decay as f64)),
            ("lr_decay_every", Json::Num(self.lr_decay_every as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("test_size", Json::Num(self.test_size as f64)),
            ("noise", noise),
            ("pipeline", Json::Bool(self.pipeline)),
            ("artifacts_dir", Json::Str(self.artifacts_dir.clone())),
        ];
        // emitted only when the retention plane is on: a zero-budget
        // config's serialization (and so its fingerprint and RunRecord)
        // stays byte-identical to pre-retention builds
        if self.store_bytes > 0 {
            fields.push((
                "retention",
                Json::obj(vec![
                    ("store_bytes", Json::Num(self.store_bytes as f64)),
                    ("policy", Json::Str(self.retention.name().into())),
                    ("replay_mix", Json::Num(self.replay_mix)),
                ]),
            ));
        }
        Json::obj(fields)
    }

    /// Rebuild a config from its [`RunConfig::to_json`] serialization —
    /// the checkpoint-resume path (`titan run --resume` reconstructs the
    /// run's exact config from the snapshot instead of trusting re-typed
    /// flags). Every field is required; unknown noise kinds error.
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let noise = match j.get("noise")? {
            Json::Str(s) if s == "none" => NoiseKind::None,
            obj @ Json::Obj(_) => match obj.get("kind")?.as_str()? {
                "feature" => NoiseKind::Feature {
                    frac: obj.get("frac")?.as_f64()? as f32,
                    sigma: obj.get("sigma")?.as_f64()? as f32,
                },
                "label" => NoiseKind::Label { frac: obj.get("frac")?.as_f64()? as f32 },
                other => {
                    return Err(Error::Config(format!("unknown noise kind {other:?}")));
                }
            },
            other => {
                return Err(Error::Config(format!("bad noise field {other:?}")));
            }
        };
        // absent = the retention plane was off (to_json omits the object
        // at store_bytes 0, and pre-retention configs never had it)
        let (store_bytes, retention, replay_mix) = match j.get("retention") {
            Err(_) | Ok(Json::Null) => (0, RetentionKind::Score, 0.5),
            Ok(r) => (
                r.get("store_bytes")?.as_usize()?,
                RetentionKind::parse(r.get("policy")?.as_str()?)?,
                r.get("replay_mix")?.as_f64()?,
            ),
        };
        Ok(RunConfig {
            model: j.get("model")?.as_str()?.to_string(),
            method: Method::parse(j.get("method")?.as_str()?)?,
            seed: j.get("seed")?.as_f64()? as u64,
            rounds: j.get("rounds")?.as_usize()?,
            stream_per_round: j.get("stream_per_round")?.as_usize()?,
            batch_size: j.get("batch_size")?.as_usize()?,
            candidate_size: j.get("candidate_size")?.as_usize()?,
            filter_blocks: j.get("filter_blocks")?.as_usize()?,
            filter_lambda: j.get("filter_lambda")?.as_f64()? as f32,
            lr: j.get("lr")?.as_f64()? as f32,
            lr_decay: j.get("lr_decay")?.as_f64()? as f32,
            lr_decay_every: j.get("lr_decay_every")?.as_usize()?,
            eval_every: j.get("eval_every")?.as_usize()?,
            test_size: j.get("test_size")?.as_usize()?,
            noise,
            pipeline: j.get("pipeline")?.as_bool()?,
            // perf-only knob, not part of the serialized config (see the
            // field docs) — resumed runs re-apply it from the CLI
            select_threads: 1,
            store_bytes,
            retention,
            replay_mix,
            artifacts_dir: j.get("artifacts_dir")?.as_str()?.to_string(),
        })
    }

    /// Canonical config fingerprint: the compact JSON serialization
    /// (object keys are sorted, so this is deterministic). Checkpoint
    /// resume compares fingerprints to refuse a snapshot whose run was
    /// configured differently — a silent mismatch would diverge instead
    /// of erroring.
    pub fn fingerprint(&self) -> String {
        self.to_json().to_string_compact()
    }

    /// Sanity checks that would otherwise surface as confusing failures
    /// deep in the pipeline.
    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 {
            return Err(Error::Config("batch_size must be > 0".into()));
        }
        if self.candidate_size < self.batch_size {
            return Err(Error::Config(format!(
                "candidate_size {} < batch_size {}",
                self.candidate_size, self.batch_size
            )));
        }
        if self.stream_per_round < self.candidate_size {
            return Err(Error::Config(format!(
                "stream_per_round {} < candidate_size {}",
                self.stream_per_round, self.candidate_size
            )));
        }
        if !(0.0..=1.0).contains(&self.filter_lambda) {
            return Err(Error::Config("filter_lambda must be in [0,1]".into()));
        }
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be > 0".into()));
        }
        if self.select_threads == 0 {
            return Err(Error::Config("select_threads must be > 0".into()));
        }
        if !self.replay_mix.is_finite() || !(0.0..=1.0).contains(&self.replay_mix) {
            return Err(Error::Config(format!(
                "replay_mix {} must be in [0, 1]",
                self.replay_mix
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn method_capabilities() {
        assert!(Method::Titan.needs_importance());
        assert!(Method::Is.needs_importance());
        assert!(!Method::Rs.needs_importance());
        assert!(Method::Ce.needs_forward());
        assert!(!Method::Cis.needs_forward());
    }

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = RunConfig::default();
        c.candidate_size = 5; // < batch 10
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.batch_size = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.filter_lambda = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.select_threads = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn select_threads_is_a_pure_perf_knob() {
        // CLI sets it; the fingerprint must NOT see it (a snapshot taken
        // at one thread count resumes at another)
        let args = Args::parse(["--select-threads", "4"].iter().map(|s| s.to_string())).unwrap();
        let c = RunConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.select_threads, 4);
        assert_eq!(c.fingerprint(), RunConfig::default().fingerprint());
        // and from_json falls back to the default
        assert_eq!(RunConfig::from_json(&c.to_json()).unwrap().select_threads, 1);
    }

    #[test]
    fn args_override() {
        let args = Args::parse(
            ["--model", "squeeze", "--method", "is", "--rounds", "7",
             "--label-noise", "0.4", "--sequential"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let c = RunConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.model, "squeeze");
        assert_eq!(c.method, Method::Is);
        assert_eq!(c.rounds, 7);
        assert!(!c.pipeline);
        assert!(matches!(c.noise, NoiseKind::Label { frac } if (frac - 0.4).abs() < 1e-6));
    }

    #[test]
    fn json_roundtrip_fields() {
        let j = RunConfig::default().to_json();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "mlp");
        assert_eq!(j.get("method").unwrap().as_str().unwrap(), "titan");
        assert_eq!(j.get("batch_size").unwrap().as_usize().unwrap(), 10);
    }

    #[test]
    fn from_json_roundtrips_every_field() {
        let cfg = RunConfig {
            model: "squeeze".into(),
            method: Method::Cis,
            seed: 12345,
            rounds: 77,
            noise: NoiseKind::Feature { frac: 0.25, sigma: 1.5 },
            pipeline: false,
            ..RunConfig::default()
        };
        let restored = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(restored.fingerprint(), cfg.fingerprint());
        assert_eq!(restored.model, "squeeze");
        assert_eq!(restored.method, Method::Cis);
        assert_eq!(restored.seed, 12345);
        assert!(matches!(restored.noise, NoiseKind::Feature { frac, sigma }
            if (frac - 0.25).abs() < 1e-7 && (sigma - 1.5).abs() < 1e-7));
        assert!(!restored.pipeline);

        let label = RunConfig {
            noise: NoiseKind::Label { frac: 0.4 },
            ..RunConfig::default()
        };
        let back = RunConfig::from_json(&label.to_json()).unwrap();
        assert_eq!(back.fingerprint(), label.fingerprint());

        // fingerprints distinguish differently configured runs
        assert_ne!(cfg.fingerprint(), RunConfig::default().fingerprint());
        // and a truncated object errors instead of defaulting
        assert!(RunConfig::from_json(&Json::obj(vec![("model", Json::Str("mlp".into()))]))
            .is_err());
    }

    /// Determinism pin (a) at the config layer: a zero-budget config must
    /// serialize byte-identically to a build that has never heard of
    /// retention — no "retention" key, no fingerprint change, no matter
    /// what the (ignored) policy/mix fields hold.
    #[test]
    fn zero_store_budget_keeps_the_fingerprint_unchanged() {
        let plain = RunConfig::default();
        assert_eq!(plain.store_bytes, 0);
        assert!(!plain.fingerprint().contains("retention"));
        let mut tweaked = plain.clone();
        tweaked.retention = RetentionKind::Reservoir;
        tweaked.replay_mix = 0.9;
        assert_eq!(tweaked.fingerprint(), plain.fingerprint());
        // turning the budget on changes the fingerprint (a budgeted run
        // must never resume from an unbudgeted snapshot, or vice versa)
        tweaked.store_bytes = 1 << 20;
        assert_ne!(tweaked.fingerprint(), plain.fingerprint());
        assert!(tweaked.fingerprint().contains("\"retention\""));
    }

    #[test]
    fn retention_args_and_json_roundtrip() {
        let args = Args::parse(
            ["--store-bytes", "65536", "--retention", "balanced", "--replay-mix", "0.25"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = RunConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.store_bytes, 65536);
        assert_eq!(cfg.retention, RetentionKind::Balanced);
        assert_eq!(cfg.replay_mix, 0.25);
        cfg.validate().unwrap();

        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.fingerprint(), cfg.fingerprint());
        assert_eq!(back.store_bytes, 65536);
        assert_eq!(back.retention, RetentionKind::Balanced);
        assert_eq!(back.replay_mix, 0.25);

        // bad values surface as config errors
        let bad = Args::parse(["--retention", "lru"].iter().map(|s| s.to_string())).unwrap();
        assert!(RunConfig::default().apply_args(&bad).is_err());
        let mut cfg = RunConfig::default();
        cfg.replay_mix = 1.5;
        assert!(cfg.validate().is_err());
    }
}
