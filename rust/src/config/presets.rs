//! Per-paper-experiment configuration presets.
//!
//! Each paper table/figure has a preset that `titan exp <id>` starts from;
//! `--fast` shrinks rounds/sizes for smoke runs while keeping the relative
//! structure (every experiment module applies the same shrink factor).

use super::{Method, NoiseKind, RunConfig};

/// The paper's six (task, model) rows of Table 1 mapped to our variants.
/// (variant, learning rate) — the paper used 0.1 for light models and
/// 0.005 for the ResNets; our tiny un-normalized variants need per-model
/// rates (probed on the synthetic tasks; see EXPERIMENTS.md §Deviations).
pub const TABLE1_MODELS: [(&str, f32); 6] = [
    ("tinyalex", 0.02),
    ("mobilenet", 0.02),
    ("squeeze", 0.02),
    ("resnet_ic", 0.01),
    ("resnet_ar", 0.05),
    ("mlp", 0.1),
];

/// The IC models used by Figs. 2/5/6/7/8/9.
pub const IC_MODELS: [&str; 4] = ["tinyalex", "mobilenet", "squeeze", "resnet_ic"];

/// Default per-model round budgets for full (non-fast) runs. Enough for
/// the loss curves to separate on the synthetic tasks while staying
/// CPU-feasible.
pub fn default_rounds(model: &str) -> usize {
    match model {
        "mlp" => 400,
        "tinyalex" => 250,
        "mobilenet" => 250,
        "squeeze" => 250,
        "resnet_ic" => 200,
        "resnet_ar" => 200,
        _ => 200,
    }
}

/// Default learning rate per model (paper's split: light 0.1 / large 0.005,
/// scaled for the tiny variants).
pub fn default_lr(model: &str) -> f32 {
    TABLE1_MODELS
        .iter()
        .find(|(m, _)| *m == model)
        .map(|(_, lr)| *lr)
        .unwrap_or(0.1)
}

/// Base config for a given model with paper-default stream geometry.
pub fn base(model: &str) -> RunConfig {
    RunConfig {
        model: model.to_string(),
        lr: default_lr(model),
        rounds: default_rounds(model),
        ..RunConfig::default()
    }
}

/// Config for one Table-1 cell.
pub fn table1(model: &str, method: Method) -> RunConfig {
    RunConfig {
        method,
        // non-Titan methods run un-pipelined (they are the baselines the
        // paper deploys as-is); Titan/C-IS use the pipeline.
        pipeline: matches!(method, Method::Titan),
        ..base(model)
    }
}

/// Fig. 11 noisy-stream configs.
pub fn noisy(model: &str, method: Method, label_noise: bool) -> RunConfig {
    let noise = if label_noise {
        NoiseKind::Label { frac: 0.4 }
    } else {
        NoiseKind::Feature { frac: 0.4, sigma: 1.0 }
    };
    RunConfig {
        noise,
        ..table1(model, method)
    }
}

/// Apply the `--fast` smoke shrink: fewer rounds, smaller test set.
/// Keeps stream geometry (velocity/batch/candidates) untouched so the
/// selection dynamics stay representative.
pub fn fast(mut c: RunConfig, fast: bool) -> RunConfig {
    if fast {
        c.rounds = (c.rounds / 10).max(20);
        c.test_size = 400;
        c.eval_every = (c.eval_every / 2).max(5);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for (m, _) in TABLE1_MODELS {
            base(m).validate().unwrap();
            for method in Method::ALL {
                table1(m, method).validate().unwrap();
            }
            fast(base(m), true).validate().unwrap();
        }
    }

    #[test]
    fn titan_is_pipelined_baselines_are_not() {
        assert!(table1("mlp", Method::Titan).pipeline);
        assert!(!table1("mlp", Method::Is).pipeline);
        assert!(!table1("mlp", Method::Rs).pipeline);
    }

    #[test]
    fn fast_shrinks_rounds_only() {
        let c = base("mlp");
        let f = fast(c.clone(), true);
        assert!(f.rounds < c.rounds);
        assert_eq!(f.batch_size, c.batch_size);
        assert_eq!(f.stream_per_round, c.stream_per_round);
    }

    #[test]
    fn noisy_presets() {
        let c = noisy("mobilenet", Method::Titan, true);
        assert!(matches!(c.noise, NoiseKind::Label { .. }));
        let c = noisy("mobilenet", Method::Rs, false);
        assert!(matches!(c.noise, NoiseKind::Feature { .. }));
    }
}
