//! Fig. 10 / Appendix B — federated learning: 50 devices, non-IID local
//! streams (5 classes each), 20% participation, 3 local iterations,
//! FedAvg. Compares global-model convergence under per-device selection
//! methods (RS / IS / C-IS-as-Titan's-fine-stage).

use crate::config::{presets, Method};
use crate::coordinator::session::observers::ProgressLog;
use crate::fl::{FlBuilder, FlConfig};
use crate::metrics::{render_table, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let methods = [Method::Rs, Method::Is, Method::Cis];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    let fast = args.has_flag("fast");
    for model in &models {
        let mut rs_rounds_to: Option<usize> = None;
        let mut rs_target = 0.0;
        for &method in &methods {
            let mut base = super::tune(presets::table1(model, method), args)?;
            base.pipeline = false;
            let mut cfg = FlConfig::paper_default(base);
            if fast {
                cfg.num_devices = 10;
                cfg.comm_rounds = 10;
                cfg.base.eval_every = 2;
            }
            cfg.comm_rounds = args.get_usize("comm-rounds", cfg.comm_rounds)?;
            let mut builder = FlBuilder::new(cfg).observe(ProgressLog::every(5));
            // vault-backed durability: one capsule per (model, method)
            // cell, resumable across interrupted sweeps with --resume
            if let Some(dir) = args.get("checkpoint-dir") {
                let every = args.get_usize("checkpoint-every", 5)?;
                let keep = args.get_usize("keep-checkpoints", 1)?;
                let path = std::path::Path::new(dir)
                    .join(format!("fl_{model}_{}.json", method.name()));
                builder = builder.checkpoint(path, every, keep).resume(args.has_flag("resume"));
            }
            let rec = builder.run()?;
            if let Some(r) = &rec.recovery {
                eprintln!(
                    "fig10 {model}/{}: degraded resume (generation {}, {} rounds lost)",
                    method.name(),
                    r.generation_used,
                    r.rounds_lost
                );
            }
            if method == Method::Rs {
                rs_target = rec.final_accuracy;
                rs_rounds_to = rec.rounds_to_accuracy(rs_target);
            }
            let rounds_to = rec.rounds_to_accuracy(rs_target);
            let speedup = match (rs_rounds_to, rounds_to) {
                (Some(a), Some(b)) if b > 0 => format!("{:.2}x", a as f64 / b as f64),
                _ => "-".into(),
            };
            rows.push(vec![
                model.clone(),
                method.name().to_string(),
                format!("{:.1}", rec.final_accuracy * 100.0),
                rounds_to.map(|r| r.to_string()).unwrap_or("-".into()),
                speedup,
            ]);
            let curve: Vec<Json> = rec
                .curve
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("round", Json::Num(p.round as f64)),
                        ("test_accuracy", Json::Num(p.test_accuracy)),
                    ])
                })
                .collect();
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("method", Json::Str(method.name().into())),
                ("final_accuracy", Json::Num(rec.final_accuracy)),
                (
                    "rounds_to_rs_target",
                    rounds_to.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
                ),
                ("curve", Json::Arr(curve)),
            ]));
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "method", "final_acc_%", "rounds_to_target", "speedup"],
            &rows
        )
    );
    let path = write_result("fig10", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
