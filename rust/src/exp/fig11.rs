//! Fig. 11 / Appendix B — noisy data streams: feature noise (Gaussian on
//! 40% of inputs) and label noise (40% of labels flipped). Titan should
//! stay ahead of RS/IS in both settings, and degrade more under label
//! noise than feature noise (label noise corrupts the gradient evidence).

use crate::config::{presets, Method};
use crate::metrics::{render_table, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let methods = [Method::Rs, Method::Is, Method::Camel, Method::Titan];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        for (noise_name, label_noise) in [("feature", false), ("label", true)] {
            let mut rs_time = 0.0f64;
            let mut target = 0.0f64;
            for &method in &methods {
                let cfg = super::tune(presets::noisy(model, method, label_noise), args)?;
                let record = super::run_config(&cfg)?;
                if method == Method::Rs {
                    target = record.final_accuracy * super::TARGET_FRAC;
                    rs_time = record
                        .time_to_accuracy_device(target)
                        .unwrap_or(record.total_device_ms);
                }
                let tta = record
                    .time_to_accuracy_device(target)
                    .unwrap_or(record.total_device_ms);
                rows.push(vec![
                    model.clone(),
                    noise_name.to_string(),
                    method.name().to_string(),
                    format!("{:.1}", record.final_accuracy * 100.0),
                    super::norm(tta, rs_time),
                ]);
                out.push(Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("noise", Json::Str(noise_name.into())),
                    ("method", Json::Str(method.name().into())),
                    ("final_accuracy", Json::Num(record.final_accuracy)),
                    ("norm_tta", Json::Num(tta / rs_time.max(1e-9))),
                ]));
            }
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "noise", "method", "final_acc_%", "norm_tta"],
            &rows
        )
    );
    let path = write_result("fig11", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
