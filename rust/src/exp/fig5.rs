//! Fig. 5 — the preliminary analyses backing Titan's design:
//!
//! (a) batch-gradient variance of RS / IS / C-IS across batch sizes
//!     (C-IS optimal, the IS gap widening at small batch);
//! (b) coarse-filter ablation: how much of C-IS's variance reduction
//!     survives when the filter keeps only 30% of the stream;
//! (c) importance (gradient-norm) stability across consecutive rounds
//!     (the one-round-delay justification).

use crate::config::{presets, Method};
use crate::coordinator::{build_stream, SelectorEngine, TrainerEngine};
use crate::data::Sample;
use crate::filter::CoarseFilter;
use crate::metrics::{render_table, write_result};
use crate::selection::variance::fig5_variances;
use crate::selection::cis::class_summaries;
use crate::selection::variance::{spec_cis, theorem2_variance};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

/// Draw one stream round and compute its ImportanceOut under a lightly
/// trained model (so gradients are informative, not random-init noise).
fn trained_candidates(
    model: &str,
    args: &Args,
    warmup_rounds: usize,
) -> Result<(Vec<Sample>, crate::runtime::model::ImportanceOut, usize)> {
    let mut cfg = super::tune(presets::table1(model, Method::Cis), args)?;
    cfg.pipeline = false;
    let (mut stream, _) = build_stream(&cfg);
    let mut trainer = TrainerEngine::new(&cfg)?;
    let mut sel = SelectorEngine::new(&cfg, stream.task())?;
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(cfg.seed);
    for _ in 0..warmup_rounds {
        let arrivals = stream.next_round(cfg.stream_per_round);
        let picks = rng.sample_indices(arrivals.len(), cfg.batch_size);
        let batch: Vec<Sample> = picks.iter().map(|&i| arrivals[i].clone()).collect();
        trainer.train(&batch)?;
    }
    sel.sync_params(trainer.share_params())?;
    let arrivals = stream.next_round(cfg.stream_per_round);
    let refs: Vec<&Sample> = arrivals.iter().collect();
    let imp = sel.rt.importance(&refs)?;
    let classes = sel.rt.set.meta.num_classes;
    Ok((arrivals, imp, classes))
}

/// Fig. 5(a).
pub fn run_a(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let batches = [2usize, 5, 10, 25, 50];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let (arrivals, imp, classes) = trained_candidates(model, args, 10)?;
        let labels: Vec<u32> = arrivals.iter().map(|s| s.label).collect();
        for &b in &batches {
            let (rs, is, cis) = fig5_variances(&labels, &imp, classes, b)?;
            rows.push(vec![
                model.clone(),
                format!("{b}"),
                format!("{rs:.4}"),
                format!("{is:.4}"),
                format!("{cis:.4}"),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("batch", Json::Num(b as f64)),
                ("var_rs", Json::Num(rs)),
                ("var_is", Json::Num(is)),
                ("var_cis", Json::Num(cis)),
            ]));
        }
    }
    println!(
        "{}",
        render_table(&["model", "batch", "V[RS]", "V[IS]", "V[C-IS]"], &rows)
    );
    let path = write_result("fig5a", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// Fig. 5(b): candidate filters (random / rep-only / div-only / Rep+Div)
/// feeding C-IS, vs the ideal of C-IS on the whole stream. Metric: the
/// retained fraction of the ideal variance *reduction* relative to RS.
pub fn run_b(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let keep = 30usize;
    let batch = 10usize;
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let (arrivals, imp_all, classes) = trained_candidates(model, args, 10)?;
        let labels_all: Vec<u32> = arrivals.iter().map(|s| s.label).collect();
        let (rs_all, _, cis_all) = fig5_variances(&labels_all, &imp_all, classes, batch)?;
        let ideal_reduction = (rs_all - cis_all).max(1e-12);

        // filter schemes -> candidate index subsets
        let mut schemes: Vec<(&str, Vec<usize>)> = Vec::new();
        // random keep
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(7);
        schemes.push(("random", rng.sample_indices(arrivals.len(), keep)));
        for (name, lam) in [("rep_only", 1.0f32), ("div_only", 0.0), ("rep+div", 0.3)] {
            // score via the coarse filter machinery on raw-input "features"
            // of the candidates themselves (filter-feature geometry mirrors
            // input geometry for the synthetic tasks)
            let dim = 16.min(arrivals[0].dim());
            let mut filt = CoarseFilter::new(classes, dim, keep, lam);
            for s in &arrivals {
                let feat: Vec<f32> = s.x[..dim].to_vec();
                filt.process(s.clone(), &feat);
            }
            let kept: Vec<usize> = filt
                .drain()
                .into_iter()
                // detlint: allow(R001) invariant: drained candidates came out of `arrivals`
                .map(|c| arrivals.iter().position(|s| s.id == c.sample.id).unwrap())
                .collect();
            schemes.push((name, kept));
        }

        for (name, subset) in schemes {
            // MSE of C-IS restricted to the subset = Theorem-2 variance on
            // the sub-Gram + the subset-selection bias ‖ḡ_S − ḡ_F‖²
            // (the batch estimates the FULL stream's gradient; a filtered
            // candidate pool whose mean drifts from the stream mean pays
            // that drift as bias even if its internal variance is small)
            let sub_labels: Vec<u32> = subset.iter().map(|&i| labels_all[i]).collect();
            let sub_imp = sub_importance(&imp_all, &subset);
            let summaries = class_summaries(&sub_labels, &sub_imp, classes);
            let spec = spec_cis(&summaries, &sub_imp, batch);
            // two metrics: pure estimator variance on the candidate pool
            // (the paper's "gradient variance reduction degree") and the
            // stricter MSE that charges the pool's drift from the full
            // stream mean as bias (our addition — see EXPERIMENTS.md)
            let var_only = theorem2_variance(&summaries, &spec);
            let mse = var_only + subset_bias2(&imp_all, &subset);
            let ret_var = ((rs_all - var_only) / ideal_reduction).max(0.0);
            let ret_mse = ((rs_all - mse) / ideal_reduction).max(0.0);
            rows.push(vec![
                model.clone(),
                name.to_string(),
                format!("{var_only:.4}"),
                format!("{:.1}", ret_var * 100.0),
                format!("{:.1}", ret_mse * 100.0),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("filter", Json::Str(name.into())),
                ("variance", Json::Num(var_only)),
                ("mse", Json::Num(mse)),
                ("retained_var_pct", Json::Num(ret_var * 100.0)),
                ("retained_mse_pct", Json::Num(ret_mse * 100.0)),
            ]));
        }
        rows.push(vec![
            model.clone(),
            "ideal(all)".into(),
            format!("{cis_all:.4}"),
            "100.0".into(),
            "100.0".into(),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["model", "filter", "V[C-IS]", "retained_var_%", "retained_mse_%"],
            &rows
        )
    );
    let path = write_result("fig5b", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// ‖ḡ_S − ḡ_F‖²: squared distance between the subset's mean gradient and
/// the full candidate set's, computed from the Gram matrix.
fn subset_bias2(imp: &crate::runtime::model::ImportanceOut, subset: &[usize]) -> f64 {
    let nf = imp.valid;
    let ns = subset.len();
    if ns == 0 || nf == 0 {
        return 0.0;
    }
    let mut ss = 0.0f64; // Σ_{i,j∈S} K
    for &i in subset {
        for &j in subset {
            // detlint: allow(D004) see above: pinned row-major Gram reduction
            ss += imp.k_at(i, j) as f64;
        }
    }
    let mut sf = 0.0f64; // Σ_{i∈S, j∈F} K
    for &i in subset {
        for j in 0..nf {
            // detlint: allow(D004) see above: pinned row-major Gram reduction
            sf += imp.k_at(i, j) as f64;
        }
    }
    let mut ff = 0.0f64; // Σ_{i,j∈F} K
    for i in 0..nf {
        for j in 0..nf {
            // detlint: allow(D004) see above: pinned row-major Gram reduction
            ff += imp.k_at(i, j) as f64;
        }
    }
    (ss / (ns * ns) as f64 - 2.0 * sf / (ns * nf) as f64 + ff / (nf * nf) as f64).max(0.0)
}

/// Restrict an ImportanceOut to a candidate subset.
fn sub_importance(
    imp: &crate::runtime::model::ImportanceOut,
    subset: &[usize],
) -> crate::runtime::model::ImportanceOut {
    let m = subset.len();
    let mut k = vec![0.0f32; m * m];
    for (a, &i) in subset.iter().enumerate() {
        for (b, &j) in subset.iter().enumerate() {
            k[a * m + b] = imp.k_at(i, j);
        }
    }
    crate::runtime::model::ImportanceOut {
        norms: subset.iter().map(|&i| imp.norms[i]).collect(),
        k,
        n_total: m,
        valid: m,
    }
}

/// Fig. 5(c): Pearson correlation of per-sample gradient norms between
/// rounds separated by a gap (fixed probe set).
pub fn run_c(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let gaps = [1usize, 2, 5, 10];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let mut cfg = super::tune(presets::table1(model, Method::Rs), args)?;
        cfg.pipeline = false;
        let rounds = cfg.rounds.min(40);
        let (mut stream, _) = build_stream(&cfg);
        let mut trainer = TrainerEngine::new(&cfg)?;
        let mut sel = SelectorEngine::new(&cfg, stream.task())?;
        // fixed probe set
        let probe: Vec<Sample> = stream.next_round(cfg.stream_per_round);
        let probe_refs: Vec<&Sample> = probe.iter().collect();
        let mut norm_history: Vec<Vec<f32>> = Vec::new();
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(cfg.seed ^ 0xF16C);
        for _ in 0..rounds {
            sel.sync_params(trainer.share_params())?;
            norm_history.push(sel.rt.importance(&probe_refs)?.norms);
            let arrivals = stream.next_round(cfg.stream_per_round);
            let picks = rng.sample_indices(arrivals.len(), cfg.batch_size);
            let batch: Vec<Sample> = picks.iter().map(|&i| arrivals[i].clone()).collect();
            trainer.train(&batch)?;
        }
        for &gap in &gaps {
            let mut cors = Vec::new();
            for t in 0..norm_history.len().saturating_sub(gap) {
                cors.push(pearson(&norm_history[t], &norm_history[t + gap]));
            }
            let mean_cor = crate::util::stats::mean(&cors);
            rows.push(vec![
                model.clone(),
                format!("{gap}"),
                format!("{mean_cor:.3}"),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("gap", Json::Num(gap as f64)),
                ("mean_pearson", Json::Num(mean_cor)),
            ]));
        }
    }
    println!(
        "{}",
        render_table(&["model", "round_gap", "norm_correlation"], &rows)
    );
    let path = write_result("fig5c", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// Pearson correlation of two f32 series.
fn pearson(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    if n < 2 {
        return 0.0;
    }
    let wide_a: Vec<f64> = a[..n].iter().map(|&x| x as f64).collect();
    let wide_b: Vec<f64> = b[..n].iter().map(|&x| x as f64).collect();
    let ma = crate::util::stats::sum(&wide_a) / n as f64;
    let mb = crate::util::stats::sum(&wide_b) / n as f64;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        let da = wide_a[i] - ma;
        let db = wide_b[i] - mb;
        // detlint: allow(D004) offline figure statistic; single-pass moment order is pinned
        cov += da * db;
        // detlint: allow(D004) see above
        va += da * da;
        // detlint: allow(D004) see above
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_basics() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let up = [2.0f32, 4.0, 6.0, 8.0];
        let down = [4.0f32, 3.0, 2.0, 1.0];
        assert!((pearson(&a, &up) - 1.0).abs() < 1e-9);
        assert!((pearson(&a, &down) + 1.0).abs() < 1e-9);
        assert_eq!(pearson(&a[..1], &up[..1]), 0.0);
    }

    #[test]
    fn sub_importance_extracts_block() {
        use crate::selection::testutil::importance_from_grads;
        let imp = importance_from_grads(&[(1.0, 0.0), (0.0, 1.0), (2.0, 0.0)]);
        let sub = sub_importance(&imp, &[0, 2]);
        assert_eq!(sub.valid, 2);
        assert!((sub.k_at(0, 1) - 2.0).abs() < 1e-5); // <(1,0),(2,0)> = 2
        assert_eq!(sub.norms.len(), 2);
    }
}
