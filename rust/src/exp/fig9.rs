//! Fig. 9 / Appendix B — fluctuant idle computing resources: Titan's
//! accuracy and training-time reduction as the candidate budget follows
//! the idle capacity (constant budgets 15..100 plus a fluctuating trace).

use crate::config::{presets, Method};
use crate::coordinator::session::observers::CandidateAudit;
use crate::coordinator::SessionBuilder;
use crate::device::idle::IdleTrace;
use crate::metrics::{render_table, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let budgets = [15usize, 30, 50, 100];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        // RS reference for time reduction
        let rs_cfg = super::tune(presets::table1(model, Method::Rs), args)?;
        let (rs, _) = SessionBuilder::new(rs_cfg).sequential().run()?;
        let target = rs.final_accuracy * super::TARGET_FRAC;
        let rs_time = rs
            .time_to_accuracy_device(target)
            .unwrap_or(rs.total_device_ms);

        // average 3 seeds: time-to-target crossings near the plateau are
        // seed-noisy, and Fig. 9's claim is a monotone trend in the budget
        let seeds = [0u64, 1, 2];
        let mut run_one = |label: String, cand: usize, trace: IdleTrace| -> Result<()> {
            let mut accs = Vec::new();
            let mut reds = Vec::new();
            let mut realized = Vec::new();
            for &ds in &seeds {
                let mut cfg = super::tune(presets::table1(model, Method::Titan), args)?;
                cfg.seed ^= ds.wrapping_mul(0x9E37);
                cfg.candidate_size = cand;
                cfg.stream_per_round = cfg.stream_per_round.max(cand);
                // the audit observer records each round's realized
                // candidate count — the budget the idle trace actually
                // granted, reported next to the configured maximum
                let (audit, audit_log) = CandidateAudit::new();
                let (rec, _) = SessionBuilder::new(cfg)
                    .pipelined(trace.clone())
                    .observe(audit)
                    .run()?;
                let counts = audit_log.lock().unwrap_or_else(|e| e.into_inner());
                realized.push(
                    counts.iter().sum::<usize>() as f64 / counts.len().max(1) as f64,
                );
                drop(counts);
                let tta = rec
                    .time_to_accuracy_device(target)
                    .unwrap_or(rec.total_device_ms);
                accs.push(rec.final_accuracy);
                reds.push((1.0 - tta / rs_time.max(1e-9)) * 100.0);
            }
            let acc = crate::util::stats::mean(&accs);
            let reduction = crate::util::stats::mean(&reds);
            let mean_realized = crate::util::stats::mean(&realized);
            rows.push(vec![
                model.clone(),
                label.clone(),
                format!("{mean_realized:.1}"),
                format!("{:.1}", acc * 100.0),
                format!("{reduction:.0}%"),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("budget", Json::Str(label)),
                ("mean_realized_candidates", Json::Num(mean_realized)),
                ("final_accuracy", Json::Num(acc)),
                ("time_reduction_pct", Json::Num(reduction)),
            ]));
            Ok(())
        };

        for &b in &budgets {
            run_one(format!("{b}"), b, IdleTrace::Constant(1.0))?;
        }
        // fluctuating trace around budget 100 (random walk 0.15..1.0)
        run_one(
            "fluctuant".into(),
            100,
            IdleTrace::RandomWalk { min: 0.15, max: 1.0, step: 0.15, seed: 5 },
        )?;
    }
    println!(
        "{}",
        render_table(
            &["model", "candidates", "realized", "final_acc_%", "time_reduction"],
            &rows
        )
    );
    let path = write_result("fig9", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
