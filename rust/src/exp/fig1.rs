//! Fig. 1 — motivation: final accuracy and normalized training time as a
//! function of the data-utilization rate.
//!
//! Utilization r means: of the v samples streaming in per round, r·v are
//! actually trained on (as r·v/|B| SGD steps per stream round). Higher r →
//! better accuracy but proportionally more device time — the tension Titan
//! resolves. The paper shows 9.6–13.4% accuracy loss at low utilization
//! and 2–3.2× time at full utilization.

use crate::config::presets;
use crate::coordinator::{build_stream, TrainerEngine};
use crate::device::{DeviceSim, Lane, Op};
use crate::metrics::{render_table, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let rates = [0.1f64, 0.2, 0.5, 1.0];
    let mut rows = Vec::new();
    let mut out = Vec::new();

    for model in &models {
        let cfg = super::tune(presets::base(model), args)?;
        // few stream rounds: the motivation figure lives in the data-scarce
        // regime (low utilization must visibly underfit; at plateau the
        // effect vanishes by definition)
        let stream_rounds = (cfg.rounds / 8).clamp(10, 40);
        let mut base_time = 0.0f64;
        for (ri, &rate) in rates.iter().enumerate() {
            let steps_per_round =
                ((rate * cfg.stream_per_round as f64 / cfg.batch_size as f64).round() as usize).max(1);
            let (mut stream, test) = build_stream(&cfg);
            let mut trainer = TrainerEngine::new(&cfg)?;
            let mut sim = DeviceSim::new(model);
            let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ ri as u64);
            for _ in 0..stream_rounds {
                let arrivals = stream.next_round(cfg.stream_per_round);
                for _ in 0..steps_per_round {
                    let picks = rng.sample_indices(arrivals.len(), cfg.batch_size);
                    let batch: Vec<_> = picks.iter().map(|&i| arrivals[i].clone()).collect();
                    trainer.train(&batch)?;
                    sim.record(Lane::Cpu, Op::TrainStep { batch: batch.len() });
                }
                sim.end_round(false);
            }
            let eval = trainer.evaluate(&test)?;
            if ri == 0 {
                base_time = sim.total_ms().max(1.0);
            }
            let norm_time = sim.total_ms() / base_time;
            rows.push(vec![
                model.clone(),
                format!("{rate:.1}"),
                format!("{:.1}", eval.accuracy * 100.0),
                format!("{norm_time:.2}"),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("utilization", Json::Num(rate)),
                ("accuracy", Json::Num(eval.accuracy)),
                ("norm_time", Json::Num(norm_time)),
            ]));
        }
    }

    println!(
        "{}",
        render_table(&["model", "utilization", "final_acc_%", "norm_time"], &rows)
    );
    let path = write_result("fig1", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
