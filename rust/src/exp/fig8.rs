//! Fig. 8 — impact of the coarse filter's feature depth: processing delay
//! and final accuracy of Titan with n model blocks for feature extraction,
//! compared against bare C-IS on the whole stream (the ideal).
//!
//! Paper findings reproduced here: block-1 features are 6.5–94× faster
//! than full C-IS with ≤0.4% accuracy drop; deeper blocks cost more and
//! gradually *hurt* accuracy (deep features are too concentrated for
//! diversity filtering).

use crate::config::{presets, Method};
use crate::coordinator::SessionBuilder;
use crate::device::idle::IdleTrace;
use crate::device::{CostModel, Op};
use crate::metrics::{render_table, write_result};
use crate::runtime::artifact::ArtifactSet;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let set = ArtifactSet::discover("artifacts", model)?;
        let n_blocks = set.meta.num_blocks();
        let costs = CostModel::for_model(model);

        // ideal: C-IS over the whole stream, no filter
        let mut cis_cfg = super::tune(presets::table1(model, Method::Cis), args)?;
        cis_cfg.pipeline = false;
        let (cis_rec, _) = SessionBuilder::new(cis_cfg).sequential().run()?;
        let cis_delay = costs.cost_ms(Op::Importance { n: 1 });
        rows.push(vec![
            model.clone(),
            "C-IS(all)".into(),
            format!("{cis_delay:.1}"),
            format!("{:.1}", cis_rec.final_accuracy * 100.0),
            "-".into(),
        ]);
        out.push(Json::obj(vec![
            ("model", Json::Str(model.clone())),
            ("config", Json::Str("cis_all".into())),
            ("device_per_sample_ms", Json::Num(cis_delay)),
            ("final_accuracy", Json::Num(cis_rec.final_accuracy)),
        ]));

        for k in 1..=n_blocks {
            let mut cfg = super::tune(presets::table1(model, Method::Titan), args)?;
            cfg.filter_blocks = k;
            let (rec, _) = SessionBuilder::new(cfg)
                .pipelined(IdleTrace::Constant(1.0))
                .run()?;
            let delay = costs.cost_ms(Op::Features { chunk: 1, blocks: k });
            let speedup = cis_delay / delay.max(1e-9);
            rows.push(vec![
                model.clone(),
                format!("Titan-{k}"),
                format!("{delay:.1}"),
                format!("{:.1}", rec.final_accuracy * 100.0),
                format!("{speedup:.1}x"),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("config", Json::Str(format!("titan_b{k}"))),
                ("blocks", Json::Num(k as f64)),
                ("device_per_sample_ms", Json::Num(delay)),
                ("final_accuracy", Json::Num(rec.final_accuracy)),
                ("speedup_vs_cis", Json::Num(speedup)),
            ]));
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "config", "delay_ms/sample", "final_acc_%", "speedup"],
            &rows
        )
    );
    let path = write_result("fig8", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
