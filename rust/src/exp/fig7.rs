//! Fig. 7 — component study: training curves of every selection method
//! (the fine-grained C-IS ablation). Curves land in results/fig7.json;
//! the stdout table summarizes rounds-to-target and final accuracy.

use crate::config::presets;
use crate::metrics::{render_table, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let methods = super::table1_methods();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        // target accuracy = RS final (as in the paper's horizontal line)
        let rs_cfg = super::tune(presets::table1(model, crate::config::Method::Rs), args)?;
        let rs = super::run_config(&rs_cfg)?;
        let target = rs.final_accuracy * super::TARGET_FRAC;
        for &method in &methods {
            let record = if method == crate::config::Method::Rs {
                rs.clone()
            } else {
                let cfg = super::tune(presets::table1(model, method), args)?;
                super::run_config(&cfg)?
            };
            let rounds_to = record
                .rounds_to_accuracy(target)
                .map(|r| r.to_string())
                .unwrap_or_else(|| "-".into());
            rows.push(vec![
                model.clone(),
                method.name().to_string(),
                rounds_to,
                format!("{:.1}", record.final_accuracy * 100.0),
            ]);
            let curve: Vec<Json> = record
                .curve
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("round", Json::Num(p.round as f64)),
                        ("test_accuracy", Json::Num(p.test_accuracy)),
                        ("test_loss", Json::Num(p.test_loss)),
                    ])
                })
                .collect();
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("method", Json::Str(method.name().into())),
                ("target", Json::Num(target)),
                ("final_accuracy", Json::Num(record.final_accuracy)),
                ("curve", Json::Arr(curve)),
            ]));
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "method", "rounds_to_target", "final_acc_%"],
            &rows
        )
    );
    let path = write_result("fig7", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
