//! Experiment harness: one module per paper table/figure (see DESIGN.md's
//! experiment index). Each experiment
//!
//!   1. builds its preset configs (honoring `--fast` and `--models`),
//!   2. runs a coordinator session (`SessionBuilder`; sequential or
//!      pipelined as the paper does),
//!   3. prints the paper-shaped rows/series to stdout, and
//!   4. writes machine-readable results under `results/<id>.json`.
//!
//! `titan exp <id> [--fast] [--models a,b] [--seed N]` from the CLI.

pub mod fig1;
pub mod fig2;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod retention;
pub mod table1;

use crate::config::{Method, RunConfig};
use crate::coordinator::SessionBuilder;
use crate::metrics::RunRecord;
use crate::util::cli::Args;
use crate::{Error, Result};

/// All experiment ids, in paper order.
pub const ALL: &[(&str, &str)] = &[
    ("fig1", "motivation: accuracy & time vs data utilization"),
    ("fig2a", "per-round training time per selection method"),
    ("fig2b", "training curves at batch 10 vs 25"),
    ("fig5a", "batch-gradient variance: RS vs IS vs C-IS"),
    ("fig5b", "coarse filter vs C-IS variance-reduction retention"),
    ("fig5c", "importance stability across rounds"),
    ("table1", "time-to-accuracy + final accuracy, all methods x models"),
    ("fig6a", "per-round time: train-only vs sequential vs pipeline"),
    ("fig6b", "per-streaming-sample processing delay"),
    ("fig6c", "peak memory footprint breakdown"),
    ("fig6d", "device power and total energy vs RS"),
    ("fig7", "training curves of all methods (component study)"),
    ("fig8", "filter depth vs delay and accuracy"),
    ("fig9", "fluctuant idle resources / candidate budgets"),
    ("fig10", "federated learning with 50 devices"),
    ("fig11", "noisy data streams (feature/label noise)"),
    ("ret", "storage-budget sweep: retention policies vs byte budget"),
];

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1" => fig1::run(args),
        "fig2a" => fig2::run_a(args),
        "fig2b" => fig2::run_b(args),
        "fig5a" => fig5::run_a(args),
        "fig5b" => fig5::run_b(args),
        "fig5c" => fig5::run_c(args),
        "table1" => table1::run(args),
        "fig6a" => fig6::run_a(args),
        "fig6b" => fig6::run_b(args),
        "fig6c" => fig6::run_c(args),
        "fig6d" => fig6::run_d(args),
        "fig7" => fig7::run(args),
        "fig8" => fig8::run(args),
        "fig9" => fig9::run(args),
        "fig10" => fig10::run(args),
        "fig11" => fig11::run(args),
        "ret" => retention::run(args),
        "all" => {
            for (id, _) in ALL {
                println!("\n===== exp {id} =====");
                run(id, args)?;
            }
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown experiment {other:?}; known: {}",
            ALL.iter().map(|(i, _)| *i).collect::<Vec<_>>().join(", ")
        ))),
    }
}

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

/// Models requested on the CLI (default: just mlp for tractable runs;
/// pass --models all for the full paper set).
pub fn models_from_args(args: &Args, default: &[&str]) -> Vec<String> {
    let requested = args.get_list("models", default);
    if requested.len() == 1 && requested[0] == "all" {
        crate::config::presets::TABLE1_MODELS
            .iter()
            .map(|(m, _)| m.to_string())
            .collect()
    } else {
        requested
    }
}

/// Apply --fast/--seed/--rounds overrides to a preset config.
pub fn tune(mut cfg: RunConfig, args: &Args) -> Result<RunConfig> {
    cfg = crate::config::presets::fast(cfg, args.has_flag("fast"));
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.rounds = args.get_usize("rounds", cfg.rounds)?;
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    Ok(cfg)
}

/// Run one config with the backend the paper would use for it (the
/// config's `pipeline` flag picks the session backend).
pub fn run_config(cfg: &RunConfig) -> Result<RunRecord> {
    let (record, _) = SessionBuilder::new(cfg.clone()).run()?;
    Ok(record)
}

/// Time-to-accuracy target as a fraction of RS's final accuracy.
///
/// The paper uses RS's final accuracy verbatim; on our synthetic tasks all
/// methods *plateau* within the round budget (unlike CIFAR-10 at the
/// paper's budgets), so the verbatim target sits on the plateau and
/// time-to-target becomes seed noise. 98% of RS-final sits just below the
/// plateau knee and recovers the paper's intended measurement. Recorded in
/// EXPERIMENTS.md §Deviations.
pub const TARGET_FRAC: f64 = 0.98;

/// Format helper: normalized value with 2 decimals.
pub fn norm(v: f64, base: f64) -> String {
    if base <= 0.0 {
        "-".into()
    } else {
        format!("{:.2}", v / base)
    }
}

/// The methods of Table 1, in the paper's column order.
pub fn table1_methods() -> Vec<Method> {
    vec![
        Method::Rs,
        Method::Is,
        Method::Ll,
        Method::Hl,
        Method::Ce,
        Method::Ocs,
        Method::Camel,
        Method::Titan,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique() {
        let mut ids: Vec<&str> = ALL.iter().map(|(i, _)| *i).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn unknown_experiment_errors() {
        let args = Args::parse(Vec::<String>::new()).unwrap();
        assert!(run("nope", &args).is_err());
    }

    #[test]
    fn models_expansion() {
        let args = Args::parse(["--models", "all"].iter().map(|s| s.to_string())).unwrap();
        let m = models_from_args(&args, &["mlp"]);
        assert_eq!(m.len(), 6);
        let args = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(models_from_args(&args, &["mlp"]), vec!["mlp"]);
    }

    #[test]
    fn norm_formatting() {
        assert_eq!(norm(5.0, 10.0), "0.50");
        assert_eq!(norm(5.0, 0.0), "-");
    }
}
