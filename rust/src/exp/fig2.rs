//! Fig. 2 — the motivating comparison of cloud-side selection methods on
//! device:
//!
//! (a) per-round training time of each method (the importance-computation
//!     blowup: IS/HDS/CS up to ~7× training-only);
//! (b) training curves at batch sizes 10 and 25 (HDS degrades at small
//!     batch; RS is surprisingly strong).

use crate::config::{presets, Method};
use crate::coordinator::SessionBuilder;
use crate::metrics::{render_table, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

/// Fig. 2(a): mean per-round device time per method (normalized to RS).
pub fn run_a(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let methods = super::table1_methods();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let mut rs_time = 0.0f64;
        for &method in &methods {
            let mut cfg = super::tune(presets::table1(model, method), args)?;
            cfg.rounds = cfg.rounds.min(12); // timing stabilizes quickly
            cfg.eval_every = 0;
            cfg.pipeline = false; // (a) isolates the selection cost
            let (record, _) = SessionBuilder::new(cfg.clone()).sequential().run()?;
            let per_round =
                record.total_device_ms / cfg.rounds as f64;
            if method == Method::Rs {
                rs_time = per_round;
            }
            rows.push(vec![
                model.clone(),
                method.name().to_string(),
                format!("{per_round:.0}"),
                super::norm(per_round, rs_time),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("method", Json::Str(method.name().into())),
                ("per_round_device_ms", Json::Num(per_round)),
                ("vs_rs", Json::Num(if rs_time > 0.0 { per_round / rs_time } else { 0.0 })),
            ]));
        }
    }
    println!(
        "{}",
        render_table(&["model", "method", "round_ms(dev)", "xRS"], &rows)
    );
    let path = write_result("fig2a", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// Fig. 2(b): training curves at batch 10 vs 25 for RS and the heuristics.
pub fn run_b(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let methods = [Method::Rs, Method::Ll, Method::Ce, Method::Camel, Method::Is];
    let mut out = Vec::new();
    let mut rows = Vec::new();
    for model in &models {
        for &batch in &[10usize, 25] {
            for &method in &methods {
                let mut cfg = super::tune(presets::table1(model, method), args)?;
                cfg.batch_size = batch;
                cfg.candidate_size = cfg.candidate_size.max(batch + 5);
                cfg.pipeline = false;
                let (record, _) = SessionBuilder::new(cfg.clone()).sequential().run()?;
                let curve: Vec<Json> = record
                    .curve
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("round", Json::Num(p.round as f64)),
                            ("test_accuracy", Json::Num(p.test_accuracy)),
                        ])
                    })
                    .collect();
                rows.push(vec![
                    model.clone(),
                    format!("{batch}"),
                    method.name().to_string(),
                    format!("{:.1}", record.final_accuracy * 100.0),
                ]);
                out.push(Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("batch", Json::Num(batch as f64)),
                    ("method", Json::Str(method.name().into())),
                    ("final_accuracy", Json::Num(record.final_accuracy)),
                    ("curve", Json::Arr(curve)),
                ]));
            }
        }
    }
    println!(
        "{}",
        render_table(&["model", "batch", "method", "final_acc_%"], &rows)
    );
    let path = write_result("fig2b", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
