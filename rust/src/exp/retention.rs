//! `ret` — storage-budget sweep for the retention plane: final accuracy
//! and store telemetry of Titan under each [`RetentionPolicy`] across a
//! range of byte budgets, against the unbudgeted baseline.
//!
//! This is the experiment axis the ROADMAP's retention item opens: the
//! paper's two stages select from the *current* stream window only, while
//! the storage-budget question ("To Store or Not?", PAPERS.md) is what to
//! *keep* across rounds. A zero-budget row is included so the neutrality
//! pin is visible in the output: it must match a plain run exactly.
//!
//! [`RetentionPolicy`]: crate::retention::RetentionPolicy

use crate::config::{presets, Method};
use crate::metrics::{render_table, write_result};
use crate::retention::RetentionKind;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

/// Byte budgets swept per policy (the zero row is the baseline).
const BUDGETS: &[usize] = &[0, 1 << 14, 1 << 16, 1 << 18];

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let kinds = [RetentionKind::Score, RetentionKind::Balanced, RetentionKind::Reservoir];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        for &bytes in BUDGETS {
            // the zero-budget baseline is policy-independent: run it once
            let swept: &[RetentionKind] = if bytes == 0 { &kinds[..1] } else { &kinds };
            for &kind in swept {
                let mut cfg = super::tune(presets::table1(model, Method::Titan), args)?;
                cfg.store_bytes = bytes;
                cfg.retention = kind;
                cfg.replay_mix = args.get_f64("replay-mix", cfg.replay_mix)?;
                cfg.validate()?;
                let rec = super::run_config(&cfg)?;
                let policy = if bytes == 0 { "-".to_string() } else { kind.name().to_string() };
                let (admits, evicts, held, hit) = match &rec.retention {
                    Some(t) => (
                        t.admits.to_string(),
                        t.evicts_total().to_string(),
                        t.bytes_held.to_string(),
                        format!("{:.3}", t.hit_rate()),
                    ),
                    None => ("-".into(), "-".into(), "-".into(), "-".into()),
                };
                rows.push(vec![
                    model.clone(),
                    policy.clone(),
                    bytes.to_string(),
                    format!("{:.2}", rec.final_accuracy * 100.0),
                    admits,
                    evicts,
                    held,
                    hit,
                ]);
                let mut fields = vec![
                    ("model", Json::Str(model.clone())),
                    ("policy", Json::Str(policy)),
                    ("store_bytes", Json::Num(bytes as f64)),
                    ("final_accuracy", Json::Num(rec.final_accuracy)),
                ];
                if let Some(t) = &rec.retention {
                    fields.push(("telemetry", t.to_json()));
                }
                out.push(Json::obj(fields));
            }
        }
    }
    println!(
        "{}",
        render_table(
            &["model", "policy", "store_bytes", "final_acc_%", "admits", "evicts", "bytes_held", "hit_rate"],
            &rows
        )
    );
    let path = write_result("ret", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
