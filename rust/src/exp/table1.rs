//! Table 1 — the headline result: normalized time-to-accuracy and final
//! accuracy for all 8 methods across the 6 (task, model) rows.
//!
//! Target accuracy per the paper: the final accuracy of RS. Times are on
//! the simulated device clock, normalized to RS's time-to-target.
//! Methods that never reach the target report their total run time
//! (like the paper's footnote).

use crate::config::presets;
use crate::metrics::{render_table, write_csv, write_result};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

pub fn run(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let methods = super::table1_methods();
    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    let mut out = Vec::new();

    for model in &models {
        // RS first: it defines the target accuracy + the time normalizer
        let rs_cfg = super::tune(presets::table1(model, crate::config::Method::Rs), args)?;
        let rs_record = super::run_config(&rs_cfg)?;
        let target = rs_record.final_accuracy * super::TARGET_FRAC;
        let rs_time = rs_record
            .time_to_accuracy_device(target)
            .unwrap_or(rs_record.total_device_ms)
            .max(1e-9);

        for &method in &methods {
            let record = if method == crate::config::Method::Rs {
                rs_record.clone()
            } else {
                let cfg = super::tune(presets::table1(model, method), args)?;
                super::run_config(&cfg)?
            };
            let (tta, reached) = match record.time_to_accuracy_device(target) {
                Some(t) => (t, true),
                None => (record.total_device_ms, false),
            };
            let norm_t = tta / rs_time;
            rows.push(vec![
                model.clone(),
                method.name().to_string(),
                format!("{}{:.2}", if reached { "" } else { ">" }, norm_t),
                format!("{:.1}", record.final_accuracy * 100.0),
            ]);
            csv_rows.push(vec![
                model.clone(),
                method.name().to_string(),
                format!("{norm_t:.4}"),
                format!("{}", reached),
                format!("{:.4}", record.final_accuracy),
            ]);
            out.push(Json::obj(vec![
                ("model", Json::Str(model.clone())),
                ("method", Json::Str(method.name().into())),
                ("target_accuracy", Json::Num(target)),
                ("norm_time_to_accuracy", Json::Num(norm_t)),
                ("reached_target", Json::Bool(reached)),
                ("final_accuracy", Json::Num(record.final_accuracy)),
                ("total_device_ms", Json::Num(record.total_device_ms)),
                ("total_host_ms", Json::Num(record.total_host_ms)),
            ]));
        }
    }

    println!(
        "{}",
        render_table(
            &["model", "method", "norm_time_to_acc", "final_acc_%"],
            &rows
        )
    );
    write_csv(
        "table1",
        &["model", "method", "norm_tta", "reached", "final_acc"],
        &csv_rows,
    )?;
    let path = write_result("table1", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
