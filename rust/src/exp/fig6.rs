//! Fig. 6 — system-overhead analysis:
//!
//! (a) per-round time: train-only vs sequential (train+select) vs the
//!     pipeline (co-execution) — the pipeline's sync cost is negligible;
//! (b) per-streaming-sample processing delay (Titan: 4–13 ms device /
//!     sub-ms host);
//! (c) peak memory footprint breakdown (pipeline adds <10% for conv nets);
//! (d) average device power and total energy vs RS.

use crate::config::{presets, Method};
use crate::coordinator::SessionBuilder;
use crate::device::idle::IdleTrace;
use crate::device::{memory, CostModel, Op};
use crate::metrics::{render_table, write_result};
use crate::runtime::artifact::ArtifactSet;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::Result;

/// Fig. 6(a).
pub fn run_a(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let mut cfg = super::tune(presets::table1(model, Method::Titan), args)?;
        cfg.rounds = cfg.rounds.min(12);
        cfg.eval_every = 0;

        // train-only: the device cost of just the SGD step
        let costs = CostModel::for_model(model);
        let train_only = costs.cost_ms(Op::TrainStep { batch: cfg.batch_size });

        let mut seq_cfg = cfg.clone();
        seq_cfg.pipeline = false;
        let (seq_rec, _) = SessionBuilder::new(seq_cfg.clone()).sequential().run()?;
        let seq_ms = seq_rec.total_device_ms / seq_cfg.rounds as f64;

        let (pipe_rec, _) = SessionBuilder::new(cfg.clone())
            .pipelined(IdleTrace::Constant(1.0))
            .run()?;
        let pipe_ms = pipe_rec.total_device_ms / cfg.rounds as f64;

        rows.push(vec![
            model.clone(),
            format!("{train_only:.0}"),
            format!("{seq_ms:.0}"),
            format!("{pipe_ms:.0}"),
            format!("{:.1}%", (pipe_ms / train_only - 1.0) * 100.0),
        ]);
        out.push(Json::obj(vec![
            ("model", Json::Str(model.clone())),
            ("train_only_ms", Json::Num(train_only)),
            ("sequential_ms", Json::Num(seq_ms)),
            ("pipeline_ms", Json::Num(pipe_ms)),
        ]));
    }
    println!(
        "{}",
        render_table(
            &["model", "train_only", "sequential", "pipeline", "pipe_overhead"],
            &rows
        )
    );
    let path = write_result("fig6a", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// Fig. 6(b): per-streaming-sample processing delay. Device-model delay
/// (block-1 forward per sample) + measured host delay from a Titan run.
pub fn run_b(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let mut cfg = super::tune(presets::table1(model, Method::Titan), args)?;
        cfg.rounds = cfg.rounds.min(10);
        cfg.eval_every = 0;
        let (rec, _) = SessionBuilder::new(cfg.clone())
            .pipelined(IdleTrace::Constant(1.0))
            .run()?;
        let costs = CostModel::for_model(model);
        let device_ms = costs.cost_ms(Op::Features { chunk: 1, blocks: cfg.filter_blocks });
        rows.push(vec![
            model.clone(),
            format!("{device_ms:.1}"),
            format!("{:.3}", rec.processing_delay.mean_ms()),
            format!("{:.3}", rec.processing_delay.percentile_ms(99.0)),
        ]);
        out.push(Json::obj(vec![
            ("model", Json::Str(model.clone())),
            ("device_per_sample_ms", Json::Num(device_ms)),
            ("host_per_sample_ms_mean", Json::Num(rec.processing_delay.mean_ms())),
            ("host_per_sample_ms_p99", Json::Num(rec.processing_delay.percentile_ms(99.0))),
        ]));
    }
    println!(
        "{}",
        render_table(
            &["model", "device_ms/sample", "host_ms/sample", "host_p99"],
            &rows
        )
    );
    let path = write_result("fig6b", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// Fig. 6(c): memory breakdown.
pub fn run_c(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let cfg = super::tune(presets::table1(model, Method::Titan), args)?;
        let set = ArtifactSet::discover(&cfg.artifacts_dir, model)?;
        let m = &set.meta;
        let br = memory::estimate(
            m.param_count,
            memory::act_mult_for(model),
            cfg.batch_size,
            m.input_dim,
            cfg.candidate_size,
            m.cand_max,
            m.feature_dim(cfg.filter_blocks),
            m.filter_chunk,
            true,
        );
        let mb = |b: usize| format!("{:.2}", b as f64 / (1024.0 * 1024.0));
        rows.push(vec![
            model.clone(),
            mb(br.params_trainer + br.train_activations),
            mb(br.params_selector),
            mb(br.candidate_buffer + br.selection_workspace),
            format!("{:.1}%", br.overhead_frac() * 100.0),
        ]);
        out.push(Json::obj(vec![
            ("model", Json::Str(model.clone())),
            ("training_mb", Json::Num((br.params_trainer + br.train_activations) as f64 / 1048576.0)),
            ("selector_params_mb", Json::Num(br.params_selector as f64 / 1048576.0)),
            ("selection_mb", Json::Num((br.candidate_buffer + br.selection_workspace) as f64 / 1048576.0)),
            ("overhead_frac", Json::Num(br.overhead_frac())),
        ]));
    }
    println!(
        "{}",
        render_table(
            &["model", "train_MB", "replica_MB", "selection_MB", "overhead"],
            &rows
        )
    );
    let path = write_result("fig6c", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}

/// Fig. 6(d): power / energy, Titan vs RS.
pub fn run_d(args: &Args) -> Result<()> {
    let models = super::models_from_args(args, &["mlp"]);
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in &models {
        let mut rs_cfg = super::tune(presets::table1(model, Method::Rs), args)?;
        rs_cfg.rounds = rs_cfg.rounds.min(20);
        rs_cfg.eval_every = 0;
        let (rs, _) = SessionBuilder::new(rs_cfg).sequential().run()?;
        let mut ti_cfg = super::tune(presets::table1(model, Method::Titan), args)?;
        ti_cfg.rounds = ti_cfg.rounds.min(20);
        ti_cfg.eval_every = 0;
        let (ti, _) = SessionBuilder::new(ti_cfg)
            .pipelined(IdleTrace::Constant(1.0))
            .run()?;
        rows.push(vec![
            model.clone(),
            format!("{:.2}", rs.avg_power_w),
            format!("{:.2}", ti.avg_power_w),
            format!("{:.2}x", ti.avg_power_w / rs.avg_power_w.max(1e-9)),
            format!("{:.2}x", ti.total_device_ms / rs.total_device_ms.max(1e-9)),
            format!("{:.2}x", ti.energy_j / rs.energy_j.max(1e-9)),
        ]);
        out.push(Json::obj(vec![
            ("model", Json::Str(model.clone())),
            ("rs_power_w", Json::Num(rs.avg_power_w)),
            ("titan_power_w", Json::Num(ti.avg_power_w)),
            ("power_ratio", Json::Num(ti.avg_power_w / rs.avg_power_w.max(1e-9))),
            ("time_ratio", Json::Num(ti.total_device_ms / rs.total_device_ms.max(1e-9))),
            ("energy_ratio", Json::Num(ti.energy_j / rs.energy_j.max(1e-9))),
        ]));
    }
    println!(
        "{}",
        render_table(
            &["model", "P(RS) W", "P(Titan) W", "power_x", "time_x", "energy_x"],
            &rows
        )
    );
    let path = write_result("fig6d", &Json::Arr(out))?;
    println!("results -> {}", path.display());
    Ok(())
}
