//! Deterministic fault-injection plane.
//!
//! Titan's pitch is training under hostile edge conditions; this module
//! makes those conditions reproducible. A [`FaultPlan`] is a pure
//! function from `(session, round)` to an optional [`FaultKind`],
//! derived from a seed and per-kind rates: the same plan always injects
//! the same faults at the same points, so a chaos run is as replayable
//! as a clean one. The fleet supervisor ([`crate::coordinator::host`])
//! consumes the plan to crash, slow, brown-out or checkpoint-corrupt
//! individual sessions, and its [`SupervisionPolicy`] decides what the
//! fleet does about it; the federated orchestrator ([`crate::fl`])
//! reuses the same plan as a per-device dropout/straggler model.
//!
//! Two pinned invariants (covered by unit + integration tests):
//!
//! - **Determinism** — same seed + rates ⇒ identical faults, and the
//!   records they produce are byte-identical across runs.
//! - **Zero-rate neutrality** — a plan with all rates zero injects
//!   nothing, and every consumer's zero-plan output is bit-identical to
//!   running without a plan at all.
//!
//! Rates are evaluated with a *single* uniform draw per `(session,
//! round)` cell against cumulative thresholds, so at most one fault
//! fires per cell and each kind's marginal frequency equals its rate.
//! A scripted overlay ([`FaultPlan::script`]) pins exact faults at
//! exact cells for tests; script entries take precedence over the
//! seeded draw.

use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// One injected fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The session's step fails; the supervisor decides recovery.
    Crash,
    /// A failure that clears on retry (the step is re-attempted and
    /// succeeds; models flaky I/O / transient contention).
    Transient,
    /// The round's device clock is inflated by `slowdown` (≥ 1) on both
    /// lanes — a thermally-throttled or contended device.
    Straggler { slowdown: f64 },
    /// The device battery drains an extra `joules` this round without
    /// doing useful work (energy brown-out).
    EnergyBrownout { joules: f64 },
    /// The session's latest on-disk checkpoint is truncated before the
    /// step, exercising the corrupt-snapshot recovery path.
    CorruptCheckpoint,
    /// The newest checkpoint artifact is clipped to a seeded prefix — a
    /// write the power failed mid-way through. Injected via the same
    /// seam as the other corruption kinds
    /// ([`crate::coordinator::vault::inject_corruption`]).
    TornWrite,
    /// One seeded bit of the newest checkpoint artifact flips — silent
    /// media corruption that leaves the JSON superficially intact.
    BitFlip,
    /// The newest checkpoint generation's bytes are replaced with the
    /// previous generation's — a rename that resurrected stale state.
    StaleRename,
}

impl FaultKind {
    /// Stable telemetry/JSON tag.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Transient => "transient",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::EnergyBrownout { .. } => "brownout",
            FaultKind::CorruptCheckpoint => "corrupt_checkpoint",
            FaultKind::TornWrite => "torn_write",
            FaultKind::BitFlip => "bit_flip",
            FaultKind::StaleRename => "stale_rename",
        }
    }

    /// True for the kinds that damage on-disk checkpoint artifacts (all
    /// four share [`crate::coordinator::vault::inject_corruption`]).
    pub fn corrupts_checkpoint(&self) -> bool {
        matches!(
            self,
            FaultKind::CorruptCheckpoint
                | FaultKind::TornWrite
                | FaultKind::BitFlip
                | FaultKind::StaleRename
        )
    }

    /// Parse a CLI fault tag (`--fault-script`): a bare [`name`] tag,
    /// with `straggler:<slowdown>` / `brownout:<joules>` carrying their
    /// parameter.
    ///
    /// [`name`]: FaultKind::name
    pub fn parse(spec: &str) -> Result<FaultKind> {
        let (head, param) = match spec.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (spec, None),
        };
        let value = |what: &str| -> Result<f64> {
            param
                .ok_or_else(|| Error::Config(format!("fault {head:?} needs :{what}")))?
                .parse()
                .map_err(|_| Error::Config(format!("bad {what} in fault spec {spec:?}")))
        };
        let kind = match head {
            "crash" => FaultKind::Crash,
            "transient" => FaultKind::Transient,
            "straggler" => return Ok(FaultKind::Straggler { slowdown: value("slowdown")? }),
            "brownout" => return Ok(FaultKind::EnergyBrownout { joules: value("joules")? }),
            "corrupt_checkpoint" => FaultKind::CorruptCheckpoint,
            "torn_write" => FaultKind::TornWrite,
            "bit_flip" => FaultKind::BitFlip,
            "stale_rename" => FaultKind::StaleRename,
            other => return Err(Error::Config(format!("unknown fault kind {other:?}"))),
        };
        if param.is_some() {
            return Err(Error::Config(format!("fault {head:?} takes no parameter")));
        }
        Ok(kind)
    }

    fn to_json(self) -> Json {
        match self {
            FaultKind::Straggler { slowdown } => Json::obj(vec![
                ("kind", Json::Str("straggler".into())),
                ("slowdown", Json::Num(slowdown)),
            ]),
            FaultKind::EnergyBrownout { joules } => Json::obj(vec![
                ("kind", Json::Str("brownout".into())),
                ("joules", Json::Num(joules)),
            ]),
            other => Json::obj(vec![("kind", Json::Str(other.name().into()))]),
        }
    }

    fn from_json(j: &Json) -> Result<FaultKind> {
        Ok(match j.get("kind")?.as_str()? {
            "crash" => FaultKind::Crash,
            "transient" => FaultKind::Transient,
            "straggler" => FaultKind::Straggler { slowdown: j.get("slowdown")?.as_f64()? },
            "brownout" => FaultKind::EnergyBrownout { joules: j.get("joules")?.as_f64()? },
            "corrupt_checkpoint" => FaultKind::CorruptCheckpoint,
            "torn_write" => FaultKind::TornWrite,
            "bit_flip" => FaultKind::BitFlip,
            "stale_rename" => FaultKind::StaleRename,
            other => return Err(Error::Json(format!("unknown fault kind {other:?}"))),
        })
    }
}

/// Seeded per-session-per-round fault schedule. See the module docs for
/// the determinism/neutrality contract.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the per-cell draws (independent of the training seed).
    pub seed: u64,
    /// Probability a cell crashes.
    pub crash_rate: f64,
    /// Probability a cell fails transiently (clears on retry).
    pub transient_rate: f64,
    /// Probability a cell straggles.
    pub straggler_rate: f64,
    /// Probability a cell brown-outs.
    pub brownout_rate: f64,
    /// Probability a cell corrupts its checkpoint before stepping.
    pub corrupt_rate: f64,
    /// Probability a cell tears the newest checkpoint artifact (seeded
    /// prefix truncation).
    pub torn_rate: f64,
    /// Probability a cell flips one seeded bit of the newest artifact.
    pub bitflip_rate: f64,
    /// Probability a cell replaces the newest generation with the
    /// previous one (stale rename).
    pub stale_rate: f64,
    /// Device-clock inflation of a straggler round (≥ 1).
    pub straggler_slowdown: f64,
    /// Extra joules drained by a brown-out round.
    pub brownout_joules: f64,
    /// Exact-cell overlay; takes precedence over the seeded draw.
    script: Vec<(usize, usize, FaultKind)>,
}

impl FaultPlan {
    /// A plan with the given seed and all rates zero (injects nothing
    /// until rates are set or cells are scripted).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            crash_rate: 0.0,
            transient_rate: 0.0,
            straggler_rate: 0.0,
            brownout_rate: 0.0,
            corrupt_rate: 0.0,
            torn_rate: 0.0,
            bitflip_rate: 0.0,
            stale_rate: 0.0,
            straggler_slowdown: 4.0,
            brownout_joules: 5.0,
            script: Vec::new(),
        }
    }

    /// Pin an exact fault at `(session, round)`. Scripted cells override
    /// the seeded draw; the first script entry for a cell wins.
    pub fn script(mut self, session: usize, round: usize, kind: FaultKind) -> FaultPlan {
        self.script.push((session, round, kind));
        self
    }

    /// True when the plan can never inject anything.
    pub fn is_zero(&self) -> bool {
        self.script.is_empty()
            && self.crash_rate == 0.0
            && self.transient_rate == 0.0
            && self.straggler_rate == 0.0
            && self.brownout_rate == 0.0
            && self.corrupt_rate == 0.0
            && self.torn_rate == 0.0
            && self.bitflip_rate == 0.0
            && self.stale_rate == 0.0
    }

    /// Check rate/parameter sanity; consumers call this once up front so
    /// a bad plan fails before any training work.
    pub fn validate(&self) -> Result<()> {
        let rates = [
            ("crash-rate", self.crash_rate),
            ("transient-rate", self.transient_rate),
            ("straggler-rate", self.straggler_rate),
            ("brownout-rate", self.brownout_rate),
            ("corrupt-rate", self.corrupt_rate),
            ("torn-rate", self.torn_rate),
            ("bitflip-rate", self.bitflip_rate),
            ("stale-rate", self.stale_rate),
        ];
        for (name, r) in rates {
            if !(0.0..=1.0).contains(&r) {
                return Err(Error::Config(format!("fault {name} {r} outside [0, 1]")));
            }
        }
        let sum: f64 = rates.iter().map(|(_, r)| r).sum();
        if sum > 1.0 + 1e-12 {
            return Err(Error::Config(format!("fault rates sum to {sum} > 1")));
        }
        if self.straggler_slowdown < 1.0 {
            return Err(Error::Config(format!(
                "straggler slowdown {} < 1",
                self.straggler_slowdown
            )));
        }
        if self.brownout_joules < 0.0 {
            return Err(Error::Config(format!(
                "brownout joules {} negative",
                self.brownout_joules
            )));
        }
        Ok(())
    }

    /// The fault injected at `(session, round)`, if any. Pure: the same
    /// cell always returns the same answer for the same plan.
    pub fn fault_for(&self, session: usize, round: usize) -> Option<FaultKind> {
        for &(s, r, kind) in &self.script {
            if s == session && r == round {
                return Some(kind);
            }
        }
        let total = self.crash_rate
            + self.transient_rate
            + self.straggler_rate
            + self.brownout_rate
            + self.corrupt_rate
            + self.torn_rate
            + self.bitflip_rate
            + self.stale_rate;
        if total <= 0.0 {
            return None;
        }
        // one independent draw per cell: the stream position of one cell
        // can never perturb another, so fleets of different sizes or
        // schedules see identical per-cell faults
        let mut rng = Xoshiro256::seed_from_u64(self.seed ^ mix_cell(session, round));
        let draw = rng.next_f64();
        let mut acc = self.crash_rate;
        if draw < acc {
            return Some(FaultKind::Crash);
        }
        acc += self.transient_rate;
        if draw < acc {
            return Some(FaultKind::Transient);
        }
        acc += self.straggler_rate;
        if draw < acc {
            return Some(FaultKind::Straggler { slowdown: self.straggler_slowdown });
        }
        acc += self.brownout_rate;
        if draw < acc {
            return Some(FaultKind::EnergyBrownout { joules: self.brownout_joules });
        }
        acc += self.corrupt_rate;
        if draw < acc {
            return Some(FaultKind::CorruptCheckpoint);
        }
        acc += self.torn_rate;
        if draw < acc {
            return Some(FaultKind::TornWrite);
        }
        acc += self.bitflip_rate;
        if draw < acc {
            return Some(FaultKind::BitFlip);
        }
        acc += self.stale_rate;
        if draw < acc {
            return Some(FaultKind::StaleRename);
        }
        None
    }

    /// Seed for the corruption injector's RNG at a cell — the same
    /// `(session, round)` decorrelation as [`FaultPlan::fault_for`],
    /// salted so the injected damage is independent of the draw that
    /// selected the fault.
    pub fn corruption_seed(&self, session: usize, round: usize) -> u64 {
        (self.seed ^ mix_cell(session, round)).rotate_left(17) ^ 0x7E4A_11E5_BADD_15C0
    }

    pub fn to_json(&self) -> Json {
        let script = Json::Arr(
            self.script
                .iter()
                .map(|&(s, r, kind)| {
                    let mut cell = kind.to_json();
                    if let Json::Obj(map) = &mut cell {
                        map.insert("session".into(), Json::Num(s as f64));
                        map.insert("round".into(), Json::Num(r as f64));
                    }
                    cell
                })
                .collect(),
        );
        Json::obj(vec![
            ("seed", Json::Str(format!("{:016x}", self.seed))),
            ("crash_rate", Json::Num(self.crash_rate)),
            ("transient_rate", Json::Num(self.transient_rate)),
            ("straggler_rate", Json::Num(self.straggler_rate)),
            ("brownout_rate", Json::Num(self.brownout_rate)),
            ("corrupt_rate", Json::Num(self.corrupt_rate)),
            ("torn_rate", Json::Num(self.torn_rate)),
            ("bitflip_rate", Json::Num(self.bitflip_rate)),
            ("stale_rate", Json::Num(self.stale_rate)),
            ("straggler_slowdown", Json::Num(self.straggler_slowdown)),
            ("brownout_joules", Json::Num(self.brownout_joules)),
            ("script", script),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let seed = u64::from_str_radix(j.get("seed")?.as_str()?, 16)
            .map_err(|e| Error::Json(format!("bad fault seed: {e}")))?;
        let mut plan = FaultPlan::new(seed);
        plan.crash_rate = j.get("crash_rate")?.as_f64()?;
        plan.transient_rate = j.get("transient_rate")?.as_f64()?;
        plan.straggler_rate = j.get("straggler_rate")?.as_f64()?;
        plan.brownout_rate = j.get("brownout_rate")?.as_f64()?;
        plan.corrupt_rate = j.get("corrupt_rate")?.as_f64()?;
        // the corruption-suite rates postdate the format: absent keys
        // (plans serialized by earlier builds) mean zero
        let rate_or_zero = |key: &str| -> Result<f64> {
            match j.get(key) {
                Err(_) => Ok(0.0),
                Ok(v) => v.as_f64(),
            }
        };
        plan.torn_rate = rate_or_zero("torn_rate")?;
        plan.bitflip_rate = rate_or_zero("bitflip_rate")?;
        plan.stale_rate = rate_or_zero("stale_rate")?;
        plan.straggler_slowdown = j.get("straggler_slowdown")?.as_f64()?;
        plan.brownout_joules = j.get("brownout_joules")?.as_f64()?;
        for cell in j.get("script")?.as_arr()? {
            plan.script.push((
                cell.get("session")?.as_usize()?,
                cell.get("round")?.as_usize()?,
                FaultKind::from_json(cell)?,
            ));
        }
        plan.validate()?;
        Ok(plan)
    }
}

/// Decorrelate the per-cell RNG streams (splitmix-style finalizer over
/// the cell coordinates).
fn mix_cell(session: usize, round: usize) -> u64 {
    let mut h = (session as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((round as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    h ^= h >> 31;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 29)
}

/// What the fleet does when a session's step fails (injected or real).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SupervisionPolicy {
    /// Abort the whole fleet on the first failure — the pre-supervision
    /// behaviour, kept as the default oracle.
    #[default]
    FailFast,
    /// Quarantine the failed session and keep scheduling the rest; the
    /// `FleetRecord` reports a per-session terminal status.
    Isolate,
    /// Rebuild the dead session from its latest valid checkpoint
    /// generation (older generations are fallbacks; from scratch only
    /// when the whole vault is exhausted — same config + seed
    /// reproduces the run), park it for
    /// `backoff_rounds * 2^attempt` fleet ticks (capped at
    /// `backoff_cap` — see [`restart_backoff`]), then re-admit. After
    /// `max_retries` restarts the session is quarantined instead.
    Restart { max_retries: usize, backoff_rounds: usize, backoff_cap: usize },
}

/// Default exponential-backoff ceiling (fleet ticks) for
/// [`SupervisionPolicy::Restart`].
pub const DEFAULT_BACKOFF_CAP: usize = 32;

/// The deterministic restart-backoff schedule: attempt `a` (0-based)
/// parks for `min(backoff_rounds * 2^a, backoff_cap)` ticks. Attempt 0
/// always equals `backoff_rounds` (when under the cap), so
/// single-restart runs are tick-identical to the historical constant
/// backoff; a zero `backoff_rounds` stays zero forever.
pub fn restart_backoff(backoff_rounds: usize, backoff_cap: usize, attempt: usize) -> u64 {
    let mult = 1usize.checked_shl(attempt.min(63) as u32).unwrap_or(usize::MAX);
    backoff_rounds.saturating_mul(mult).min(backoff_cap) as u64
}

impl SupervisionPolicy {
    /// Stable record/CLI tag.
    pub fn name(&self) -> &'static str {
        match self {
            SupervisionPolicy::FailFast => "failfast",
            SupervisionPolicy::Isolate => "isolate",
            SupervisionPolicy::Restart { .. } => "restart",
        }
    }
}

/// Parse a `--supervise` argument. `restart` takes optional
/// `:max_retries:backoff_rounds:backoff_cap` suffixes (default
/// `restart:3:1:32`).
pub fn parse_supervision(s: &str) -> Result<SupervisionPolicy> {
    let mut parts = s.split(':');
    let head = parts.next().unwrap_or("");
    let policy = match head {
        "failfast" => SupervisionPolicy::FailFast,
        "isolate" => SupervisionPolicy::Isolate,
        "restart" => {
            let mut field = |what: &str, default: usize| -> Result<usize> {
                match parts.next() {
                    None => Ok(default),
                    Some(v) => v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad restart {what} {v:?}"))),
                }
            };
            SupervisionPolicy::Restart {
                max_retries: field("max_retries", 3)?,
                backoff_rounds: field("backoff_rounds", 1)?,
                backoff_cap: field("backoff_cap", DEFAULT_BACKOFF_CAP)?,
            }
        }
        other => {
            return Err(Error::Config(format!(
                "unknown supervision policy {other:?} \
                 (failfast|isolate|restart[:retries[:backoff[:cap]]])"
            )))
        }
    };
    if parts.next().is_some() {
        return Err(Error::Config(format!("trailing fields in supervision spec {s:?}")));
    }
    Ok(policy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_plan_injects_nothing() {
        let plan = FaultPlan::new(42);
        assert!(plan.is_zero());
        for s in 0..8 {
            for r in 0..64 {
                assert_eq!(plan.fault_for(s, r), None);
            }
        }
    }

    #[test]
    fn same_seed_same_faults_different_seed_differs() {
        let mut a = FaultPlan::new(7);
        a.crash_rate = 0.3;
        a.straggler_rate = 0.3;
        let b = a.clone();
        let mut c = a.clone();
        c.seed = 8;
        let grid = |p: &FaultPlan| -> Vec<Option<FaultKind>> {
            (0..4).flat_map(|s| (0..32).map(move |r| (s, r))).map(|(s, r)| p.fault_for(s, r)).collect()
        };
        assert_eq!(grid(&a), grid(&b));
        assert_ne!(grid(&a), grid(&c), "different fault seeds agree on a 128-cell grid");
    }

    #[test]
    fn rates_govern_frequency() {
        let mut plan = FaultPlan::new(99);
        plan.crash_rate = 1.0;
        for s in 0..4 {
            for r in 0..16 {
                assert_eq!(plan.fault_for(s, r), Some(FaultKind::Crash));
            }
        }
        // cumulative split: every cell draws exactly one fault when the
        // rates sum to 1, with each kind's share near its rate
        let mut plan = FaultPlan::new(5);
        plan.crash_rate = 0.5;
        plan.straggler_rate = 0.5;
        let mut crashes = 0;
        let n = 1000;
        for cell in 0..n {
            match plan.fault_for(cell % 7, cell) {
                Some(FaultKind::Crash) => crashes += 1,
                Some(FaultKind::Straggler { .. }) => {}
                other => panic!("rates sum to 1 but cell {cell} drew {other:?}"),
            }
        }
        assert!((350..=650).contains(&crashes), "crash share {crashes}/{n}");
    }

    #[test]
    fn scripted_cells_override_seeded_draw() {
        let mut plan = FaultPlan::new(3);
        plan.crash_rate = 1.0;
        let plan = plan.script(1, 2, FaultKind::Straggler { slowdown: 2.0 });
        assert!(!plan.is_zero());
        assert_eq!(plan.fault_for(1, 2), Some(FaultKind::Straggler { slowdown: 2.0 }));
        assert_eq!(plan.fault_for(1, 3), Some(FaultKind::Crash));
        assert_eq!(plan.fault_for(0, 2), Some(FaultKind::Crash));
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut plan = FaultPlan::new(u64::MAX - 3);
        plan.crash_rate = 0.1;
        plan.transient_rate = 0.2;
        plan.straggler_rate = 0.3;
        plan.brownout_rate = 0.05;
        plan.corrupt_rate = 0.01;
        plan.straggler_slowdown = 3.5;
        plan.brownout_joules = 0.1 + 0.2; // no short decimal form
        let plan = plan
            .script(0, 4, FaultKind::CorruptCheckpoint)
            .script(2, 1, FaultKind::EnergyBrownout { joules: 7.25 });
        let text = plan.to_json().to_string_compact();
        let back = FaultPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn validate_rejects_bad_plans() {
        let mut plan = FaultPlan::new(1);
        plan.crash_rate = -0.1;
        assert!(plan.validate().is_err());
        plan.crash_rate = 0.8;
        plan.straggler_rate = 0.5;
        assert!(plan.validate().is_err(), "rates sum > 1");
        plan.straggler_rate = 0.1;
        plan.straggler_slowdown = 0.5;
        assert!(plan.validate().is_err(), "slowdown < 1");
        plan.straggler_slowdown = 2.0;
        plan.validate().unwrap();
    }

    #[test]
    fn supervision_parsing() {
        assert_eq!(parse_supervision("failfast").unwrap(), SupervisionPolicy::FailFast);
        assert_eq!(parse_supervision("isolate").unwrap(), SupervisionPolicy::Isolate);
        assert_eq!(
            parse_supervision("restart").unwrap(),
            SupervisionPolicy::Restart {
                max_retries: 3,
                backoff_rounds: 1,
                backoff_cap: DEFAULT_BACKOFF_CAP,
            }
        );
        assert_eq!(
            parse_supervision("restart:5:0").unwrap(),
            SupervisionPolicy::Restart {
                max_retries: 5,
                backoff_rounds: 0,
                backoff_cap: DEFAULT_BACKOFF_CAP,
            }
        );
        assert_eq!(
            parse_supervision("restart:1:2:3").unwrap(),
            SupervisionPolicy::Restart { max_retries: 1, backoff_rounds: 2, backoff_cap: 3 }
        );
        assert!(parse_supervision("reboot").is_err());
        assert!(parse_supervision("restart:x").is_err());
        assert!(parse_supervision("restart:1:2:x").is_err());
        assert!(parse_supervision("restart:1:2:3:4").is_err());
        assert_eq!(SupervisionPolicy::default(), SupervisionPolicy::FailFast);
    }

    #[test]
    fn restart_backoff_schedule_is_capped_exponential() {
        // attempt 0 always equals the configured backoff, so historical
        // single-restart runs keep their exact tick schedule
        assert_eq!(restart_backoff(1, DEFAULT_BACKOFF_CAP, 0), 1);
        assert_eq!(restart_backoff(2, DEFAULT_BACKOFF_CAP, 0), 2);
        // pinned full schedule for restart:_:2:12
        let sched: Vec<u64> = (0..6).map(|a| restart_backoff(2, 12, a)).collect();
        assert_eq!(sched, vec![2, 4, 8, 12, 12, 12]);
        // zero backoff stays zero forever; huge attempts saturate at the cap
        assert_eq!(restart_backoff(0, DEFAULT_BACKOFF_CAP, 40), 0);
        assert_eq!(restart_backoff(3, 32, 200), 32);
    }

    #[test]
    fn fault_kind_parse_accepts_cli_tags() {
        assert_eq!(FaultKind::parse("crash").unwrap(), FaultKind::Crash);
        assert_eq!(FaultKind::parse("transient").unwrap(), FaultKind::Transient);
        assert_eq!(
            FaultKind::parse("corrupt_checkpoint").unwrap(),
            FaultKind::CorruptCheckpoint
        );
        assert_eq!(FaultKind::parse("torn_write").unwrap(), FaultKind::TornWrite);
        assert_eq!(FaultKind::parse("bit_flip").unwrap(), FaultKind::BitFlip);
        assert_eq!(FaultKind::parse("stale_rename").unwrap(), FaultKind::StaleRename);
        assert_eq!(
            FaultKind::parse("straggler:2.5").unwrap(),
            FaultKind::Straggler { slowdown: 2.5 }
        );
        assert_eq!(
            FaultKind::parse("brownout:0.125").unwrap(),
            FaultKind::EnergyBrownout { joules: 0.125 }
        );
        assert!(FaultKind::parse("straggler").is_err(), "missing slowdown");
        assert!(FaultKind::parse("crash:1").is_err(), "stray parameter");
        assert!(FaultKind::parse("meteor").is_err());
    }

    #[test]
    fn corruption_kinds_roundtrip_and_draw() {
        let mut plan = FaultPlan::new(11);
        plan.torn_rate = 0.2;
        plan.bitflip_rate = 0.1;
        plan.stale_rate = 0.05;
        let plan = plan.script(0, 1, FaultKind::TornWrite).script(1, 1, FaultKind::StaleRename);
        plan.validate().unwrap();
        let text = plan.to_json().to_string_compact();
        let back = FaultPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
        assert_eq!(plan.fault_for(0, 1), Some(FaultKind::TornWrite));
        // all draws come from the corruption family
        let mut seen = std::collections::BTreeSet::new();
        let mut full = plan.clone();
        full.torn_rate = 0.4;
        full.bitflip_rate = 0.4;
        full.stale_rate = 0.2;
        for cell in 0..600 {
            if let Some(k) = full.fault_for(cell % 5, cell) {
                assert!(k.corrupts_checkpoint(), "non-corruption draw {k:?}");
                seen.insert(k.name());
            }
        }
        assert_eq!(
            seen.into_iter().collect::<Vec<_>>(),
            vec!["bit_flip", "stale_rename", "torn_write"]
        );
    }

    #[test]
    fn from_json_defaults_absent_corruption_rates() {
        // plans serialized before the vault work lack the three new rate
        // keys; they must deserialize as zero-rate
        let mut plan = FaultPlan::new(9);
        plan.crash_rate = 0.25;
        let mut j = plan.to_json();
        if let Json::Obj(map) = &mut j {
            for key in ["torn_rate", "bitflip_rate", "stale_rate"] {
                map.remove(key);
            }
        }
        let back = FaultPlan::from_json(&j).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.torn_rate, 0.0);
        assert_eq!(back.bitflip_rate, 0.0);
        assert_eq!(back.stale_rate, 0.0);
    }

    #[test]
    fn corruption_seed_is_cell_deterministic() {
        let plan = FaultPlan::new(21);
        assert_eq!(plan.corruption_seed(0, 4), plan.corruption_seed(0, 4));
        assert_ne!(plan.corruption_seed(0, 4), plan.corruption_seed(0, 5));
        assert_ne!(plan.corruption_seed(0, 4), plan.corruption_seed(1, 4));
        let mut other = plan.clone();
        other.seed = 22;
        assert_ne!(plan.corruption_seed(0, 4), other.corruption_seed(0, 4));
    }
}
