//! Typed model runtime: compile the artifact set on a PJRT CPU client and
//! expose the four operations the coordinator uses. Owns the parameter
//! state for the trainer role.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::data::sample::Sample;
use crate::runtime::artifact::ArtifactSet;
use crate::runtime::literal as lit;
use crate::{Error, Result};

/// Which executables a runtime instance compiles. Pipeline threads each
/// own one runtime with just the executables their role needs (the client
/// is !Send, see runtime module docs), halving redundant compile work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuntimeRole {
    /// train_step + eval (the model-update process).
    Trainer,
    /// features (all depths on demand) + importance (the selection process).
    Selector,
    /// Everything (sequential coordinator, tests, benches).
    Full,
}

/// Evaluation summary over the held-out set.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalReport {
    pub loss: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Compiled model runtime.
pub struct ModelRuntime {
    pub set: ArtifactSet,
    train_exe: Option<Rc<xla::PjRtLoadedExecutable>>,
    eval_exe: Option<Rc<xla::PjRtLoadedExecutable>>,
    importance_exe: Option<Rc<xla::PjRtLoadedExecutable>>,
    probe_exe: Option<Rc<xla::PjRtLoadedExecutable>>,
    /// feature executables by depth k (compiled on demand).
    feature_exes: BTreeMap<usize, Rc<xla::PjRtLoadedExecutable>>,
    /// Current model parameters (trainer role owns the authoritative
    /// copy). `Arc`-backed so the pipeline can hand a snapshot to the
    /// selector with a refcount bump instead of a full `Vec<f32>` clone —
    /// train steps replace the whole `Arc` (fresh vector from PJRT), they
    /// never mutate in place, so shared snapshots stay immutable.
    params: Arc<Vec<f32>>,
    /// Active training batch size (defaults to meta.train_batch; can be
    /// switched to another lowered size, e.g. 25 for the Fig. 2b study).
    train_batch: usize,
}

impl ModelRuntime {
    /// Load artifacts for `model` and compile the executables `role` needs.
    pub fn load(artifacts_dir: &str, model: &str, role: RuntimeRole) -> Result<ModelRuntime> {
        let set = ArtifactSet::discover(artifacts_dir, model)?;
        let params = Arc::new(set.init_params()?);
        let mut rt = ModelRuntime {
            set,
            train_exe: None,
            eval_exe: None,
            importance_exe: None,
            probe_exe: None,
            feature_exes: BTreeMap::new(),
            params,
            train_batch: 0,
        };
        rt.train_batch = rt.set.meta.train_batch;
        match role {
            RuntimeRole::Trainer => {
                rt.train_exe = Some(rt.compile_stem("train_step")?);
                rt.eval_exe = Some(rt.compile_stem("eval")?);
            }
            RuntimeRole::Selector => {
                rt.importance_exe = Some(rt.compile_stem("importance")?);
            }
            RuntimeRole::Full => {
                rt.train_exe = Some(rt.compile_stem("train_step")?);
                rt.eval_exe = Some(rt.compile_stem("eval")?);
                rt.importance_exe = Some(rt.compile_stem("importance")?);
            }
        }
        Ok(rt)
    }

    fn compile_stem(&self, stem: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let path = self.set.hlo_path(stem);
        self.compile_path(&path)
    }

    /// All compilation funnels through the thread-local executable cache
    /// (runtime::cache) — repeated engine construction over the same
    /// artifacts is a map hit, not a PJRT compile.
    fn compile_path(&self, path: &std::path::Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        crate::runtime::cache::compile_cached(path)
    }

    /// Switch to an alternate lowered training batch size (e.g. 25 for
    /// Fig. 2b). The default size uses `train_step.hlo.txt`; others use
    /// `train_step_b<B>.hlo.txt` and must have been lowered by aot.py.
    pub fn select_train_batch(&mut self, batch: usize) -> Result<()> {
        if batch == self.train_batch {
            return Ok(());
        }
        let path = if batch == self.set.meta.train_batch {
            self.set.hlo_path("train_step")
        } else {
            self.set.hlo_path(&format!("train_step_b{batch}"))
        };
        if !path.exists() {
            return Err(Error::Artifact(format!(
                "no train_step artifact for batch {batch} ({})",
                path.display()
            )));
        }
        self.train_exe = Some(self.compile_path(&path)?);
        self.train_batch = batch;
        Ok(())
    }

    /// Active training batch size.
    pub fn train_batch(&self) -> usize {
        self.train_batch
    }

    /// Ensure the features executable for depth k exists (compiles lazily).
    pub fn ensure_features(&mut self, k: usize) -> Result<()> {
        let k = k.clamp(1, self.set.meta.num_blocks());
        if !self.feature_exes.contains_key(&k) {
            let path = self.set.features_path(k);
            if !path.exists() {
                return Err(Error::Artifact(format!("{} missing", path.display())));
            }
            let exe = self.compile_path(&path)?;
            self.feature_exes.insert(k, exe);
        }
        Ok(())
    }

    // ---- parameter state ---------------------------------------------------

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Zero-copy snapshot of the current parameters (refcount bump only).
    /// This is what crosses the pipeline's parameter-sync slot.
    pub fn share_params(&self) -> Arc<Vec<f32>> {
        Arc::clone(&self.params)
    }

    pub fn set_params(&mut self, p: Vec<f32>) -> Result<()> {
        self.set_params_shared(Arc::new(p))
    }

    /// Adopt a shared parameter snapshot without copying the payload.
    pub fn set_params_shared(&mut self, p: Arc<Vec<f32>>) -> Result<()> {
        if p.len() != self.set.meta.param_count {
            return Err(Error::Other(format!(
                "set_params: {} != param_count {}",
                p.len(),
                self.set.meta.param_count
            )));
        }
        self.params = p;
        Ok(())
    }

    pub fn reset_params(&mut self) -> Result<()> {
        self.params = Arc::new(self.set.init_params()?);
        Ok(())
    }

    /// Owned copy of the current parameters for serialization (session
    /// checkpoints). One `Vec` clone — the export path, not a hot path.
    pub fn export_params(&self) -> Vec<f32> {
        self.params.as_ref().clone()
    }

    /// Adopt parameters from a checkpoint. Length-checked alias of
    /// [`ModelRuntime::set_params`] — the import half of the
    /// export/import pair, kept explicit so resume call sites read as
    /// state restoration rather than ad-hoc parameter poking.
    pub fn import_params(&mut self, p: Vec<f32>) -> Result<()> {
        self.set_params(p)
    }

    // ---- operations ----------------------------------------------------------

    /// One SGD step on a batch of samples; updates internal params and
    /// returns the batch loss. Pads short batches by repeating the last
    /// sample with ZERO weight (the real samples are re-scaled so the
    /// effective batch mean is preserved). Unit weights reproduce the
    /// plain mini-batch mean.
    pub fn train_step(&mut self, samples: &[&Sample], lr: f32) -> Result<f32> {
        let ones = vec![1.0f32; samples.len()];
        self.train_step_weighted(samples, &ones, lr)
    }

    /// Weighted SGD step (the paper's unbiased estimator — Appendix A.2).
    pub fn train_step_weighted(
        &mut self,
        samples: &[&Sample],
        weights: &[f32],
        lr: f32,
    ) -> Result<f32> {
        if weights.len() != samples.len() {
            return Err(Error::Other(format!(
                "weights {} != samples {}",
                weights.len(),
                samples.len()
            )));
        }
        let m = &self.set.meta;
        let b = self.train_batch;
        let x = lit::batch_inputs(samples, b, m.input_dim)?;
        let y = lit::batch_onehot(samples, b, m.num_classes)?;
        // pad weights with zeros; rescale the valid entries so the batch
        // mean over b rows equals the mean over the valid rows
        let valid = samples.len().min(b);
        let scale = b as f32 / valid as f32;
        let mut w = vec![0.0f32; b];
        for i in 0..valid {
            w[i] = weights[i] * scale;
        }
        let exe = self
            .train_exe
            .as_ref()
            .ok_or_else(|| Error::Other("train_step not compiled for this role".into()))?;
        let args = [
            lit::literal_1d(&self.params),
            lit::literal_2d(&x, b, m.input_dim)?,
            lit::literal_2d(&y, b, m.num_classes)?,
            lit::literal_1d(&w),
            lit::literal_scalar(lr),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            return Err(Error::Other(format!("train_step returned {} outputs", outs.len())));
        }
        self.params = Arc::new(lit::to_f32s(&outs[0])?);
        let loss = outs[1].to_vec::<f32>()?[0];
        Ok(loss)
    }

    /// Shallow features (depth k) for up to `filter_chunk` samples.
    /// Returns (features row-major, rows_valid).
    pub fn features(&mut self, samples: &[&Sample], k: usize) -> Result<(Vec<f32>, usize)> {
        let m = self.set.meta.clone();
        let valid = samples.len().min(m.filter_chunk);
        self.ensure_features(k)?;
        let x = lit::batch_inputs(&samples[..valid], m.filter_chunk, m.input_dim)?;
        let exe = &self.feature_exes[&k.clamp(1, m.num_blocks())];
        let args = [
            lit::literal_1d(&self.params),
            lit::literal_2d(&x, m.filter_chunk, m.input_dim)?,
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let feats = lit::to_f32s(&result.to_tuple1()?)?;
        Ok((feats, valid))
    }

    /// Importance of up to `cand_max` candidates: per-sample last-layer
    /// gradient norms and the pairwise gradient Gram matrix K.
    /// Rows past `samples.len()` are masked out (zero norms, zero K rows).
    pub fn importance(&self, samples: &[&Sample]) -> Result<ImportanceOut> {
        let m = &self.set.meta;
        let valid = samples.len().min(m.cand_max);
        let x = lit::batch_inputs(&samples[..valid], m.cand_max, m.input_dim)?;
        let y = lit::batch_onehot(&samples[..valid], m.cand_max, m.num_classes)?;
        let mask = lit::mask(m.cand_max, valid);
        let exe = self
            .importance_exe
            .as_ref()
            .ok_or_else(|| Error::Other("importance not compiled for this role".into()))?;
        let args = [
            lit::literal_1d(&self.params),
            lit::literal_2d(&x, m.cand_max, m.input_dim)?,
            lit::literal_2d(&y, m.cand_max, m.num_classes)?,
            lit::literal_1d(&mask),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            return Err(Error::Other(format!("importance returned {} outputs", outs.len())));
        }
        let norms = lit::to_f32s(&outs[0])?;
        let k = lit::to_f32s(&outs[1])?;
        Ok(ImportanceOut {
            norms: norms[..valid].to_vec(),
            k,
            n_total: m.cand_max,
            valid,
        })
    }

    /// Per-candidate probe scores (loss + entropy) for the heuristic
    /// baselines. Compiled lazily — only the heuristic methods pay for it.
    pub fn probe(&mut self, samples: &[&Sample]) -> Result<crate::selection::ProbeOut> {
        let m = self.set.meta.clone();
        let valid = samples.len().min(m.cand_max);
        if self.probe_exe.is_none() {
            self.probe_exe = Some(self.compile_stem("probe")?);
        }
        let x = lit::batch_inputs(&samples[..valid], m.cand_max, m.input_dim)?;
        let y = lit::batch_onehot(&samples[..valid], m.cand_max, m.num_classes)?;
        let mask = lit::mask(m.cand_max, valid);
        // detlint: allow(R001) invariant: populated by the is_none() guard above
        let exe = self.probe_exe.as_ref().unwrap();
        let args = [
            lit::literal_1d(&self.params),
            lit::literal_2d(&x, m.cand_max, m.input_dim)?,
            lit::literal_2d(&y, m.cand_max, m.num_classes)?,
            lit::literal_1d(&mask),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            return Err(Error::Other(format!("probe returned {} outputs", outs.len())));
        }
        let loss = lit::to_f32s(&outs[0])?;
        let entropy = lit::to_f32s(&outs[1])?;
        Ok(crate::selection::ProbeOut {
            loss: loss[..valid].to_vec(),
            entropy: entropy[..valid].to_vec(),
        })
    }

    /// Evaluate on a test set (chunked to the artifact's eval_chunk).
    /// Remainder samples that don't fill a chunk are dropped — keep
    /// `test.len()` a multiple of `eval_chunk` for exact counts.
    pub fn evaluate(&self, test: &[Sample]) -> Result<EvalReport> {
        let m = &self.set.meta;
        let exe = self
            .eval_exe
            .as_ref()
            .ok_or_else(|| Error::Other("eval not compiled for this role".into()))?;
        let chunks = test.len() / m.eval_chunk;
        if chunks == 0 {
            return Err(Error::Other(format!(
                "test set {} smaller than eval_chunk {}",
                test.len(),
                m.eval_chunk
            )));
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for ci in 0..chunks {
            let chunk: Vec<&Sample> =
                test[ci * m.eval_chunk..(ci + 1) * m.eval_chunk].iter().collect();
            let x = lit::batch_inputs(&chunk, m.eval_chunk, m.input_dim)?;
            let y = lit::batch_onehot(&chunk, m.eval_chunk, m.num_classes)?;
            let args = [
                lit::literal_1d(&self.params),
                lit::literal_2d(&x, m.eval_chunk, m.input_dim)?,
                lit::literal_2d(&y, m.eval_chunk, m.num_classes)?,
            ];
            let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let outs = result.to_tuple()?;
            // detlint: allow(D004) chunk-ordered eval reduction, pinned across backends by the
            // record differ (same chunking on every host-thread count)
            loss_sum += outs[0].to_vec::<f32>()?[0] as f64;
            // detlint: allow(D004) see above: chunk-ordered eval reduction
            correct += outs[1].to_vec::<f32>()?[0] as f64;
        }
        let n = chunks * m.eval_chunk;
        Ok(EvalReport {
            loss: loss_sum / n as f64,
            accuracy: correct / n as f64,
            n,
        })
    }
}

/// Output of the importance executable.
#[derive(Clone, Debug)]
pub struct ImportanceOut {
    /// ‖g_i‖ for the `valid` candidates (padding rows stripped).
    pub norms: Vec<f32>,
    /// Full K matrix [n_total * n_total] row-major (padding rows are zero).
    pub k: Vec<f32>,
    pub n_total: usize,
    pub valid: usize,
}

impl ImportanceOut {
    /// K[i, j] accessor over the valid region.
    pub fn k_at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.valid && j < self.valid);
        self.k[i * self.n_total + j]
    }

    /// All per-class Gram aggregates in **one sweep over K's upper
    /// triangle** — O(n²/2) contiguous row reads instead of the O(C·n²)
    /// per-class `k_at` loops it replaces. For every class this yields the
    /// diagonal sum, the norm sum, and the full block sums
    /// `Σ_{i∈a, j∈b} K_ij` for every class pair (using K's symmetry, so
    /// the within-class block is `K_ii + 2·Σ_{i<j} K_ij`).
    ///
    /// Single-threaded alias of [`ImportanceOut::gram_class_sums_threaded`].
    /// Below [`GRAM_BLOCK_MIN_ROWS`] rows — every pinned run configuration —
    /// the sweep is one accumulation chain whose terms arrive in exactly
    /// the order the old nested per-class loops produced them (ascending
    /// i, then ascending j within the row), so downstream summaries stay
    /// bit-identical to the historical path.
    pub fn gram_class_sums(&self, labels: &[u32], num_classes: usize) -> GramClassSums {
        self.gram_class_sums_threaded(labels, num_classes, 1)
    }

    /// The triangle sweep, parallelized across `threads` scoped workers.
    ///
    /// Rows are partitioned into contiguous blocks balanced by triangle
    /// **area** (row i covers `n − i` entries, so equal row counts would
    /// load the first worker quadratically harder). Each block accumulates
    /// its own per-class partials — its `diag` rows are disjoint slices
    /// written in place — and the partials merge in **block order**.
    ///
    /// Determinism contract: the block partition is a function of `n`
    /// only ([`gram_block_ranges`]) and the merge order is fixed, so the
    /// result is **bit-identical for every `threads` value** (workers only
    /// decide *who* sweeps a block, never how sums associate) — the
    /// `gram_sums_bit_identical_across_thread_counts` pin. `threads = 1`
    /// sweeps the blocks on the caller thread; no threads are spawned.
    pub fn gram_class_sums_threaded(
        &self,
        labels: &[u32],
        num_classes: usize,
        threads: usize,
    ) -> GramClassSums {
        let n = self.valid.min(labels.len());
        let c = num_classes;
        let mut indices: Vec<Vec<usize>> = vec![Vec::new(); c];
        let mut sum_norm = vec![0.0f64; c];
        for (i, &y) in labels.iter().enumerate().take(n) {
            indices[y as usize].push(i);
            // detlint: allow(D004) index-ordered class reduction; pinned bit-identical across
            // thread counts by gram_sums_bit_identical_across_thread_counts
            sum_norm[y as usize] += self.norms[i] as f64;
        }

        let ranges = gram_block_ranges(n);
        let mut diag = vec![0.0f64; n];
        // carve diag into one contiguous output slice per block
        let mut diag_slices: Vec<&mut [f64]> = Vec::with_capacity(ranges.len());
        let mut rest: &mut [f64] = &mut diag;
        for r in &ranges {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
            diag_slices.push(head);
            rest = tail;
        }
        debug_assert!(rest.is_empty(), "block ranges must cover the diagonal");

        let workers = threads.max(1).min(ranges.len());
        let mut partials: Vec<Option<GramBlockSums>> = (0..ranges.len()).map(|_| None).collect();
        if workers <= 1 {
            for ((range, out), slot) in
                ranges.iter().zip(diag_slices).zip(partials.iter_mut())
            {
                *slot = Some(self.sweep_rows(labels, n, c, range.clone(), out));
            }
        } else {
            // deal blocks round-robin across workers; the dealing can
            // never affect results — partials merge by block index below
            let mut per_worker: Vec<Vec<(usize, std::ops::Range<usize>, &mut [f64])>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (b, (range, out)) in ranges.iter().zip(diag_slices).enumerate() {
                per_worker[b % workers].push((b, range.clone(), out));
            }
            let results: Vec<Vec<(usize, GramBlockSums)>> = std::thread::scope(|s| {
                let handles: Vec<_> = per_worker
                    .into_iter()
                    .map(|tasks| {
                        s.spawn(move || {
                            tasks
                                .into_iter()
                                .map(|(b, range, out)| {
                                    (b, self.sweep_rows(labels, n, c, range, out))
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // detlint: allow(R001) re-raising a worker panic on the caller is the intent
                    .map(|h| h.join().expect("gram sweep worker panicked"))
                    .collect()
            });
            for worker in results {
                for (b, p) in worker {
                    partials[b] = Some(p);
                }
            }
        }

        // fixed-order merge; a lone block moves straight through so the
        // small-n path adds zero arithmetic over the historical chain
        // detlint: allow(R001) invariant: both branches above fill every partials slot
        let mut parts = partials.into_iter().map(|p| p.expect("every block swept"));
        let (sum_diag, block) = if ranges.len() == 1 {
            // detlint: allow(R001) invariant: ranges.len() == 1 guarantees one part
            let p = parts.next().expect("one block");
            (p.sum_diag, p.block)
        } else {
            let mut sum_diag = vec![0.0f64; c];
            let mut block = vec![0.0f64; c * c];
            for p in parts {
                for (d, s) in sum_diag.iter_mut().zip(&p.sum_diag) {
                    *d += s;
                }
                for (d, s) in block.iter_mut().zip(&p.block) {
                    *d += s;
                }
            }
            (sum_diag, block)
        };
        GramClassSums {
            num_classes: c,
            indices,
            sum_norm,
            sum_diag,
            block,
            diag,
        }
    }

    /// Sweep one contiguous row block of the upper triangle. The inner
    /// loop is the historical single-pass body verbatim; `diag_out` is
    /// this block's slice of the global diagonal.
    fn sweep_rows(
        &self,
        labels: &[u32],
        n: usize,
        c: usize,
        rows: std::ops::Range<usize>,
        diag_out: &mut [f64],
    ) -> GramBlockSums {
        debug_assert_eq!(diag_out.len(), rows.len());
        let mut sum_diag = vec![0.0f64; c];
        let mut block = vec![0.0f64; c * c];
        let start = rows.start;
        for i in rows {
            let yi = labels[i] as usize;
            let row = &self.k[i * self.n_total..i * self.n_total + n];
            let d = row[i] as f64;
            diag_out[i - start] = d;
            // detlint: allow(D004) historical single-pass triangle body, verbatim; the block
            // partition + fixed merge order keep it bit-identical across thread counts
            sum_diag[yi] += d;
            // detlint: allow(D004) see above: pinned triangle-sweep order
            block[yi * c + yi] += d;
            for (j, &kij) in row.iter().enumerate().skip(i + 1) {
                let yj = labels[j] as usize;
                let v = kij as f64;
                if yi == yj {
                    // detlint: allow(D004) see above: pinned triangle-sweep order
                    block[yi * c + yi] += 2.0 * v;
                } else {
                    // detlint: allow(D004) see above: pinned triangle-sweep order
                    block[yi * c + yj] += v;
                    // detlint: allow(D004) see above: pinned triangle-sweep order
                    block[yj * c + yi] += v;
                }
            }
        }
        GramBlockSums { sum_diag, block }
    }
}

/// Per-block partial accumulators of the triangle sweep (the block's
/// `diag` rows are written in place into disjoint slices instead).
struct GramBlockSums {
    sum_diag: Vec<f64>,
    block: Vec<f64>,
}

/// Rows below this sweep as a single accumulation block: the blocked
/// merge rounds differently than one serial chain at the ULP level, and
/// every pinned run keeps n ≤ cand_max ≪ this threshold — so small-n
/// results are bit-identical to the historical (pre-blocking) path.
const GRAM_BLOCK_MIN_ROWS: usize = 1024;

/// Upper bound on accumulation blocks (≥ any worker count worth having
/// on the row sweep; also caps the merge cost at O(blocks · C²)).
const GRAM_MAX_BLOCKS: usize = 16;

/// Contiguous row ranges balanced by upper-triangle area. **A function
/// of n only** — never of the worker count — which is what makes
/// [`ImportanceOut::gram_class_sums_threaded`] bit-identical across
/// `select_threads` values. Returns exactly one range below
/// [`GRAM_BLOCK_MIN_ROWS`].
fn gram_block_ranges(n: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return vec![0..0];
    }
    let k = if n < GRAM_BLOCK_MIN_ROWS {
        1
    } else {
        (n / (GRAM_BLOCK_MIN_ROWS / 2)).min(GRAM_MAX_BLOCKS)
    };
    if k <= 1 {
        return vec![0..n];
    }
    let total = n as u64 * (n as u64 + 1) / 2;
    let mut ranges = Vec::with_capacity(k);
    let mut start = 0usize;
    let mut acc = 0u64;
    let mut cut = 1u64;
    for i in 0..n {
        acc += (n - i) as u64;
        // cut when the running area crosses cut/k of the total
        if acc * k as u64 >= total * cut {
            ranges.push(start..i + 1);
            start = i + 1;
            cut += 1;
        }
    }
    debug_assert_eq!(start, n, "area cuts must cover every row");
    ranges
}

/// Per-class aggregates of one `ImportanceOut`, produced by
/// [`ImportanceOut::gram_class_sums`] in a single triangle sweep.
/// `selection::cis::class_summaries` consumes the within-class blocks
/// (and forwards the diagonal to the Theorem-2 variance analysis via
/// `ClassSummary::diag`); the cross-class blocks cost two extra adds per
/// off-class pair in the same sweep and are exposed for inter-class
/// analyses (subset bias, class-confusion geometry) so those never need a
/// second O(n²) pass over K. Consumers divide by counts themselves.
#[derive(Clone, Debug)]
pub struct GramClassSums {
    pub num_classes: usize,
    /// Candidate indices per class (ascending within each class).
    pub indices: Vec<Vec<usize>>,
    /// `Σ norms[i]` per class.
    pub sum_norm: Vec<f64>,
    /// `Σ K_ii` per class.
    pub sum_diag: Vec<f64>,
    /// Full class-pair block sums `Σ_{i∈a, j∈b} K_ij`, row-major `[a*C+b]`.
    /// Symmetric; the diagonal entries are the within-class full sums.
    pub block: Vec<f64>,
    /// `K_ii` per valid candidate (global candidate order).
    pub diag: Vec<f64>,
}

impl GramClassSums {
    /// Within-class full block sum `Σ_{i,j∈y} K_ij`.
    pub fn within(&self, y: usize) -> f64 {
        self.block[y * self.num_classes + y]
    }

    /// Cross-class block sum `Σ_{i∈a, j∈b} K_ij`.
    pub fn between(&self, a: usize, b: usize) -> f64 {
        self.block[a * self.num_classes + b]
    }
}

#[cfg(test)]
mod tests {
    //! Golden-numerics integration tests: execute the compiled artifacts on
    //! the deterministic inputs from `aot.det_input` and compare with
    //! golden.json. These are THE cross-language correctness signal.
    use super::*;

    fn have(model: &str) -> bool {
        std::path::Path::new("artifacts").join(model).join("meta.json").exists()
    }

    /// Reimplementation of aot.det_input: x[i] = sin(0.1 * (i+1)) as f32.
    fn det_input(n: usize, d: usize) -> Vec<Sample> {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let x: Vec<f32> = (0..d)
                .map(|j| ((0.1 * ((i * d + j) as f64 + 1.0)).sin()) as f32)
                .collect();
            out.push(Sample::new(i as u64, 0, x));
        }
        out
    }

    fn det_labels(mut samples: Vec<Sample>, c: usize) -> Vec<Sample> {
        for (i, s) in samples.iter_mut().enumerate() {
            s.label = (i % c) as u32;
            s.clean_label = s.label;
        }
        samples
    }

    #[test]
    fn golden_train_step_matches() {
        if !have("mlp") {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Trainer).unwrap();
        let golden = rt.set.golden().unwrap();
        let m = rt.set.meta.clone();
        let samples = det_labels(det_input(m.train_batch, m.input_dim), m.num_classes);
        let refs: Vec<&Sample> = samples.iter().collect();
        let lr = golden.get("lr").unwrap().as_f64().unwrap() as f32;
        let loss = rt.train_step(&refs, lr).unwrap();
        let want = golden.get("loss_step0").unwrap().as_f64().unwrap();
        assert!((loss as f64 - want).abs() < 1e-3, "loss {loss} vs golden {want}");
        let l2: f64 = rt.params().iter().map(|&p| (p as f64) * (p as f64)).sum::<f64>().sqrt();
        let want_l2 = golden.get("params_l2_after_step").unwrap().as_f64().unwrap();
        assert!((l2 - want_l2).abs() < 1e-2, "l2 {l2} vs golden {want_l2}");
    }

    #[test]
    fn golden_importance_matches() {
        if !have("mlp") {
            return;
        }
        let rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Selector).unwrap();
        let golden = rt.set.golden().unwrap();
        let m = rt.set.meta.clone();
        let valid = golden.get("mask_valid").unwrap().as_usize().unwrap();
        let samples = det_labels(det_input(m.cand_max, m.input_dim), m.num_classes);
        let refs: Vec<&Sample> = samples.iter().take(valid).collect();
        let out = rt.importance(&refs).unwrap();
        assert_eq!(out.valid, valid);
        let want_norms = golden.get("norms_head").unwrap().f64_list().unwrap();
        for (i, w) in want_norms.iter().enumerate() {
            assert!(
                (out.norms[i] as f64 - w).abs() < 1e-3,
                "norm[{i}] {} vs {w}",
                out.norms[i]
            );
        }
        let ksum: f64 = out.k.iter().map(|&v| v as f64).sum();
        let want_ksum = golden.get("k_sum").unwrap().as_f64().unwrap();
        assert!(
            (ksum - want_ksum).abs() < 1e-2 * want_ksum.abs().max(1.0),
            "k_sum {ksum} vs {want_ksum}"
        );
        // masked region must be zero
        for i in valid..out.n_total {
            for j in 0..out.n_total {
                assert_eq!(out.k[i * out.n_total + j], 0.0);
            }
        }
    }

    #[test]
    fn golden_eval_and_features_match() {
        if !have("mlp") {
            return;
        }
        let mut rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Full).unwrap();
        let golden = rt.set.golden().unwrap();
        let m = rt.set.meta.clone();
        // eval
        let samples = det_labels(det_input(m.eval_chunk, m.input_dim), m.num_classes);
        let rep = rt.evaluate(&samples).unwrap();
        let want_loss = golden.get("eval_loss_sum").unwrap().as_f64().unwrap() / m.eval_chunk as f64;
        let want_corr = golden.get("eval_correct").unwrap().as_f64().unwrap();
        assert!((rep.loss - want_loss).abs() < 1e-3, "{} vs {want_loss}", rep.loss);
        assert!(
            (rep.accuracy * rep.n as f64 - want_corr).abs() < 0.5,
            "{} vs {want_corr}",
            rep.accuracy * rep.n as f64
        );
        // features depth 1
        let fsamples = det_input(m.filter_chunk, m.input_dim);
        let refs: Vec<&Sample> = fsamples.iter().collect();
        let (feats, valid) = rt.features(&refs, 1).unwrap();
        assert_eq!(valid, m.filter_chunk);
        assert_eq!(feats.len(), m.filter_chunk * m.feature_dim(1));
        let fsum: f64 = feats.iter().map(|&v| v as f64).sum();
        let want_fsum = golden.get("feats_b1_sum").unwrap().as_f64().unwrap();
        assert!(
            (fsum - want_fsum).abs() < 1e-2 * want_fsum.abs().max(1.0),
            "{fsum} vs {want_fsum}"
        );
        let head = golden.get("feats_b1_head").unwrap().f64_list().unwrap();
        for (i, w) in head.iter().enumerate() {
            assert!((feats[i] as f64 - w).abs() < 1e-4);
        }
    }

    #[test]
    fn golden_probe_matches() {
        if !have("mlp") {
            return;
        }
        let mut rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Selector).unwrap();
        let golden = rt.set.golden().unwrap();
        let m = rt.set.meta.clone();
        let valid = golden.get("mask_valid").unwrap().as_usize().unwrap();
        let samples = det_labels(det_input(m.cand_max, m.input_dim), m.num_classes);
        let refs: Vec<&Sample> = samples.iter().take(valid).collect();
        let probe = rt.probe(&refs).unwrap();
        let want_loss = golden.get("probe_loss_head").unwrap().f64_list().unwrap();
        let want_ent = golden.get("probe_entropy_head").unwrap().f64_list().unwrap();
        for i in 0..want_loss.len() {
            assert!((probe.loss[i] as f64 - want_loss[i]).abs() < 1e-3);
            assert!((probe.entropy[i] as f64 - want_ent[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn role_gating() {
        if !have("mlp") {
            return;
        }
        let rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Selector).unwrap();
        let m = rt.set.meta.clone();
        let samples = det_input(2, m.input_dim);
        let refs: Vec<&Sample> = samples.iter().collect();
        // selector role must not have train/eval
        let mut rt2 = rt;
        assert!(rt2.train_step(&refs, 0.1).is_err());
        assert!(rt2.evaluate(&samples).is_err());
    }

    #[test]
    fn gram_class_sums_hand_computed() {
        // 3 candidates, classes [0, 1, 0], K from 1-D "gradients" [1, 2, 3]
        // (so K_ij = g_i * g_j), one padding row to exercise n_total > valid
        let g = [1.0f32, 2.0, 3.0];
        let n_total = 4;
        let mut k = vec![0.0f32; n_total * n_total];
        for i in 0..3 {
            for j in 0..3 {
                k[i * n_total + j] = g[i] * g[j];
            }
        }
        let imp = ImportanceOut {
            norms: g.to_vec(),
            k,
            n_total,
            valid: 3,
        };
        let labels = [0u32, 1, 0];
        let sums = imp.gram_class_sums(&labels, 2);
        assert_eq!(sums.indices, vec![vec![0, 2], vec![1]]);
        assert_eq!(sums.diag, vec![1.0, 4.0, 9.0]);
        assert_eq!(sums.sum_diag, vec![10.0, 4.0]); // 1+9, 4
        assert_eq!(sums.sum_norm, vec![4.0, 2.0]); // 1+3, 2
        // within class 0: 1 + 9 + 2*3 = 16 = (1+3)^2; within class 1: 4
        assert_eq!(sums.within(0), 16.0);
        assert_eq!(sums.within(1), 4.0);
        // between: (1+3)*2 = 8, symmetric
        assert_eq!(sums.between(0, 1), 8.0);
        assert_eq!(sums.between(1, 0), 8.0);
    }

    #[test]
    fn gram_block_ranges_cover_and_balance() {
        for n in [0usize, 1, 5, 1023, 1024, 2048, 4096, 8192, 100_000] {
            let ranges = super::gram_block_ranges(n);
            // contiguous disjoint cover of 0..n
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "n={n}");
                assert!(r.end >= r.start, "n={n}");
                next = r.end;
            }
            assert_eq!(next, n, "n={n}");
            if n < super::GRAM_BLOCK_MIN_ROWS {
                assert_eq!(ranges.len(), 1, "small n must stay single-chain");
            } else {
                assert!(ranges.len() > 1, "n={n}");
                assert!(ranges.len() <= super::GRAM_MAX_BLOCKS);
                // area balance: no block carries more than 2x its share
                let area = |r: &std::ops::Range<usize>| -> u64 {
                    r.clone().map(|i| (n - i) as u64).sum()
                };
                let total: u64 = n as u64 * (n as u64 + 1) / 2;
                let fair = total / ranges.len() as u64;
                for r in &ranges {
                    assert!(area(r) <= 2 * fair, "n={n} block {r:?} area {}", area(r));
                }
            }
        }
    }

    /// Synthetic low-rank K at blocking scale (n ≥ GRAM_BLOCK_MIN_ROWS).
    fn synth_blocked_importance(n: usize) -> ImportanceOut {
        let grads: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                let th = i as f64 * 0.37;
                let r = 0.5 + (i % 7) as f64 * 0.25;
                (r * th.cos(), r * th.sin())
            })
            .collect();
        let mut k = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = (grads[i].0 * grads[j].0 + grads[i].1 * grads[j].1) as f32;
            }
        }
        let norms: Vec<f32> = grads
            .iter()
            .map(|g| ((g.0 * g.0 + g.1 * g.1) as f32).sqrt())
            .collect();
        ImportanceOut { norms, k, n_total: n, valid: n }
    }

    /// THE cross-`select_threads` determinism pin: 1, 4 and 16 workers
    /// must produce bit-identical sums at a size where the sweep really
    /// splits into multiple blocks (n = 2048 → 4 area-balanced blocks).
    #[test]
    fn gram_sums_bit_identical_across_thread_counts() {
        let n = 2048usize;
        let classes = 10usize;
        let imp = synth_blocked_importance(n);
        let labels: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
        let base = imp.gram_class_sums_threaded(&labels, classes, 1);
        for threads in [2usize, 4, 16] {
            let par = imp.gram_class_sums_threaded(&labels, classes, threads);
            assert_eq!(base.indices, par.indices, "t={threads}");
            for (name, a, b) in [
                ("sum_norm", &base.sum_norm, &par.sum_norm),
                ("sum_diag", &base.sum_diag, &par.sum_diag),
                ("block", &base.block, &par.block),
                ("diag", &base.diag, &par.diag),
            ] {
                assert_eq!(a.len(), b.len(), "t={threads} {name}");
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "t={threads} {name}[{i}]: {x} vs {y}"
                    );
                }
            }
        }
    }

    /// The blocked sweep must still compute the right numbers: compare
    /// against a naive per-class double loop at blocking scale.
    #[test]
    fn gram_blocked_sums_match_naive_reference() {
        let n = 1024usize; // exactly at the threshold -> 2 blocks
        let classes = 4usize;
        let imp = synth_blocked_importance(n);
        let labels: Vec<u32> = (0..n).map(|i| ((i * 7) % classes) as u32).collect();
        let sums = imp.gram_class_sums_threaded(&labels, classes, 4);
        let mut want_within = vec![0.0f64; classes];
        let mut want_diag = vec![0.0f64; classes];
        for i in 0..n {
            let yi = labels[i] as usize;
            want_diag[yi] += imp.k_at(i, i) as f64;
            for j in 0..n {
                if labels[j] as usize == yi {
                    want_within[yi] += imp.k_at(i, j) as f64;
                }
            }
        }
        for y in 0..classes {
            assert!(
                (sums.within(y) - want_within[y]).abs()
                    <= 1e-9 * want_within[y].abs().max(1.0),
                "class {y}: {} vs {}",
                sums.within(y),
                want_within[y]
            );
            assert!(
                (sums.sum_diag[y] - want_diag[y]).abs() <= 1e-9 * want_diag[y].abs().max(1.0),
                "class {y} diag"
            );
        }
    }

    #[test]
    fn set_params_roundtrip() {
        if !have("mlp") {
            return;
        }
        let mut rt = ModelRuntime::load("artifacts", "mlp", RuntimeRole::Selector).unwrap();
        let n = rt.set.meta.param_count;
        let p: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.1).collect();
        rt.set_params(p.clone()).unwrap();
        assert_eq!(rt.params(), &p[..]);
        assert!(rt.set_params(vec![0.0; 3]).is_err());
        // checkpoint export/import round-trips through owned vectors
        let exported = rt.export_params();
        assert_eq!(exported, p);
        rt.reset_params().unwrap();
        rt.import_params(exported).unwrap();
        assert_eq!(rt.params(), &p[..]);
        assert!(rt.import_params(vec![0.0; 3]).is_err());
        rt.reset_params().unwrap();
        assert_ne!(rt.params(), &p[..]);
    }

    /// Same golden check for every other built variant's importance path
    /// (cheaper than per-variant train checks, still catches contract rot).
    #[test]
    fn golden_all_variants_importance() {
        let models = crate::runtime::artifact::ArtifactSet::list_models("artifacts");
        for model in models.iter().filter(|m| m.as_str() != "mlp") {
            let rt = match ModelRuntime::load("artifacts", model, RuntimeRole::Selector) {
                Ok(rt) => rt,
                Err(e) => {
                    eprintln!("skipping {model}: {e}");
                    continue;
                }
            };
            let golden = rt.set.golden().unwrap();
            let m = rt.set.meta.clone();
            let valid = golden.get("mask_valid").unwrap().as_usize().unwrap();
            let samples = det_labels(det_input(m.cand_max, m.input_dim), m.num_classes);
            let refs: Vec<&Sample> = samples.iter().take(valid).collect();
            let out = rt.importance(&refs).unwrap();
            let want_norms = golden.get("norms_head").unwrap().f64_list().unwrap();
            for (i, w) in want_norms.iter().enumerate() {
                assert!(
                    (out.norms[i] as f64 - w).abs() < 2e-3 * w.abs().max(1.0),
                    "{model} norm[{i}] {} vs {w}",
                    out.norms[i]
                );
            }
            let ksum: f64 = out.k.iter().map(|&v| v as f64).sum();
            let want_ksum = golden.get("k_sum").unwrap().as_f64().unwrap();
            assert!(
                (ksum - want_ksum).abs() < 2e-2 * want_ksum.abs().max(1.0),
                "{model} k_sum {ksum} vs {want_ksum}"
            );
        }
    }
}
