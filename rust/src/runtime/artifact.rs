//! Artifact discovery and the meta.json contract between the python AOT
//! path and the Rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Parsed `artifacts/<model>/meta.json` — the shape contract every
/// executable in the artifact set adheres to.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub param_count: usize,
    pub input_dim: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub h_dim: usize,
    /// Pooled feature dim per trunk block (filter depth k uses block_dims[k-1]).
    pub block_dims: Vec<usize>,
    pub train_batch: usize,
    pub filter_chunk: usize,
    pub cand_max: usize,
    pub eval_chunk: usize,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let j = Json::parse_file(&dir.join("meta.json"))?;
        Ok(ArtifactMeta {
            name: j.get("name")?.as_str()?.to_string(),
            param_count: j.get("param_count")?.as_usize()?,
            input_dim: j.get("input_dim")?.as_usize()?,
            input_shape: j.get("input_shape")?.usize_list()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            h_dim: j.get("h_dim")?.as_usize()?,
            block_dims: j.get("block_dims")?.usize_list()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            filter_chunk: j.get("filter_chunk")?.as_usize()?,
            cand_max: j.get("cand_max")?.as_usize()?,
            eval_chunk: j.get("eval_chunk")?.as_usize()?,
        })
    }

    pub fn num_blocks(&self) -> usize {
        self.block_dims.len()
    }

    /// Feature dim at filter depth `k` (1-based, clamped like the python side).
    pub fn feature_dim(&self, k: usize) -> usize {
        let idx = k.clamp(1, self.num_blocks()) - 1;
        self.block_dims[idx]
    }
}

/// Paths of one model's artifact set.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub meta: ArtifactMeta,
}

impl ArtifactSet {
    /// Discover and validate `artifacts_dir/<model>/`.
    pub fn discover(artifacts_dir: &str, model: &str) -> Result<ArtifactSet> {
        let dir = PathBuf::from(artifacts_dir).join(model);
        if !dir.is_dir() {
            return Err(Error::Artifact(format!(
                "artifact dir {} missing — run `make artifacts`",
                dir.display()
            )));
        }
        let meta = ArtifactMeta::load(&dir)?;
        for f in ["train_step.hlo.txt", "importance.hlo.txt", "eval.hlo.txt", "init_params.bin"] {
            if !dir.join(f).exists() {
                return Err(Error::Artifact(format!("{} missing {f}", dir.display())));
            }
        }
        Ok(ArtifactSet { dir, meta })
    }

    pub fn hlo_path(&self, stem: &str) -> PathBuf {
        self.dir.join(format!("{stem}.hlo.txt"))
    }

    pub fn features_path(&self, k: usize) -> PathBuf {
        self.dir.join(format!("features_b{k}.hlo.txt"))
    }

    /// Load the f32 LE initial parameter vector.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join("init_params.bin"))?;
        if bytes.len() != self.meta.param_count * 4 {
            return Err(Error::Artifact(format!(
                "init_params.bin has {} bytes, expected {}",
                bytes.len(),
                self.meta.param_count * 4
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Parsed golden.json (cross-language numerics check).
    pub fn golden(&self) -> Result<Json> {
        Json::parse_file(&self.dir.join("golden.json"))
    }

    /// List models available under an artifacts dir.
    pub fn list_models(artifacts_dir: &str) -> Vec<String> {
        let mut out = Vec::new();
        if let Ok(entries) = std::fs::read_dir(artifacts_dir) {
            for e in entries.flatten() {
                let p = e.path();
                if p.is_dir() && p.join("meta.json").exists() {
                    if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
                        out.push(name.to_string());
                    }
                }
            }
        }
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> String {
        // tests run from the crate root
        "artifacts".to_string()
    }

    fn have_artifacts() -> bool {
        Path::new(&artifacts_root()).join("mlp/meta.json").exists()
    }

    #[test]
    fn meta_parses_and_is_consistent() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let set = ArtifactSet::discover(&artifacts_root(), "mlp").unwrap();
        let m = &set.meta;
        assert_eq!(m.name, "mlp");
        assert_eq!(m.input_dim, 900);
        assert_eq!(m.num_classes, 6);
        assert_eq!(m.input_shape.iter().product::<usize>(), m.input_dim);
        assert!(m.num_blocks() >= 2);
        assert_eq!(m.feature_dim(1), m.block_dims[0]);
        assert_eq!(m.feature_dim(99), *m.block_dims.last().unwrap());
        let params = set.init_params().unwrap();
        assert_eq!(params.len(), m.param_count);
        assert!(params.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn discover_rejects_missing() {
        let err = ArtifactSet::discover("artifacts", "no_such_model").unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
    }

    #[test]
    fn list_models_contains_built() {
        if !have_artifacts() {
            return;
        }
        let models = ArtifactSet::list_models(&artifacts_root());
        assert!(models.contains(&"mlp".to_string()), "{models:?}");
    }
}
