//! Thread-local compiled-executable cache.
//!
//! The experiment harness constructs many engines per process (Table 1
//! alone runs 8 methods × N models, each building Selector/Trainer
//! runtimes), and PJRT compilation of the same HLO artifact dominates
//! engine startup. `PjRtLoadedExecutable` is `!Send` (the client is
//! Rc-based), so the cache is thread-local: one shared CPU client per
//! thread plus a path+mtime-keyed map of compiled executables. Same-thread
//! reloads become map hits; the pipeline's selector thread builds its own
//! cache on first use.
//!
//! Measured impact is recorded in EXPERIMENTS.md §Perf (engine
//! construction drops from PJRT-compile-bound to file-stat-bound).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::SystemTime;

use crate::util::clock;
use crate::Result;

thread_local! {
    static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> = const { RefCell::new(None) };
    static EXES: RefCell<HashMap<(PathBuf, SystemTime), Rc<xla::PjRtLoadedExecutable>>> =
        RefCell::new(HashMap::new());
}

/// The calling thread's shared PJRT CPU client (created on first use).
pub fn thread_client() -> Result<Rc<xla::PjRtClient>> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if let Some(client) = slot.as_ref() {
            return Ok(client.clone());
        }
        let client = Rc::new(xla::PjRtClient::cpu()?);
        *slot = Some(client.clone());
        Ok(client)
    })
}

/// Compile `path` (HLO text) on the thread client, reusing a cached
/// executable when the file is unchanged (path + mtime key).
pub fn compile_cached(path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
    let mtime = clock::file_mtime(path)?;
    let key = (path.to_path_buf(), mtime);
    if let Some(hit) = EXES.with(|m| m.borrow().get(&key).cloned()) {
        return Ok(hit);
    }
    let client = thread_client()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = Rc::new(client.compile(&comp)?);
    EXES.with(|m| m.borrow_mut().insert(key, exe.clone()));
    Ok(exe)
}

/// Cache statistics for the calling thread (entries currently held).
pub fn cached_count() -> usize {
    EXES.with(|m| m.borrow().len())
}

/// Drop all cached executables on this thread (tests / memory pressure).
pub fn clear() {
    EXES.with(|m| m.borrow_mut().clear());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Path::new("artifacts/mlp/meta.json").exists()
    }

    #[test]
    fn cache_hits_same_path() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        clear();
        let p = Path::new("artifacts/mlp/eval.hlo.txt");
        let a = compile_cached(p).unwrap();
        let n1 = cached_count();
        let b = compile_cached(p).unwrap();
        assert!(Rc::ptr_eq(&a, &b), "second compile must be a cache hit");
        assert_eq!(cached_count(), n1);
        clear();
        assert_eq!(cached_count(), 0);
    }

    #[test]
    fn thread_client_is_shared() {
        let a = thread_client().unwrap();
        let b = thread_client().unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_file_errors() {
        assert!(compile_cached(Path::new("artifacts/nope.hlo.txt")).is_err());
    }
}
