//! Conversions between the coordinator's `Vec<f32>` world and `xla`
//! `Literal`s, plus batch-assembly helpers (padding, one-hot).

use crate::data::sample::Sample;
use crate::{Error, Result};

/// Build a rank-2 f32 literal [rows, cols] from a flat slice.
pub fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    if data.len() != rows * cols {
        return Err(Error::Other(format!(
            "literal_2d: {} elements for [{rows},{cols}]",
            data.len()
        )));
    }
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build a rank-1 f32 literal.
pub fn literal_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Build a rank-0 (scalar) f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read back a literal into Vec<f32>.
pub fn to_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Assemble a fixed-size input batch from samples, padding by repeating the
/// last sample (padded rows are masked or ignored downstream). Returns the
/// flat [batch * dim] buffer.
pub fn batch_inputs(samples: &[&Sample], batch: usize, dim: usize) -> Result<Vec<f32>> {
    if samples.is_empty() {
        return Err(Error::Other("batch_inputs: empty sample set".into()));
    }
    let mut out = Vec::with_capacity(batch * dim);
    for i in 0..batch {
        let s = samples[i.min(samples.len() - 1)];
        if s.dim() != dim {
            return Err(Error::Other(format!(
                "sample dim {} != expected {dim}",
                s.dim()
            )));
        }
        out.extend_from_slice(&s.x);
    }
    Ok(out)
}

/// One-hot label matrix [batch, classes] with the same padding rule.
pub fn batch_onehot(samples: &[&Sample], batch: usize, classes: usize) -> Result<Vec<f32>> {
    if samples.is_empty() {
        return Err(Error::Other("batch_onehot: empty sample set".into()));
    }
    let mut out = vec![0.0f32; batch * classes];
    for i in 0..batch {
        let s = samples[i.min(samples.len() - 1)];
        let y = s.label as usize;
        if y >= classes {
            return Err(Error::Other(format!("label {y} >= classes {classes}")));
        }
        out[i * classes + y] = 1.0;
    }
    Ok(out)
}

/// Validity mask [n]: 1.0 for the first `valid` rows, 0.0 for padding.
pub fn mask(n: usize, valid: usize) -> Vec<f32> {
    (0..n).map(|i| if i < valid { 1.0 } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(id: u64, label: u32, dim: usize) -> Sample {
        Sample::new(id, label, vec![id as f32; dim])
    }

    #[test]
    fn batch_pads_by_repeating_last() {
        let a = s(1, 0, 3);
        let b = s(2, 1, 3);
        let refs = vec![&a, &b];
        let x = batch_inputs(&refs, 4, 3).unwrap();
        assert_eq!(x.len(), 12);
        assert_eq!(&x[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&x[6..9], &[2.0, 2.0, 2.0]); // padded with last
        assert_eq!(&x[9..12], &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn onehot_layout() {
        let a = s(1, 2, 2);
        let refs = vec![&a];
        let y = batch_onehot(&refs, 2, 4).unwrap();
        assert_eq!(y, vec![0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn errors_on_mismatch() {
        let a = s(1, 9, 3);
        let refs = vec![&a];
        assert!(batch_onehot(&refs, 1, 4).is_err()); // label out of range
        assert!(batch_inputs(&refs, 1, 5).is_err()); // dim mismatch
        let empty: Vec<&Sample> = vec![];
        assert!(batch_inputs(&empty, 1, 3).is_err());
    }

    #[test]
    fn mask_shape() {
        assert_eq!(mask(4, 2), vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(mask(2, 5), vec![1.0, 1.0]);
    }

    #[test]
    fn literal_roundtrip() {
        // requires a working XLA install; cheap enough to always run
        let lit = literal_2d(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        let back = to_f32s(&lit).unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(literal_2d(&[1.0], 2, 3).is_err());
    }
}
