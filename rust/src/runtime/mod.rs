//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the coordinator's hot
//! path. Python never runs here.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based and
//! therefore `!Send`. Each pipeline thread constructs its *own*
//! [`ModelRuntime`] over the same artifact files (the trainer thread
//! compiles `train_step` + `eval`; the selector thread compiles
//! `features` + `importance`). Model parameters cross threads as plain
//! `Vec<f32>` once per round — exactly the paper's "synchronize model
//! parameters once per model update" pipeline cost.

pub mod artifact;
pub mod cache;
pub mod literal;
pub mod model;

pub use artifact::ArtifactMeta;
pub use model::{EvalReport, ModelRuntime, RuntimeRole};
