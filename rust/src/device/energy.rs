//! Energy accounting for the simulated device (paper Fig. 6d).
//!
//! A simple state-based power model: the device draws a baseline (idle)
//! power plus per-lane active power while a lane is busy. The paper's
//! observation — Titan raises average power (two lanes active) but lowers
//! wall time, so total energy lands between 0.69× and 1.17× of RS —
//! emerges from exactly this structure.

/// Power draw parameters (watts), Jetson-Nano-flavoured defaults
/// (5–10 W envelope).
#[derive(Clone, Copy, Debug)]
pub struct PowerParams {
    pub idle_w: f64,
    pub cpu_active_w: f64,
    pub gpu_active_w: f64,
}

impl Default for PowerParams {
    fn default() -> Self {
        Self {
            idle_w: 1.8,
            cpu_active_w: 3.6,
            gpu_active_w: 2.8,
        }
    }
}

/// Accumulated energy over a run.
#[derive(Clone, Debug, Default)]
pub struct EnergyModel {
    params: PowerParamsHolder,
    /// Joules consumed so far.
    energy_j: f64,
    /// Wall ms accounted.
    wall_ms: f64,
}

// Default-able wrapper (PowerParams has no natural zero default).
#[derive(Clone, Debug)]
struct PowerParamsHolder(PowerParams);

impl Default for PowerParamsHolder {
    fn default() -> Self {
        Self(PowerParams::default())
    }
}

impl EnergyModel {
    pub fn with_params(params: PowerParams) -> Self {
        Self {
            params: PowerParamsHolder(params),
            energy_j: 0.0,
            wall_ms: 0.0,
        }
    }

    /// Account one round: the CPU lane was busy `cpu_ms`, the GPU lane
    /// `gpu_ms`, within a realized wall window of `wall_ms`.
    pub fn account_round(&mut self, cpu_ms: f64, gpu_ms: f64, wall_ms: f64) {
        let p = &self.params.0;
        let cpu_busy = cpu_ms.min(wall_ms);
        let gpu_busy = gpu_ms.min(wall_ms);
        let e = p.idle_w * wall_ms / 1e3
            + p.cpu_active_w * cpu_busy / 1e3
            + p.gpu_active_w * gpu_busy / 1e3;
        self.energy_j += e;
        self.wall_ms += wall_ms;
    }

    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    /// Consume `joules` outside the per-round power integral — an
    /// injected brown-out. Wall time is unchanged: the device lost
    /// charge, not progress, so average power rises.
    pub fn drain(&mut self, joules: f64) {
        self.energy_j += joules.max(0.0);
    }

    /// Wall ms accounted so far (the denominator of
    /// [`EnergyModel::avg_power_w`]).
    pub fn wall_ms(&self) -> f64 {
        self.wall_ms
    }

    /// Restore the accumulators from a checkpoint (power params stay as
    /// constructed — they are configuration, not run state).
    pub fn restore(&mut self, energy_j: f64, wall_ms: f64) {
        self.energy_j = energy_j;
        self.wall_ms = wall_ms;
    }

    /// Average power over the accounted wall time (W).
    pub fn avg_power_w(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.energy_j / (self.wall_ms / 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_only_round() {
        let mut e = EnergyModel::default();
        e.account_round(0.0, 0.0, 1000.0);
        assert!((e.energy_j() - 1.8).abs() < 1e-9);
        assert!((e.avg_power_w() - 1.8).abs() < 1e-9);
    }

    #[test]
    fn two_lane_round_draws_more_power_for_less_time() {
        // pipelined: both lanes busy, wall = max
        let mut pipe = EnergyModel::default();
        pipe.account_round(1000.0, 800.0, 1000.0);
        // sequential: lanes serialized, wall = sum
        let mut seq = EnergyModel::default();
        seq.account_round(1000.0, 800.0, 1800.0);
        assert!(pipe.avg_power_w() > seq.avg_power_w());
        // same busy work => similar energy, pipelined strictly less
        // (less idle-time integration)
        assert!(pipe.energy_j() < seq.energy_j());
    }

    #[test]
    fn busy_clamped_to_wall() {
        let mut e = EnergyModel::default();
        // lane time cannot exceed the wall window
        e.account_round(5000.0, 0.0, 1000.0);
        let expect = 1.8 + 3.6; // 1 s of idle + 1 s of cpu
        assert!((e.energy_j() - expect).abs() < 1e-9);
    }
}
