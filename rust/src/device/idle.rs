//! Fluctuating idle-resource traces (paper §3.4 + Fig. 9 / Appendix B).
//!
//! Co-running apps occupy a varying share of the selection lane. Titan
//! adapts by letting the coarse filter keep however many candidates the
//! idle capacity managed to evaluate that round, instead of a fixed size.
//! A trace maps round -> available fraction of the GPU lane; the
//! coordinator converts that into this round's effective candidate budget.

use crate::util::rng::Xoshiro256;

/// A per-round idle-capacity trace in [min_frac, 1].
#[derive(Clone, Debug)]
pub enum IdleTrace {
    /// Constant capacity (the default fixed-budget experiments).
    Constant(f64),
    /// Sinusoid with period (rounds) — diurnal-style load.
    Sine { min: f64, max: f64, period: f64 },
    /// Bounded random walk — bursty co-running apps.
    RandomWalk { min: f64, max: f64, step: f64, seed: u64 },
}

impl IdleTrace {
    /// Available fraction of the selection lane in `round`.
    pub fn fraction(&self, round: usize) -> f64 {
        match self {
            IdleTrace::Constant(f) => f.clamp(0.05, 1.0),
            IdleTrace::Sine { min, max, period } => {
                let phase = round as f64 / period * std::f64::consts::TAU;
                let mid = (min + max) / 2.0;
                let amp = (max - min) / 2.0;
                (mid + amp * phase.sin()).clamp(0.05, 1.0)
            }
            IdleTrace::RandomWalk { min, max, step, seed } => {
                // stateless: regenerate the walk up to `round` (rounds are
                // small; determinism beats carrying state through threads)
                let mut rng = Xoshiro256::seed_from_u64(*seed ^ 0x1D1E);
                let mut x = (min + max) / 2.0;
                for _ in 0..=round {
                    x += (rng.next_f64() * 2.0 - 1.0) * step;
                    x = x.clamp(*min, *max);
                }
                x.clamp(0.05, 1.0)
            }
        }
    }

    /// Effective candidate budget for the round given the configured
    /// maximum: the filter can only score/buffer what the idle share of
    /// the lane gets through (paper: "evaluated samples naturally become
    /// candidate data ... without a predefined size").
    pub fn candidate_budget(&self, round: usize, max_candidates: usize) -> usize {
        let b = (self.fraction(round) * max_candidates as f64).round() as usize;
        b.clamp(1, max_candidates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_trace() {
        let t = IdleTrace::Constant(0.5);
        assert_eq!(t.fraction(0), 0.5);
        assert_eq!(t.candidate_budget(3, 100), 50);
    }

    #[test]
    fn sine_oscillates_in_bounds() {
        let t = IdleTrace::Sine { min: 0.2, max: 1.0, period: 50.0 };
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for r in 0..200 {
            let f = t.fraction(r);
            assert!((0.05..=1.0).contains(&f));
            lo = lo.min(f);
            hi = hi.max(f);
        }
        assert!(lo < 0.3 && hi > 0.9, "range [{lo}, {hi}]");
    }

    #[test]
    fn random_walk_deterministic_and_bounded() {
        let t = IdleTrace::RandomWalk { min: 0.15, max: 1.0, step: 0.1, seed: 3 };
        for r in [0usize, 7, 31] {
            let a = t.fraction(r);
            let b = t.fraction(r);
            assert_eq!(a, b);
            assert!((0.05..=1.0).contains(&a));
        }
    }

    #[test]
    fn budget_clamped() {
        let t = IdleTrace::Constant(0.001);
        assert_eq!(t.candidate_budget(0, 30), 2); // 0.05 floor * 30, min 1
        let t = IdleTrace::Constant(1.0);
        assert_eq!(t.candidate_budget(0, 30), 30);
    }
}
