//! Peak-memory accounting for the simulated device (paper Fig. 6c).
//!
//! Tracks the framework's resident components: model parameters (one copy
//! per process in the pipeline), activation workspace for the training
//! batch, the candidate buffer payload, and the selection workspace
//! (K matrix + feature chunks). The paper's claim — pipeline adds <10%
//! over bare training — corresponds to the extra params copy + selection
//! workspace being small next to the training activations.

/// Byte sizes of the components resident during a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    /// Model parameters held by the trainer process.
    pub params_trainer: usize,
    /// Parameter replica held by the selector process (pipeline only).
    pub params_selector: usize,
    /// Training activation workspace (fwd+bwd for one batch).
    pub train_activations: usize,
    /// Candidate buffer payloads.
    pub candidate_buffer: usize,
    /// Selection workspace: K matrix, feature chunk, norms.
    pub selection_workspace: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.params_trainer
            + self.params_selector
            + self.train_activations
            + self.candidate_buffer
            + self.selection_workspace
    }

    /// Everything beyond bare training (the paper's "extra footprint").
    pub fn overhead(&self) -> usize {
        self.params_selector + self.candidate_buffer + self.selection_workspace
    }

    pub fn overhead_frac(&self) -> f64 {
        let base = self.params_trainer + self.train_activations;
        if base == 0 {
            0.0
        } else {
            self.overhead() as f64 / base as f64
        }
    }
}

/// Estimate the breakdown for a run configuration.
///
/// `param_count`: model params; `act_mult`: activation bytes per param
/// during fwd+bwd (model-dependent; conv nets rematerialize more);
/// `input_dim`, `cand`: candidate buffer geometry; `k_n`: importance N.
pub fn estimate(
    param_count: usize,
    act_mult: f64,
    batch: usize,
    input_dim: usize,
    cand: usize,
    k_n: usize,
    feature_dim: usize,
    filter_chunk: usize,
    pipelined: bool,
) -> MemoryBreakdown {
    let f = std::mem::size_of::<f32>();
    MemoryBreakdown {
        params_trainer: param_count * f,
        params_selector: if pipelined { param_count * f } else { 0 },
        train_activations: (param_count as f64 * act_mult) as usize * f
            + batch * input_dim * f,
        candidate_buffer: cand * input_dim * f,
        selection_workspace: (k_n * k_n + k_n + filter_chunk * feature_dim) * f,
    }
}

/// Activation multiplier per model variant (rough, from layer geometry).
pub fn act_mult_for(model: &str) -> f64 {
    match model {
        "mlp" => 0.4,
        "tinyalex" => 2.5,
        "mobilenet" => 4.0,
        "squeeze" => 3.0,
        "resnet_ic" => 5.0,
        "resnet_ar" => 4.0,
        _ => 3.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_overhead() {
        let m = estimate(100_000, 2.0, 10, 3072, 30, 100, 16, 25, true);
        assert_eq!(m.total(), m.overhead() + m.params_trainer + m.train_activations);
        assert!(m.params_selector == m.params_trainer);
        // selection workspace dominated by the 100x100 K matrix
        assert!(m.selection_workspace >= 100 * 100 * 4);
    }

    #[test]
    fn sequential_has_no_replica() {
        let m = estimate(100_000, 2.0, 10, 3072, 30, 100, 16, 25, false);
        assert_eq!(m.params_selector, 0);
    }

    #[test]
    fn pipeline_overhead_is_small_fraction() {
        // the paper's <10% claim holds for the conv variants where
        // activations dominate
        for model in ["tinyalex", "mobilenet", "squeeze", "resnet_ic"] {
            let m = estimate(
                120_000,
                act_mult_for(model),
                10,
                3072,
                30,
                100,
                32,
                25,
                true,
            );
            assert!(
                m.overhead_frac() < 0.75,
                "{model}: overhead {:.2}",
                m.overhead_frac()
            );
        }
    }
}
