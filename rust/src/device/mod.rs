//! Edge-device simulator — the stand-in for the paper's Jetson Nano
//! testbed (DESIGN.md §Substitutions).
//!
//! The paper's claims are *relative* (time-to-accuracy normalized to RS,
//! overhead vs train-only). We therefore model per-operation costs with a
//! calibrated table shaped like the paper's measurements (Jetson Nano,
//! §2.2/§4: ~20 s per MobileNet batch-16 round scaled to batch 10; 4–13 ms
//! per-sample filter delay; importance computation "up to 7×" a training
//! round when run over the whole stream), scaled by the actual workload
//! each method issues. Host wall-clock is measured separately by the
//! metrics plane; every figure reports which clock it uses.
//!
//! Two compute lanes model the paper's process placement: `Cpu` runs the
//! model update, `Gpu` runs filtering + selection (§4.1). Pipelined
//! rounds cost `max(cpu, gpu) + sync`; sequential rounds cost the sum.

pub mod energy;
pub mod idle;
pub mod memory;

/// Compute lanes on the simulated device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Model update (the paper trains on mobile CPU).
    Cpu,
    /// Data selection (filter + importance on mobile GPU).
    Gpu,
}

/// Operations with simulated costs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// One SGD step on a training batch of the given size.
    TrainStep { batch: usize },
    /// Shallow feature extraction for a chunk, at filter depth `blocks`.
    Features { chunk: usize, blocks: usize },
    /// Importance (norms + K) over n candidates.
    Importance { n: usize },
    /// Probe (per-sample loss/entropy) over n candidates.
    Probe { n: usize },
    /// Raw-input pairwise distances over n candidates (Camel).
    InputDistance { n: usize },
    /// Evaluation chunk.
    EvalChunk { n: usize },
    /// Cross-process sync of params + selected batch (pipeline cost).
    Sync,
}

/// Per-model cost table (milliseconds on the simulated device).
///
/// Derived from the paper's reported Jetson numbers: a full-model
/// forward+backward dominates (`train_ms_per_sample`), per-sample forward
/// is ~1/3 of that, shallow-block forward is the per-sample filter cost
/// (4–13 ms, Fig. 6b), and importance adds the last-layer gradient algebra
/// on top of a forward.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub model: String,
    /// Full fwd+bwd per sample (ms).
    pub train_ms_per_sample: f64,
    /// Full forward per sample (ms).
    pub fwd_ms_per_sample: f64,
    /// First-block forward per sample (ms); deeper blocks scale linearly.
    pub block_fwd_ms_per_sample: f64,
    pub num_blocks: usize,
    /// Last-layer gradient + Gram algebra per candidate (ms).
    pub grad_algebra_ms_per_sample: f64,
    /// Raw-input distance per candidate pair (ms).
    pub dist_ms_per_pair: f64,
    /// Params+batch sync between processes (ms).
    pub sync_ms: f64,
    /// Batched-execution discount for selection ops: scoring N candidates
    /// in one kernel launch amortizes far better than N training-style
    /// per-sample passes (the paper's GPU selection path).
    pub batch_discount: f64,
}

impl CostModel {
    /// Calibration table per model variant. The paper's Jetson trains
    /// MobileNetV1 at ~20 s per batch-16 round (§2.2) → ~1.2 s/sample;
    /// lighter/heavier variants scale with their relative FLOPs.
    pub fn for_model(model: &str) -> CostModel {
        // (train, fwd, block) ms per sample on the simulated device
        let (train, fwd, block, blocks) = match model {
            "mlp" => (60.0, 18.0, 4.0, 2),
            "tinyalex" => (900.0, 280.0, 8.0, 3),
            "mobilenet" => (1250.0, 380.0, 10.0, 4),
            "squeeze" => (800.0, 250.0, 7.0, 3),
            "resnet_ic" => (2000.0, 600.0, 12.0, 5),
            "resnet_ar" => (1500.0, 450.0, 13.0, 4),
            _ => (1000.0, 300.0, 10.0, 3),
        };
        CostModel {
            model: model.to_string(),
            train_ms_per_sample: train,
            fwd_ms_per_sample: fwd,
            block_fwd_ms_per_sample: block,
            num_blocks: blocks,
            grad_algebra_ms_per_sample: fwd * 0.15,
            dist_ms_per_pair: 0.02,
            sync_ms: 40.0,
            batch_discount: 0.5,
        }
    }

    /// Simulated cost of an operation in ms.
    pub fn cost_ms(&self, op: Op) -> f64 {
        match op {
            Op::TrainStep { batch } => self.train_ms_per_sample * batch as f64,
            Op::Features { chunk, blocks } => {
                let depth = blocks.clamp(1, self.num_blocks) as f64;
                // deeper features cost proportionally more; full depth
                // approaches the full forward cost
                let per_sample = self.block_fwd_ms_per_sample
                    + (self.fwd_ms_per_sample - self.block_fwd_ms_per_sample)
                        * (depth - 1.0)
                        / self.num_blocks as f64;
                per_sample * chunk as f64 * self.batch_discount
            }
            Op::Importance { n } => {
                (self.fwd_ms_per_sample + self.grad_algebra_ms_per_sample)
                    * n as f64
                    * self.batch_discount
            }
            Op::Probe { n } => self.fwd_ms_per_sample * n as f64 * self.batch_discount,
            Op::InputDistance { n } => self.dist_ms_per_pair * (n * n) as f64,
            Op::EvalChunk { n } => self.fwd_ms_per_sample * n as f64 * self.batch_discount,
            Op::Sync => self.sync_ms,
        }
    }
}

/// Accumulates simulated time per lane within a round, then folds rounds
/// into a device-clock total.
#[derive(Debug)]
pub struct DeviceSim {
    pub costs: CostModel,
    round_ms: [f64; 2],
    total_ms: f64,
    round_log: Vec<RoundTiming>,
    energy: energy::EnergyModel,
    /// One-round clock inflation (straggler fault injection); ≥ 1, reset
    /// to the neutral 1.0 after each round.
    round_slowdown: f64,
}

/// Timing of one completed round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTiming {
    pub cpu_ms: f64,
    pub gpu_ms: f64,
    /// Realized wall ms for the round (max or sum depending on pipeline).
    pub wall_ms: f64,
}

impl DeviceSim {
    pub fn new(model: &str) -> DeviceSim {
        DeviceSim {
            costs: CostModel::for_model(model),
            round_ms: [0.0, 0.0],
            total_ms: 0.0,
            round_log: Vec::new(),
            energy: energy::EnergyModel::default(),
            round_slowdown: 1.0,
        }
    }

    /// Inflate the *current* round's device clock by `factor` (clamped to
    /// ≥ 1) on both lanes — a straggler round. One-shot: the factor
    /// resets to 1 when the round ends.
    pub fn set_round_slowdown(&mut self, factor: f64) {
        self.round_slowdown = factor.max(1.0);
    }

    /// Drain `joules` from the simulated battery without useful work
    /// (energy brown-out injection).
    pub fn drain_energy(&mut self, joules: f64) {
        self.energy.drain(joules);
    }

    /// Record an operation on a lane within the current round.
    pub fn record(&mut self, lane: Lane, op: Op) {
        let ms = self.costs.cost_ms(op);
        self.round_ms[lane as usize] += ms;
    }

    /// Close the round. `pipelined` determines whether lanes overlap.
    /// Returns the realized round timing.
    pub fn end_round(&mut self, pipelined: bool) -> RoundTiming {
        // ×1.0 is a bit-exact identity, so fault-free rounds are
        // untouched by the slowdown hook
        let cpu = self.round_ms[Lane::Cpu as usize] * self.round_slowdown;
        let gpu = self.round_ms[Lane::Gpu as usize] * self.round_slowdown;
        self.round_slowdown = 1.0;
        let wall = if pipelined { cpu.max(gpu) } else { cpu + gpu };
        self.total_ms += wall;
        self.energy.account_round(cpu, gpu, wall);
        let t = RoundTiming {
            cpu_ms: cpu,
            gpu_ms: gpu,
            wall_ms: wall,
        };
        self.round_log.push(t);
        self.round_ms = [0.0, 0.0];
        t
    }

    /// Simulated wall-clock since start (ms).
    pub fn total_ms(&self) -> f64 {
        self.total_ms
    }

    pub fn rounds(&self) -> &[RoundTiming] {
        &self.round_log
    }

    pub fn energy(&self) -> &energy::EnergyModel {
        &self.energy
    }

    /// Export the accumulated clock/energy state at a round boundary
    /// (checkpoint). The intra-round lane accumulators are intentionally
    /// not exported: snapshots are only taken between rounds, where they
    /// are zero by construction.
    pub fn export_state(&self) -> DeviceSimState {
        DeviceSimState {
            total_ms: self.total_ms,
            energy_j: self.energy.energy_j(),
            energy_wall_ms: self.energy.wall_ms(),
            rounds: self.round_log.clone(),
        }
    }

    /// Restore a state exported by [`DeviceSim::export_state`] into a
    /// fresh simulator (resume). Clears any intra-round accumulation.
    pub fn restore_state(&mut self, st: DeviceSimState) {
        self.total_ms = st.total_ms;
        self.energy.restore(st.energy_j, st.energy_wall_ms);
        self.round_log = st.rounds;
        self.round_ms = [0.0, 0.0];
        self.round_slowdown = 1.0;
    }
}

/// Accumulated [`DeviceSim`] state at a round boundary — what a session
/// checkpoint carries so a resumed run's device clock, energy integral
/// and per-round log continue from the interrupted run's values.
#[derive(Clone, Debug, Default)]
pub struct DeviceSimState {
    pub total_ms: f64,
    pub energy_j: f64,
    pub energy_wall_ms: f64,
    pub rounds: Vec<RoundTiming>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_table_shapes() {
        let c = CostModel::for_model("mobilenet");
        // train >> fwd >> block-1 forward (the paper's premise)
        assert!(c.train_ms_per_sample > c.fwd_ms_per_sample * 2.0);
        assert!(c.fwd_ms_per_sample > c.block_fwd_ms_per_sample * 10.0);
        // filter per-sample delay lands in the paper's 4–13 ms band
        assert!((4.0..=13.0).contains(&c.block_fwd_ms_per_sample));
    }

    #[test]
    fn features_cost_grows_with_depth() {
        let c = CostModel::for_model("resnet_ic");
        let d1 = c.cost_ms(Op::Features { chunk: 10, blocks: 1 });
        let d3 = c.cost_ms(Op::Features { chunk: 10, blocks: 3 });
        let dmax = c.cost_ms(Op::Features { chunk: 10, blocks: 99 });
        assert!(d1 < d3 && d3 < dmax);
        assert!(dmax <= c.cost_ms(Op::Probe { n: 10 }) + 1e-9);
    }

    #[test]
    fn pipeline_overlap_vs_sequential() {
        let mut sim = DeviceSim::new("mlp");
        sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
        sim.record(Lane::Gpu, Op::Importance { n: 30 });
        sim.record(Lane::Gpu, Op::Sync);
        let t_pipe = sim.end_round(true);
        assert!((t_pipe.wall_ms - t_pipe.cpu_ms.max(t_pipe.gpu_ms)).abs() < 1e-9);

        sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
        sim.record(Lane::Gpu, Op::Importance { n: 30 });
        let t_seq = sim.end_round(false);
        assert!((t_seq.wall_ms - (t_seq.cpu_ms + t_seq.gpu_ms)).abs() < 1e-9);
        assert!(t_seq.wall_ms > t_pipe.wall_ms * 0.99);
    }

    #[test]
    fn is_on_full_stream_much_slower_than_training() {
        // the paper's Fig. 2(a): computing importance for the whole stream
        // (100 samples) rivals/multiplies the training cost
        let c = CostModel::for_model("mobilenet");
        let train = c.cost_ms(Op::TrainStep { batch: 10 });
        let is_sel = c.cost_ms(Op::Importance { n: 100 });
        let ratio = (train + is_sel) / train;
        assert!(ratio > 2.0, "IS per-round blowup {ratio}");
        // while Titan's filter (block-1 on 100) + importance on 30 is light
        let titan_gpu = c.cost_ms(Op::Features { chunk: 100, blocks: 1 })
            + c.cost_ms(Op::Importance { n: 30 });
        assert!(titan_gpu < train, "titan gpu lane {titan_gpu} vs train {train}");
    }

    #[test]
    fn sim_state_roundtrip_continues_clock_and_energy() {
        let mut live = DeviceSim::new("mlp");
        for _ in 0..3 {
            live.record(Lane::Cpu, Op::TrainStep { batch: 10 });
            live.record(Lane::Gpu, Op::Importance { n: 30 });
            live.end_round(true);
        }
        let mut restored = DeviceSim::new("mlp");
        restored.restore_state(live.export_state());
        assert_eq!(restored.total_ms(), live.total_ms());
        assert_eq!(restored.energy().energy_j(), live.energy().energy_j());
        assert_eq!(restored.rounds().len(), 3);
        // both continue identically
        for sim in [&mut live, &mut restored] {
            sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
            sim.end_round(false);
        }
        assert_eq!(restored.total_ms(), live.total_ms());
        assert_eq!(restored.energy().avg_power_w(), live.energy().avg_power_w());
        assert_eq!(restored.rounds().len(), live.rounds().len());
    }

    #[test]
    fn round_slowdown_inflates_one_round_then_resets() {
        let mut clean = DeviceSim::new("mlp");
        let mut slow = DeviceSim::new("mlp");
        for sim in [&mut clean, &mut slow] {
            sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
            sim.record(Lane::Gpu, Op::Importance { n: 30 });
        }
        slow.set_round_slowdown(3.0);
        let tc = clean.end_round(true);
        let ts = slow.end_round(true);
        assert_eq!(ts.wall_ms, tc.wall_ms * 3.0);
        assert_eq!(ts.cpu_ms, tc.cpu_ms * 3.0);
        assert!(slow.energy().energy_j() > clean.energy().energy_j());
        // one-shot: the next round is back to clean costs
        for sim in [&mut clean, &mut slow] {
            sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
        }
        assert_eq!(slow.end_round(true).wall_ms, clean.end_round(true).wall_ms);
        // sub-unity factors clamp to the neutral 1.0
        clean.record(Lane::Cpu, Op::TrainStep { batch: 5 });
        clean.set_round_slowdown(0.25);
        let t = clean.end_round(false);
        assert_eq!(t.wall_ms, t.cpu_ms);
    }

    #[test]
    fn drain_energy_adds_joules_without_wall_time() {
        let mut sim = DeviceSim::new("mlp");
        sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
        sim.end_round(true);
        let base_e = sim.energy().energy_j();
        let base_t = sim.total_ms();
        sim.drain_energy(2.5);
        assert_eq!(sim.energy().energy_j(), base_e + 2.5);
        assert_eq!(sim.total_ms(), base_t);
        // negative drains are ignored, not credited
        sim.drain_energy(-10.0);
        assert_eq!(sim.energy().energy_j(), base_e + 2.5);
    }

    #[test]
    fn totals_accumulate() {
        let mut sim = DeviceSim::new("mlp");
        for _ in 0..3 {
            sim.record(Lane::Cpu, Op::TrainStep { batch: 10 });
            sim.end_round(true);
        }
        assert_eq!(sim.rounds().len(), 3);
        assert!((sim.total_ms() - 3.0 * 600.0).abs() < 1e-6);
    }
}
