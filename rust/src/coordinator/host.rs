//! The host fleet runtime — many device sessions multiplexed on one host.
//!
//! The ROADMAP north star is a host serving millions of device sessions;
//! the prerequisite is that no session may own a thread for its whole
//! run. [`crate::coordinator::session::Session`] is a step-driven state
//! machine, so a [`Fleet`] can own N boxed sessions and interleave them
//! **round-by-round** on one scheduler thread: each scheduler tick picks
//! one ready session under a pluggable [`SchedPolicy`] and advances it by
//! exactly one [`StepEvent`].
//!
//! # Sharded multi-thread host
//!
//! One scheduler thread caps the fleet at one core.
//! [`FleetBuilder::host_threads`] partitions the members into `t` shards
//! by a stable hash of session index ([`shard_of`]); each shard runs its
//! own fresh copy of the [`SchedPolicy`] ([`SchedPolicy::fresh`]) on a
//! `std::thread::scope` worker and advances its sessions **op by op**
//! ([`Session::step_op`]) — a slow selection stalls only its own session,
//! not a whole tick of everyone else. An idle worker *steals* the
//! oldest-stamped un-admitted member from the most-loaded shard's cold
//! queue. Stealing moves whole sessions (un-started builder recipes),
//! never mid-op state and never a *started* session: a session's engines
//! are pinned to the worker that admitted it (the runtime's compile cache
//! and `Rc`-shared executables are thread-local), so per-session round
//! order is untouched and every per-session [`RunRecord`] stays
//! bit-identical across all `host_threads` values — `host_threads = 1`
//! runs the original single-thread loop and is the determinism oracle.
//! Aggregates that read the host wall clock (`total_host_ms`, per-shard
//! [`ShardStats`]) legitimately vary; everything derived from the
//! simulated device clocks does not.
//!
//! Sessions are fully independent (own data source, own engines, own
//! device sim), so the interleaving order cannot perturb any session's
//! output: for every session that is reproducible solo — any
//! sequential-backend session, and pipelined sessions with
//! parameter-independent selection — the per-session [`RunRecord`] in a
//! fleet is identical to the solo record, under every policy (pinned by
//! the fleet integration tests). Pipelined sessions with
//! parameter-*dependent* selection are timing-sensitive by design (the
//! latest-only param slot; see the session module docs), so their
//! records vary run-to-run with or without a fleet around them.
//!
//! Shared host accounting rolls up into a [`FleetRecord`]: aggregate
//! simulated device time and ops, energy, the summed peak-memory estimate
//! (all sessions are resident concurrently), and the scheduler's own
//! overhead (host wall time *not* spent inside `Session::step` — the
//! pick + bookkeeping + observer fan-out cost per interleaved round,
//! tracked in PERF.md).
//!
//! Edge fleets get killed; [`FleetBuilder::session_checkpointed`] wires
//! each member to its own on-disk snapshot (the
//! [`observers::Checkpoint`](crate::coordinator::session::observers::Checkpoint)
//! observer) so a restarted `titan fleet --resume` run picks every
//! member back up at its own saved round instead of re-spending
//! device-ms from round 0.
//!
//! Edge fleets also fail *while running*: [`FleetBuilder::fault_plan`]
//! attaches a seeded, deterministic [`FaultPlan`] that injects crashes,
//! transient errors, stragglers, energy brown-outs and checkpoint
//! corruption per (session, round) cell, and
//! [`FleetBuilder::supervise`] picks what the scheduler does about
//! failures: [`SupervisionPolicy::FailFast`] aborts the fleet (the
//! historical behavior and still the default),
//! [`SupervisionPolicy::Isolate`] quarantines the failed member and
//! finishes everyone else, and [`SupervisionPolicy::Restart`] rebuilds
//! the member from its factory — resuming from its latest valid
//! checkpoint when it has one — after a deterministic scheduler-tick
//! backoff. Every terminal state is reported per session as a
//! [`SessionStatus`]; fault activity rolls up into
//! [`FleetRecord::faults`]. With a zero-rate plan (or none) every
//! policy is bit-identical to the unsupervised fleet on all
//! deterministic fields.
//!
//! ```no_run
//! use titan::config::{presets, Method};
//! use titan::coordinator::host::{FewestRoundsFirst, FleetBuilder};
//! use titan::coordinator::SessionBuilder;
//!
//! let mut fleet = FleetBuilder::new().policy(FewestRoundsFirst::new());
//! for (i, method) in [Method::Titan, Method::Rs].into_iter().enumerate() {
//!     let mut cfg = presets::table1("mlp", method);
//!     cfg.pipeline = false;
//!     cfg.seed += i as u64;
//!     fleet = fleet.session(format!("dev{i}"), SessionBuilder::new(cfg));
//! }
//! let record = fleet.host_threads(4).run()?;
//! println!("{} rounds interleaved, {} steals", record.rounds_executed, record.steals);
//! # Ok::<(), titan::Error>(())
//! ```

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use crate::coordinator::session::{observers::Checkpoint, Session, SessionBuilder, StepEvent};
use crate::coordinator::snapshot::{load_checkpoint_str, load_vault_checkpoint, Loaded};
use crate::coordinator::vault::{self, RecoveryTelemetry};
use crate::coordinator::RoundOutcome;
use crate::fault::{restart_backoff, FaultKind, FaultPlan, SupervisionPolicy};
use crate::metrics::RunRecord;
use crate::util::json::Json;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};

/// Per-task scheduling bookkeeping the policies decide on. The driver
/// (fleet or FL orchestrator) maintains one per task.
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskState {
    /// Rounds this task has completed.
    pub rounds_done: usize,
    /// Driver tick at which this task last ran (0 = never). Staleness is
    /// the *difference* `now − last_run`, so ordering "stalest first" is
    /// ordering "smallest last_run first" — which is what lets the driver
    /// update one entry per tick instead of aging all N.
    pub last_run: u64,
}

/// A scheduling policy over ready tasks.
///
/// `ready` is non-empty, **sorted ascending**, and holds indices into
/// `states`; `pick` must return one of them, and must be
/// **deterministic** (no wall clock, no RNG) so fleet runs replay
/// exactly. Policies may keep internal state (e.g. the round-robin
/// cursor).
///
/// The optional lifecycle hooks let a policy maintain O(log N) indexed
/// state instead of scanning `ready` on every pick: the driver calls
/// [`SchedPolicy::prepare`] whenever the ready set is (re)initialized
/// and [`SchedPolicy::task_ran`] after a picked task finished a unit of
/// work *and remains ready* (its `states` entry already updated). A task
/// that leaves the ready set simply gets no `task_ran` — a picked entry
/// is consumed. Policies that ignore the hooks (the default no-ops) must
/// answer `pick` from `states`/`ready` alone, and the built-in
/// heap-backed policies fall back to exactly that scan when the driver
/// never prepared them.
pub trait SchedPolicy {
    /// Pick the next task to run among `ready`.
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize;

    /// The ready set was (re)initialized (fleet start, FL comm round).
    fn prepare(&mut self, _states: &[TaskState], _ready: &[usize]) {}

    /// `task` was picked, ran one unit, and is ready again; its
    /// `states[task]` is current.
    fn task_ran(&mut self, _task: usize, _states: &[TaskState]) {}

    /// A fresh, state-free instance of this policy for one shard worker
    /// of the sharded host ([`FleetBuilder::host_threads`] > 1). Each
    /// worker schedules its own shard independently, so the instance must
    /// start from the same blank state a `new()` would. The default
    /// `None` means the policy cannot be replicated across shards —
    /// a sharded run then fails with [`Error::Sched`] *before* spawning
    /// any worker. Single-thread fleets never call this.
    fn fresh(&self) -> Option<Box<dyn SchedPolicy + Send>> {
        None
    }

    /// Display name for records and logs.
    fn name(&self) -> &'static str;
}

/// Cyclic fairness: the smallest ready index strictly after the last
/// pick, wrapping to the smallest ready index.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRobin {
    last: Option<usize>,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { last: None }
    }
}

impl SchedPolicy for RoundRobin {
    fn pick(&mut self, _states: &[TaskState], ready: &[usize]) -> usize {
        let next = self
            .last
            .and_then(|l| ready.iter().copied().filter(|&i| i > l).min())
            // detlint: allow(R001) pick() contract: callers never pass an empty ready set
            .unwrap_or_else(|| ready.iter().copied().min().expect("ready is non-empty"));
        self.last = Some(next);
        next
    }

    fn fresh(&self) -> Option<Box<dyn SchedPolicy + Send>> {
        Some(Box::new(RoundRobin::new()))
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Key-ordered policy core shared by [`FewestRoundsFirst`] and
/// [`StalenessPriority`]: a lazy-deletion min-heap over `(key, index)`.
///
/// `task_ran` pushes the task's fresh key without hunting down the old
/// entry; `pick` pops until the top entry's key still matches the task's
/// current key and the task is live — O(log N) amortized (each stale
/// entry is popped exactly once). Without `prepare` the heap is empty
/// and `pick` answers with the original O(|ready|) scan, which doubles
/// as the equivalence oracle (`heap_policies_match_scan_reference`).
#[derive(Clone, Debug, Default)]
struct KeyHeap {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>>,
    /// `queued[i]`: task i has exactly one live entry in the heap.
    queued: Vec<bool>,
    prepared: bool,
}

impl KeyHeap {
    fn prepare(&mut self, n: usize, ready: &[usize], key: impl Fn(usize) -> u64) {
        self.heap.clear();
        self.queued = vec![false; n];
        self.prepared = true;
        for &i in ready {
            self.heap.push(std::cmp::Reverse((key(i), i)));
            self.queued[i] = true;
        }
    }

    fn push(&mut self, task: usize, key: u64) {
        if self.prepared {
            self.heap.push(std::cmp::Reverse((key, task)));
            self.queued[task] = true;
        }
    }

    /// Pop the live minimum, or None when unprepared / drained.
    fn pop_min(&mut self, key: impl Fn(usize) -> u64) -> Option<usize> {
        if !self.prepared {
            return None;
        }
        while let Some(std::cmp::Reverse((k, i))) = self.heap.pop() {
            if self.queued.get(i).copied().unwrap_or(false) && key(i) == k {
                self.queued[i] = false;
                return Some(i);
            }
            // stale: superseded by a later push or consumed — drop it
        }
        None
    }
}

/// Progress fairness: the ready task with the fewest completed rounds
/// (ties: smallest index). Keeps heterogeneous-length sessions aligned.
///
/// Heap-backed through the [`SchedPolicy`] lifecycle hooks — O(log N)
/// per pick on prepared drivers, with the original scan as the
/// unprepared fallback (and the pinned reference).
#[derive(Clone, Debug, Default)]
pub struct FewestRoundsFirst {
    heap: KeyHeap,
}

impl FewestRoundsFirst {
    pub fn new() -> FewestRoundsFirst {
        FewestRoundsFirst::default()
    }
}

impl SchedPolicy for FewestRoundsFirst {
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize {
        self.heap
            .pop_min(|i| states[i].rounds_done as u64)
            .unwrap_or_else(|| {
                ready
                    .iter()
                    .copied()
                    .min_by_key(|&i| (states[i].rounds_done, i))
                    // detlint: allow(R001) pick() contract: ready is non-empty
                    .expect("ready is non-empty")
            })
    }

    fn prepare(&mut self, states: &[TaskState], ready: &[usize]) {
        self.heap.prepare(states.len(), ready, |i| states[i].rounds_done as u64);
    }

    fn task_ran(&mut self, task: usize, states: &[TaskState]) {
        self.heap.push(task, states[task].rounds_done as u64);
    }

    fn fresh(&self) -> Option<Box<dyn SchedPolicy + Send>> {
        Some(Box::new(FewestRoundsFirst::new()))
    }

    fn name(&self) -> &'static str {
        "fewest-rounds-first"
    }
}

/// Staleness priority: the ready task that has waited longest since it
/// last ran — the smallest [`TaskState::last_run`] (ties: smallest
/// index; a never-run task has `last_run` 0 and outranks everything).
/// Bounds per-session latency when the ready set churns.
///
/// Heap-backed exactly like [`FewestRoundsFirst`]; `last_run` only moves
/// forward, so each pick invalidates at most one heap entry.
#[derive(Clone, Debug, Default)]
pub struct StalenessPriority {
    heap: KeyHeap,
}

impl StalenessPriority {
    pub fn new() -> StalenessPriority {
        StalenessPriority::default()
    }
}

impl SchedPolicy for StalenessPriority {
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize {
        self.heap.pop_min(|i| states[i].last_run).unwrap_or_else(|| {
            ready
                .iter()
                .copied()
                .min_by_key(|&i| (states[i].last_run, i))
                // detlint: allow(R001) pick() contract: ready is non-empty
                .expect("ready is non-empty")
        })
    }

    fn prepare(&mut self, states: &[TaskState], ready: &[usize]) {
        self.heap.prepare(states.len(), ready, |i| states[i].last_run);
    }

    fn task_ran(&mut self, task: usize, states: &[TaskState]) {
        self.heap.push(task, states[task].last_run);
    }

    fn fresh(&self) -> Option<Box<dyn SchedPolicy + Send>> {
        Some(Box::new(StalenessPriority::new()))
    }

    fn name(&self) -> &'static str {
        "priority-by-staleness"
    }
}

/// Pick under `policy` and validate the choice against `ready`.
///
/// The shared dispatch seam for every policy consumer (the session
/// [`Fleet`] and the FL orchestrator): a misbehaving custom policy must
/// fail loudly here instead of hanging a drain loop or indexing out of
/// bounds in release builds, where a `debug_assert!` would vanish.
/// `ready` is sorted ascending (the [`SchedPolicy`] contract), so the
/// membership check is a binary search, not a scan. A bad pick is a
/// typed [`Error::Sched`] — schedulers misbehaving are a different
/// failure class from sessions failing, and supervision must not treat
/// one as the other.
pub fn pick_validated(
    policy: &mut dyn SchedPolicy,
    states: &[TaskState],
    ready: &[usize],
) -> Result<usize> {
    debug_assert!(ready.windows(2).all(|w| w[0] < w[1]), "ready must be sorted");
    let idx = policy.pick(states, ready);
    if ready.binary_search(&idx).is_err() {
        return Err(Error::Sched(format!(
            "policy {:?} picked non-ready task {idx} (ready: {ready:?})",
            policy.name()
        )));
    }
    Ok(idx)
}

/// Parse a policy by its CLI name.
pub fn parse_policy(name: &str) -> Result<Box<dyn SchedPolicy>> {
    match name {
        "rr" | "round-robin" => Ok(Box::new(RoundRobin::new())),
        "fewest" | "fewest-rounds-first" => Ok(Box::new(FewestRoundsFirst::new())),
        "staleness" | "priority-by-staleness" => Ok(Box::new(StalenessPriority::new())),
        other => Err(Error::Config(format!(
            "unknown scheduling policy {other:?} (rr|fewest|staleness)"
        ))),
    }
}

/// Fleet-level observer: sees every session's rounds in the order the
/// scheduler interleaves them. Per-session
/// [`RoundObserver`](crate::coordinator::session::RoundObserver)s still
/// fire inside each session; this is the cross-session fan-out
/// (dashboards, fleet-wide audits).
pub trait FleetObserver {
    /// One session completed one round.
    fn on_session_round(&mut self, _session: usize, _name: &str, _outcome: &RoundOutcome) {}

    /// One session finished its run.
    fn on_session_finished(&mut self, _session: usize, _name: &str, _record: &RunRecord) {}

    /// The fault plan fired `kind` (see [`FaultKind::name`]) against a
    /// session at its `round`.
    fn on_fault(&mut self, _session: usize, _name: &str, _round: usize, _kind: &str) {}

    /// Supervision gave up on a session: it is out of the fleet with no
    /// final record.
    fn on_session_quarantined(&mut self, _session: usize, _name: &str, _round: usize, _reason: &str) {
    }

    /// A session resumed **degraded**: its checkpoint vault rejected
    /// frames (torn/checksum) or fell back past the newest generation —
    /// possibly all the way to a fresh start. Fired once per degraded
    /// resume (at fleet assembly, or mid-run on a supervised restart);
    /// clean resumes are silent.
    fn on_recovery(&mut self, _session: usize, _name: &str, _telemetry: &RecoveryTelemetry) {}
}

/// Built-in fleet observer: logs interleaving progress at debug level.
pub struct FleetProgress {
    every: usize,
    steps: usize,
}

impl FleetProgress {
    /// Log every `every` interleaved rounds (0 = finishes only).
    pub fn every(every: usize) -> FleetProgress {
        FleetProgress { every, steps: 0 }
    }
}

impl FleetObserver for FleetProgress {
    fn on_session_round(&mut self, session: usize, name: &str, outcome: &RoundOutcome) {
        self.steps += 1;
        if self.every > 0 && self.steps % self.every == 0 {
            log::debug!(
                "fleet step {:>6}: session {session} ({name}) round {} loss {:.4}",
                self.steps,
                outcome.round + 1,
                outcome.train_loss
            );
        }
    }

    fn on_session_finished(&mut self, session: usize, name: &str, record: &RunRecord) {
        log::debug!(
            "fleet: session {session} ({name}) finished, final acc {:.2}%",
            record.final_accuracy * 100.0
        );
    }
}

/// Rebuilds a member's [`SessionBuilder`] from scratch for
/// [`SupervisionPolicy::Restart`]: same config, same backend, an
/// identically constructed data source. Determinism of the fleet under
/// restarts is exactly the determinism of this closure. `Send` because a
/// restartable member travels to (and between) shard workers with its
/// factory attached.
pub type SessionFactory = Box<dyn Fn() -> Result<SessionBuilder> + Send>;

/// One member's checkpoint wiring: vault base path, snapshot cadence,
/// and how many generations the vault retains (`keep` = 1 is the
/// historical single-file discipline; ≥ 2 keeps checksummed `.g<N>`
/// frames a restart can fall back through).
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    pub path: PathBuf,
    pub every: usize,
    pub keep: usize,
}

impl CheckpointSpec {
    /// The vault this member writes through and resumes from.
    pub fn vault(&self) -> crate::coordinator::vault::CheckpointVault {
        crate::coordinator::vault::CheckpointVault::new(&self.path, self.keep)
    }
}

/// Builder for a [`Fleet`]: named sessions + policy + fleet observers.
///
/// Members are stored as **un-built** [`SessionBuilder`] recipes
/// (validated on add — see [`SessionBuilder::validate`]) and materialized
/// by the host that runs them: the single-thread host builds everything
/// up front, the sharded host builds each member on the worker that
/// admits it, which is what makes members movable (and stealable) across
/// shard threads.
pub struct FleetBuilder {
    names: Vec<String>,
    builders: Vec<SessionBuilder>,
    /// Index-aligned with `builders`: how to rebuild each member
    /// (restart supervision); None = not restartable.
    factories: Vec<Option<SessionFactory>>,
    /// Index-aligned with `builders`: each member's checkpoint wiring;
    /// None = not checkpointed.
    checkpoints: Vec<Option<CheckpointSpec>>,
    /// Index-aligned with `builders`: telemetry from a **degraded**
    /// assembly-time resume (vault fell back past a bad artifact); None
    /// for clean resumes and fresh starts.
    recoveries: Vec<Option<RecoveryTelemetry>>,
    policy: Box<dyn SchedPolicy>,
    supervise: SupervisionPolicy,
    fault_plan: Option<FaultPlan>,
    observers: Vec<Box<dyn FleetObserver>>,
    host_threads: usize,
    keep_checkpoints: usize,
}

impl FleetBuilder {
    pub fn new() -> FleetBuilder {
        FleetBuilder {
            names: Vec::new(),
            builders: Vec::new(),
            factories: Vec::new(),
            checkpoints: Vec::new(),
            recoveries: Vec::new(),
            policy: Box::new(RoundRobin::new()),
            supervise: SupervisionPolicy::FailFast,
            fault_plan: None,
            observers: Vec::new(),
            host_threads: 1,
            keep_checkpoints: 1,
        }
    }

    /// Add a session under a display name; repeatable. Sessions build and
    /// start lazily, so assembling a large fleet is cheap; an invalid
    /// builder surfaces from [`FleetBuilder::build`], which validates
    /// every member by name.
    pub fn session(mut self, name: impl Into<String>, builder: SessionBuilder) -> Self {
        self.names.push(name.into());
        self.builders.push(builder);
        self.factories.push(None);
        self.checkpoints.push(None);
        self.recoveries.push(None);
        self
    }

    /// Add a session [`SupervisionPolicy::Restart`] can rebuild: the
    /// factory must reassemble the member's [`SessionBuilder`] from
    /// scratch (same config, same backend, identically constructed data
    /// source). Without a checkpoint the rebuilt member restarts from
    /// round 0 — deterministic sessions reproduce the lost rounds
    /// exactly; pair with
    /// [`FleetBuilder::session_checkpointed_restartable`] to resume from
    /// the latest snapshot instead.
    pub fn session_restartable(
        mut self,
        name: impl Into<String>,
        factory: impl Fn() -> Result<SessionBuilder> + Send + 'static,
    ) -> Result<Self> {
        let builder = factory()?;
        builder.validate()?;
        self.names.push(name.into());
        self.builders.push(builder);
        self.factories.push(Some(Box::new(factory)));
        self.checkpoints.push(None);
        self.recoveries.push(None);
        Ok(self)
    }

    /// Add a session that checkpoints to `path` every `every` rounds,
    /// and — when `resume` is set — restarts from the snapshot already
    /// at `path`, so a killed `titan fleet` run picks each member back
    /// up **at its own saved round**:
    ///
    /// - no file at `path` (or `resume` unset): the member starts fresh;
    /// - a mid-run snapshot: the member resumes from it (the snapshot's
    ///   config fingerprint must match `builder`'s config — mismatches
    ///   error instead of silently diverging);
    /// - a completion marker **for the same config**: the member already
    ///   finished, so it is **skipped** (logged at info level), and the
    ///   resumed fleet runs only the unfinished members. A completion
    ///   marker whose recorded config does not match `builder`'s errors
    ///   like a mismatched mid-run snapshot would — skipping it would
    ///   silently drop a run the user actually asked for.
    pub fn session_checkpointed(
        self,
        name: impl Into<String>,
        builder: SessionBuilder,
        path: impl Into<PathBuf>,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        self.add_checkpointed(name.into(), builder, None, path.into(), every, resume)
    }

    /// [`FleetBuilder::session_checkpointed`] + a rebuild factory: under
    /// [`SupervisionPolicy::Restart`] a failed member is reassembled from
    /// the factory and resumed from the latest valid snapshot at `path`
    /// (falling back to a fresh start when the file is corrupt or
    /// missing), so recovery costs only the rounds since the last
    /// checkpoint cadence.
    pub fn session_checkpointed_restartable(
        self,
        name: impl Into<String>,
        factory: impl Fn() -> Result<SessionBuilder> + Send + 'static,
        path: impl Into<PathBuf>,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        let builder = factory()?;
        self.add_checkpointed(
            name.into(),
            builder,
            Some(Box::new(factory)),
            path.into(),
            every,
            resume,
        )
    }

    fn add_checkpointed(
        mut self,
        name: String,
        builder: SessionBuilder,
        factory: Option<SessionFactory>,
        path: PathBuf,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        let spec = CheckpointSpec { path, every, keep: self.keep_checkpoints };
        let vault = spec.vault();
        let mut builder = builder;
        let mut recovery = None;
        if resume && vault.has_artifacts() {
            let (loaded, telemetry) = load_vault_checkpoint(&vault)?;
            match loaded {
                Loaded::Resumable(snap) => {
                    log::info!(
                        "fleet: resuming {name:?} from {} at round {}{}",
                        spec.path.display(),
                        snap.round,
                        if telemetry.degraded() {
                            format!(
                                " (degraded: generation {}, {} rounds lost)",
                                telemetry.generation_used, telemetry.rounds_lost
                            )
                        } else {
                            String::new()
                        }
                    );
                    builder = builder.resume_from_snapshot(*snap);
                    if telemetry.degraded() {
                        recovery = Some(telemetry);
                    }
                }
                Loaded::Complete { round, config, .. } => {
                    // Json::Null means the run finished before its first
                    // cadence snapshot — no config to verify against
                    if config != Json::Null
                        && config.to_string_compact() != builder.cfg().fingerprint()
                    {
                        return Err(Error::Config(format!(
                            "{}: completion marker belongs to a differently configured \
                             run — refusing to skip {name:?} (delete the file to start over)",
                            spec.path.display()
                        )));
                    }
                    log::info!(
                        "fleet: {name:?} already finished ({round} rounds per {}), skipping",
                        spec.path.display()
                    );
                    return Ok(self);
                }
            }
        }
        let builder =
            builder.observe(Checkpoint::every(spec.path.clone(), spec.every).keep(spec.keep));
        builder.validate()?;
        self.names.push(name);
        self.builders.push(builder);
        self.factories.push(factory);
        self.checkpoints.push(Some(spec));
        self.recoveries.push(recovery);
        Ok(self)
    }

    /// Sessions added so far (resume may skip completed members — see
    /// [`FleetBuilder::session_checkpointed`] — so a caller can detect an
    /// everything-already-finished resume before `build` errors on an
    /// empty fleet).
    pub fn len(&self) -> usize {
        self.builders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.builders.is_empty()
    }

    /// Replace the default round-robin policy.
    pub fn policy(mut self, policy: impl SchedPolicy + 'static) -> Self {
        self.policy = Box::new(policy);
        self
    }

    /// Replace the policy with an already-boxed one (CLI parsing).
    pub fn policy_boxed(mut self, policy: Box<dyn SchedPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// What the scheduler does when a session fails (injected or real).
    /// Default: [`SupervisionPolicy::FailFast`], the historical
    /// abort-the-fleet behavior.
    pub fn supervise(mut self, policy: SupervisionPolicy) -> Self {
        self.supervise = policy;
        self
    }

    /// Attach a deterministic fault-injection plan; validated at
    /// [`Fleet::run`]. A zero-rate plan injects nothing and leaves every
    /// deterministic output bit-identical to an unfaulted fleet.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Attach a fleet observer; repeatable, invoked in attach order.
    pub fn observe(mut self, observer: impl FleetObserver + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Worker threads for the fleet host (clamped to ≥ 1; default 1, the
    /// single-thread reference host). With `t > 1` sessions are
    /// partitioned into `t` shards by [`shard_of`] and stepped at **op**
    /// granularity on `t` scoped worker threads with work stealing; every
    /// per-session [`RunRecord`] and every deterministic [`FleetRecord`]
    /// field is bit-identical across thread counts (see the module docs).
    pub fn host_threads(mut self, threads: usize) -> Self {
        self.host_threads = threads.max(1);
        self
    }

    /// Checkpoint generations each member's vault retains (clamped to
    /// ≥ 1; default 1, the historical bare-file layout with bit-identical
    /// bytes on disk). With `keep ≥ 2` snapshots are written as
    /// checksummed `.g<N>` frames and a restart whose newest generation
    /// is torn or bit-flipped falls back to the previous one instead of
    /// round 0. Applies to members added **after** this call, so set it
    /// before `session_checkpointed*`.
    pub fn keep_checkpoints(mut self, keep: usize) -> Self {
        self.keep_checkpoints = keep.max(1);
        self
    }

    /// Assemble the fleet. Errors on an empty session list, and surfaces
    /// the first invalid member ([`SessionBuilder::validate`]) by name —
    /// members build lazily on the host that runs them, so this is the
    /// last pre-run moment that can name a misconfigured session cheaply.
    pub fn build(self) -> Result<Fleet> {
        if self.builders.is_empty() {
            return Err(Error::Config("fleet needs at least one session".into()));
        }
        for (name, builder) in self.names.iter().zip(&self.builders) {
            builder
                .validate()
                .map_err(|e| Error::Config(format!("fleet session {name:?}: {e}")))?;
        }
        Ok(Fleet {
            names: self.names,
            builders: self.builders,
            factories: self.factories,
            checkpoints: self.checkpoints,
            recoveries: self.recoveries,
            policy: self.policy,
            supervise: self.supervise,
            fault_plan: self.fault_plan,
            observers: self.observers,
            host_threads: self.host_threads,
        })
    }

    /// Build and run in one step.
    pub fn run(self) -> Result<FleetRecord> {
        self.build()?.run()
    }
}

impl Default for FleetBuilder {
    fn default() -> Self {
        FleetBuilder::new()
    }
}

/// N session recipes interleaved under one [`SchedPolicy`] — round per
/// tick on the single-thread host, op per tick on the sharded host.
pub struct Fleet {
    names: Vec<String>,
    builders: Vec<SessionBuilder>,
    factories: Vec<Option<SessionFactory>>,
    checkpoints: Vec<Option<CheckpointSpec>>,
    recoveries: Vec<Option<RecoveryTelemetry>>,
    policy: Box<dyn SchedPolicy>,
    supervise: SupervisionPolicy,
    fault_plan: Option<FaultPlan>,
    observers: Vec<Box<dyn FleetObserver>>,
    host_threads: usize,
}

impl Fleet {
    pub fn len(&self) -> usize {
        self.builders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.builders.is_empty()
    }

    /// Drive every session to a terminal state under the configured
    /// supervision policy: one round per scheduler tick on the
    /// single-thread host, one **op** per worker tick on the sharded host
    /// ([`FleetBuilder::host_threads`]). Both produce bit-identical
    /// deterministic outputs; wall-clock fields vary.
    ///
    /// Under [`SupervisionPolicy::FailFast`] (the default) a session
    /// error aborts the whole fleet (the scheduler acting as a
    /// single-tenant research runtime, not an isolator) and the error
    /// names the session that failed — the historical contract, byte for
    /// byte. `Isolate` and `Restart` turn failures into per-session
    /// [`SessionStatus`]es instead and the fleet runs to completion.
    pub fn run(self) -> Result<FleetRecord> {
        if let Some(plan) = &self.fault_plan {
            plan.validate()?;
        }
        if self.host_threads > 1 {
            self.run_sharded()
        } else {
            self.run_single()
        }
    }

    /// The single-thread reference host: materialize every member up
    /// front, then the historical round-per-tick scheduler loop. This is
    /// the determinism oracle the sharded host is pinned against.
    fn run_single(mut self) -> Result<FleetRecord> {
        let n = self.builders.len();
        let fleet_sw = Stopwatch::start();
        let mut sessions: Vec<Box<Session>> = Vec::with_capacity(n);
        for (i, builder) in std::mem::take(&mut self.builders).into_iter().enumerate() {
            let session = builder.build().map_err(|e| {
                Error::Pipeline(format!(
                    "fleet session {:?}: failed to build: {e}",
                    self.names[i]
                ))
            })?;
            sessions.push(Box::new(session));
        }
        let mut states = vec![TaskState::default(); n];
        let mut records: Vec<Option<RunRecord>> = (0..n).map(|_| None).collect();
        let mut statuses: Vec<Option<SessionStatus>> = vec![None; n];
        let mut ready: Vec<usize> = (0..n).collect();
        // restart backoff: (scheduler tick at which the session re-enters
        // the ready set, session index)
        let mut parked: Vec<(u64, usize)> = Vec::new();
        let mut restarts_used = vec![0usize; n];
        // (session, session-round) cells whose fault already fired: a
        // Transient clears on retry, and a restarted member replaying
        // earlier rounds does not re-crash on the same cell
        let mut fired: HashSet<(usize, usize)> = HashSet::new();
        let mut faults = FaultTelemetry::default();
        // per-session vault-recovery telemetry, seeded with degraded
        // assembly-time resumes and merged with restart-time recoveries;
        // surfaced on the member's record and the fleet aggregate
        let mut recoveries = std::mem::take(&mut self.recoveries);
        for (i, t) in recoveries.iter().enumerate() {
            if let Some(t) = t {
                for obs in self.observers.iter_mut() {
                    obs.on_recovery(i, &self.names[i], t);
                }
            }
        }
        let mut rounds_executed = 0usize;
        let mut device_ops = 0u64;
        let mut step_ms = 0.0f64;
        // scheduler clock for staleness: one O(1) last_run write per tick
        // replaces the old all-tasks aging pass (O(N) per round)
        let mut tick = 0u64;
        self.policy.prepare(&states, &ready);

        loop {
            // re-admit parked (restarting) sessions whose backoff elapsed;
            // with nothing ready, jump the clock to the next wake-up. The
            // clock is scheduler ticks, so backoff is simulation-
            // deterministic — no wall time involved.
            if !parked.is_empty() {
                if ready.is_empty() {
                    let wake = parked
                        .iter()
                        .map(|&(at, _)| at)
                        .min()
                        // detlint: allow(R001) guarded by the !parked.is_empty() branch above
                        .expect("parked is non-empty");
                    tick = tick.max(wake);
                }
                if parked.iter().any(|&(at, _)| at <= tick) {
                    let mut due: Vec<usize> = parked
                        .iter()
                        .filter(|&&(at, _)| at <= tick)
                        .map(|&(_, i)| i)
                        .collect();
                    parked.retain(|&(at, _)| at > tick);
                    due.sort_unstable();
                    for i in due {
                        if let Err(pos) = ready.binary_search(&i) {
                            ready.insert(pos, i);
                        }
                    }
                    self.policy.prepare(&states, &ready);
                }
            }
            if ready.is_empty() {
                break;
            }

            let idx = pick_validated(self.policy.as_mut(), &states, &ready)?;

            // fault injection, keyed on the session's own round (not the
            // fleet tick) so the plan names cells a user can reason
            // about; skipped on the finishing step, which runs no round
            let session_round = sessions[idx].rounds_completed();
            let fault = self
                .fault_plan
                .as_ref()
                .filter(|_| session_round < sessions[idx].cfg().rounds)
                .and_then(|plan| plan.fault_for(idx, session_round))
                .filter(|_| fired.insert((idx, session_round)));
            if let Some(kind) = fault {
                faults.record(idx, session_round, &kind);
                for obs in self.observers.iter_mut() {
                    obs.on_fault(idx, &self.names[idx], session_round, kind.name());
                }
                match kind {
                    FaultKind::Transient => {
                        // clears on retry: the session stays ready, but
                        // the pick consumed the policy's indexed entry
                        self.policy.prepare(&states, &ready);
                        continue;
                    }
                    FaultKind::Straggler { slowdown } => {
                        sessions[idx].inject_slowdown(slowdown);
                    }
                    FaultKind::EnergyBrownout { joules } => {
                        sessions[idx].inject_brownout(joules);
                    }
                    FaultKind::Crash => {
                        self.handle_failure(
                            idx,
                            session_round,
                            "injected crash".into(),
                            tick,
                            &mut sessions,
                            &states,
                            &mut ready,
                            &mut parked,
                            &mut statuses,
                            &mut restarts_used,
                            &mut faults,
                            &mut recoveries,
                        )?;
                        continue;
                    }
                    // every remaining kind damages the on-disk checkpoint
                    other => {
                        let seed = self
                            .fault_plan
                            .as_ref()
                            .map_or(0, |p| p.corruption_seed(idx, session_round));
                        inject_checkpoint_fault(&other, self.checkpoints[idx].as_ref(), seed);
                    }
                }
            }

            let step_sw = Stopwatch::start();
            let stepped = sessions[idx].step();
            // detlint: allow(D004) host-profiling accumulator; *_ms fields are diff-ignored
            step_ms += step_sw.elapsed_ms();
            let event = match stepped {
                Ok(event) => event,
                Err(e) => {
                    self.handle_failure(
                        idx,
                        session_round,
                        e.to_string(),
                        tick,
                        &mut sessions,
                        &states,
                        &mut ready,
                        &mut parked,
                        &mut statuses,
                        &mut restarts_used,
                        &mut faults,
                        &mut recoveries,
                    )?;
                    continue;
                }
            };
            match event {
                StepEvent::RoundCompleted(outcome) => {
                    states[idx].rounds_done += 1;
                    tick += 1;
                    states[idx].last_run = tick;
                    self.policy.task_ran(idx, &states);
                    rounds_executed += 1;
                    // +1: the round's TrainStep on the CPU lane (selector
                    // ops are the GPU-lane charge)
                    device_ops += outcome.selector.ops.len() as u64 + 1;
                    for obs in self.observers.iter_mut() {
                        obs.on_session_round(idx, &self.names[idx], &outcome);
                    }
                    // drain the outcome the session retained: the fleet
                    // surface for per-round data is the observer fan-out,
                    // and keeping N x R outcomes alive across in-flight
                    // sessions would grow with fleet size
                    sessions[idx].take_outcomes();
                }
                StepEvent::Finished(mut record) => {
                    // stamp accumulated vault-recovery telemetry so the
                    // member's record says how it got here
                    record.recovery = recoveries[idx].clone();
                    for obs in self.observers.iter_mut() {
                        obs.on_session_finished(idx, &self.names[idx], &record);
                    }
                    records[idx] = Some(record);
                    statuses[idx] = Some(SessionStatus::Finished);
                    ready.retain(|&i| i != idx);
                }
            }
        }

        // every session that left the ready set carries a terminal
        // status; a scheduler bug that dropped one reports as quarantined
        // instead of panicking the whole fleet
        let statuses: Vec<SessionStatus> = statuses
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.unwrap_or_else(|| SessionStatus::Quarantined {
                    round: states[i].rounds_done,
                    reason: "scheduler exited without a terminal status".into(),
                })
            })
            .collect();
        let total_host_ms = fleet_sw.elapsed_ms();
        // canonical (session, round) event order — shared with the
        // sharded host, whose workers log concurrently
        faults.events.sort_unstable_by_key(|e| (e.session, e.round));
        let finished = records.iter().flatten();
        // fleet-wide retention aggregate: component-wise sum over the
        // finished members that retained; None when no member did
        let retention = finished
            .clone()
            .filter_map(|r| r.retention.as_ref())
            .fold(None, |acc: Option<crate::retention::RetentionTelemetry>, t| {
                let mut sum = acc.unwrap_or_default();
                sum.merge(t);
                Some(sum)
            });
        Ok(FleetRecord {
            policy: self.policy.name().to_string(),
            supervision: self.supervise.name().to_string(),
            names: self.names,
            session_rounds: states.iter().map(|s| s.rounds_done).collect(),
            rounds_executed,
            device_ops,
            total_device_ms: finished.clone().map(|r| r.total_device_ms).sum(),
            energy_j: finished.clone().map(|r| r.energy_j).sum(),
            peak_memory_bytes: finished.map(|r| r.peak_memory_bytes).sum(),
            records,
            statuses,
            faults,
            fault_plan: self.fault_plan.as_ref().map(|p| p.to_json()),
            retention,
            recovery: merge_recoveries(&recoveries),
            total_host_ms,
            sched_overhead_ms: (total_host_ms - step_ms).max(0.0),
            host_threads: 1,
            steals: 0,
            shards: Vec::new(),
        })
    }

    /// Apply the supervision policy to one failed session. `FailFast`
    /// returns the historical fleet-aborting error; `Isolate` and
    /// `Restart` mutate the scheduler state and return `Ok`.
    #[allow(clippy::too_many_arguments)]
    fn handle_failure(
        &mut self,
        idx: usize,
        round: usize,
        reason: String,
        tick: u64,
        sessions: &mut [Box<Session>],
        states: &[TaskState],
        ready: &mut Vec<usize>,
        parked: &mut Vec<(u64, usize)>,
        statuses: &mut [Option<SessionStatus>],
        restarts_used: &mut [usize],
        faults: &mut FaultTelemetry,
        recoveries: &mut [Option<RecoveryTelemetry>],
    ) -> Result<()> {
        match self.supervise {
            SupervisionPolicy::FailFast => {
                Err(Error::Pipeline(format!("fleet session {:?}: {reason}", self.names[idx])))
            }
            SupervisionPolicy::Isolate => {
                self.quarantine(idx, round, reason, ready, statuses, faults);
                self.policy.prepare(states, ready);
                Ok(())
            }
            SupervisionPolicy::Restart { max_retries, backoff_rounds, backoff_cap } => {
                if restarts_used[idx] >= max_retries {
                    let reason = format!("{reason} ({max_retries} restarts exhausted)");
                    self.quarantine(idx, round, reason, ready, statuses, faults);
                } else {
                    let rebuilt = rebuild_builder(
                        self.factories[idx].as_ref(),
                        self.checkpoints[idx].as_ref(),
                    )
                    .and_then(|(builder, resumed, rec)| Ok((builder.build()?, resumed, rec)));
                    match rebuilt {
                        Ok((session, resumed_round, rec)) => {
                            sessions[idx] = Box::new(session);
                            // capped exponential backoff: attempt 0 waits
                            // the base, each retry doubles up to the cap
                            let delay =
                                restart_backoff(backoff_rounds, backoff_cap, restarts_used[idx]);
                            restarts_used[idx] += 1;
                            faults.restarts += 1;
                            faults.rounds_recovered += round.saturating_sub(resumed_round);
                            if let Some(t) = rec {
                                for obs in self.observers.iter_mut() {
                                    obs.on_recovery(idx, &self.names[idx], &t);
                                }
                                recoveries[idx]
                                    .get_or_insert_with(RecoveryTelemetry::default)
                                    .merge(&t);
                            }
                            log::info!(
                                "fleet: restarting session {:?} from round {resumed_round} \
                                 (failed at {round}: {reason}; retry {}/{max_retries}, \
                                 backoff {delay} ticks)",
                                self.names[idx],
                                restarts_used[idx],
                            );
                            ready.retain(|&i| i != idx);
                            parked.push((tick + delay, idx));
                        }
                        Err(e) => {
                            let reason = format!("{reason}; restart failed: {e}");
                            self.quarantine(idx, round, reason, ready, statuses, faults);
                        }
                    }
                }
                self.policy.prepare(states, ready);
                Ok(())
            }
        }
    }

    /// Remove a session from scheduling with a terminal
    /// [`SessionStatus::Quarantined`]; the rest of the fleet keeps
    /// running.
    fn quarantine(
        &mut self,
        idx: usize,
        round: usize,
        reason: String,
        ready: &mut Vec<usize>,
        statuses: &mut [Option<SessionStatus>],
        faults: &mut FaultTelemetry,
    ) {
        log::warn!(
            "fleet: quarantining session {:?} at round {round}: {reason}",
            self.names[idx]
        );
        for obs in self.observers.iter_mut() {
            obs.on_session_quarantined(idx, &self.names[idx], round, &reason);
        }
        statuses[idx] = Some(SessionStatus::Quarantined { round, reason });
        ready.retain(|&i| i != idx);
        faults.quarantines += 1;
    }

}

/// Rebuild a failed member's [`SessionBuilder`] from its factory for
/// restart supervision, resuming through its checkpoint vault when it has
/// one: the newest valid generation wins, a torn/bit-flipped newest falls
/// back to an older frame, and a vault with nothing usable degrades to a
/// fresh start — deterministic sessions reproduce the lost rounds
/// exactly. Returns the recipe, the round it will start from, and the
/// recovery telemetry when the resume was degraded. Shared by both hosts:
/// single-thread restarts build the result in place, shard workers
/// re-queue it as a cold member.
fn rebuild_builder(
    factory: Option<&SessionFactory>,
    checkpoint: Option<&CheckpointSpec>,
) -> Result<(SessionBuilder, usize, Option<RecoveryTelemetry>)> {
    let Some(factory) = factory else {
        return Err(Error::Config(
            "no session factory registered (use session_restartable / \
             session_checkpointed_restartable)"
                .into(),
        ));
    };
    let mut builder = factory()?;
    let mut resumed_round = 0usize;
    let mut recovery = None;
    if let Some(spec) = checkpoint {
        let vault = spec.vault();
        if vault.has_artifacts() {
            let (winner, mut telemetry) = vault.load_latest_valid();
            let walk_failed = winner.is_err();
            match winner
                .and_then(|w| load_checkpoint_str(&w.text, &w.path.display().to_string()))
            {
                Ok(Loaded::Resumable(snap)) => {
                    resumed_round = snap.round;
                    builder = builder.resume_from_snapshot(*snap);
                }
                Ok(Loaded::Complete { .. }) => {
                    log::warn!(
                        "fleet: {} marks a completed run but the session failed — \
                         restarting from scratch",
                        spec.path.display()
                    );
                }
                Err(e) => {
                    log::warn!("fleet: discarding unusable checkpoint: {e}");
                    if !walk_failed {
                        // the generation that won the vault walk was still
                        // unusable downstream (typed parse failure): count
                        // it so the fresh start reads as degraded
                        telemetry.crc_failures += 1;
                    }
                }
            }
            if telemetry.degraded() {
                recovery = Some(telemetry);
            }
        }
        builder = builder.observe(Checkpoint::every(spec.path.clone(), spec.every).keep(spec.keep));
    }
    Ok((builder, resumed_round, recovery))
}

/// Route an injected checkpoint-corruption fault ([`FaultKind`] variants
/// with [`FaultKind::corrupts_checkpoint`]) through the vault's
/// deterministic injector seam; a member without checkpoint wiring makes
/// this a no-op — there is nothing on disk to damage.
fn inject_checkpoint_fault(kind: &FaultKind, checkpoint: Option<&CheckpointSpec>, seed: u64) {
    debug_assert!(kind.corrupts_checkpoint(), "not a corruption fault: {kind:?}");
    let Some(spec) = checkpoint else { return };
    vault::inject_corruption(kind, &spec.path, seed);
}

/// Fleet-wide recovery aggregate: component-wise sum over the members
/// that resumed degraded ([`RecoveryTelemetry::merge`]); None when every
/// resume was clean.
fn merge_recoveries(recoveries: &[Option<RecoveryTelemetry>]) -> Option<RecoveryTelemetry> {
    recoveries.iter().flatten().fold(None, |acc: Option<RecoveryTelemetry>, t| {
        let mut sum = acc.unwrap_or_default();
        sum.merge(t);
        Some(sum)
    })
}

/// Stable session-index → shard map (the splitmix64 finalizer over the
/// index, reduced mod `threads`): uniform across shard counts, and a pure
/// function of `(idx, threads)`, so a fleet's home-shard layout is
/// reproducible without running anything.
pub fn shard_of(idx: usize, threads: usize) -> usize {
    let mut z = (idx as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % threads.max(1) as u64) as usize
}

/// Per-shard scheduler accounting for one sharded fleet run
/// ([`FleetRecord::shards`]). Wall-clock fields (`host_ms`, `step_ms`,
/// `sched_overhead_ms`) and the steal counters vary run to run — they
/// describe the host, not the simulation — while the per-session records
/// the shard produced stay bit-identical.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Shard index (== worker thread index).
    pub shard: usize,
    /// Sessions this worker admitted (home members plus stolen-in ones,
    /// counting each restart re-admission).
    pub sessions: usize,
    /// Scheduler ticks the worker executed (one session op each).
    pub ops: u64,
    /// Rounds completed on this shard.
    pub rounds: usize,
    /// Cold members this worker stole from other shards' queues.
    pub steals_in: u64,
    /// Cold members other workers stole from this shard's queue.
    pub steals_out: u64,
    /// Worker wall clock (ms).
    pub host_ms: f64,
    /// Wall clock inside [`Session::step_op`] (ms).
    pub step_ms: f64,
    /// `host_ms − step_ms`, floored at zero: scheduling, fault injection
    /// and queue bookkeeping.
    pub sched_overhead_ms: f64,
}

impl ShardStats {
    /// Scheduling overhead amortized per scheduler tick (ms); 0 for a
    /// worker that never ran an op.
    pub fn sched_overhead_per_tick_ms(&self) -> f64 {
        if self.ops == 0 {
            0.0
        } else {
            self.sched_overhead_ms / self.ops as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("sessions", Json::Num(self.sessions as f64)),
            ("ops", Json::Num(self.ops as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("steals_in", Json::Num(self.steals_in as f64)),
            ("steals_out", Json::Num(self.steals_out as f64)),
            ("host_ms", Json::Num(self.host_ms)),
            ("step_ms", Json::Num(self.step_ms)),
            ("sched_overhead_ms", Json::Num(self.sched_overhead_ms)),
            ("sched_overhead_per_tick_ms", Json::Num(self.sched_overhead_per_tick_ms())),
        ])
    }
}

/// A not-yet-started fleet member: a `Send` recipe sitting in (and
/// movable between) shard queues. Everything a worker needs to run and
/// supervise the session travels with the member, which is what makes
/// stealing a queue splice instead of a state migration.
struct ColdMember {
    idx: usize,
    builder: SessionBuilder,
    factory: Option<SessionFactory>,
    checkpoint: Option<CheckpointSpec>,
    /// Fleet-wide admission age (initial members: their session index;
    /// restart re-queues: a shared counter). "Oldest" — the steal
    /// victim's minimum stamp — is therefore well defined fleet-wide.
    stamp: u64,
    /// Earliest owning-worker tick at which the member may be admitted
    /// (restart backoff; 0 for initial members).
    wake_at: u64,
    /// Scheduling bookkeeping carried across restarts, like the
    /// single-thread host's persistent per-session `TaskState`.
    state: TaskState,
    restarts_used: usize,
    /// Session-rounds whose injected fault already fired (a restarted
    /// member replaying earlier rounds must not re-crash on the same
    /// cell).
    fired: HashSet<usize>,
}

/// A started — and therefore worker-pinned — member. Sessions share
/// thread-local runtime state once started, so a hot member never
/// migrates; only its [`ColdMember`] form does.
struct HotMember {
    session: Box<Session>,
    factory: Option<SessionFactory>,
    checkpoint: Option<CheckpointSpec>,
    restarts_used: usize,
    fired: HashSet<usize>,
}

/// Worker → main-thread event stream: everything the (possibly
/// non-`Send`) fleet observers and the aggregate record need, in
/// per-shard completion order. The main thread owns observer fan-out and
/// record assembly; workers own stepping and supervision.
enum HostEvent {
    Round { session: usize, outcome: RoundOutcome },
    Finished { session: usize, record: Box<RunRecord> },
    Fault { session: usize, round: usize, kind: &'static str },
    Quarantined { session: usize, round: usize, reason: String },
    /// A restarted member resumed **degraded** through its vault. Sent
    /// before the member is re-queued, and the re-queue happens-before
    /// any later event for the same session, so on the main thread a
    /// `Recovery` always precedes that session's `Finished`.
    Recovery { session: usize, telemetry: RecoveryTelemetry },
}

/// Trips the shared stop flag if its worker unwinds: a panicking shard
/// must not leave the surviving workers spinning on `live > 0` forever.
struct PanicStop<'a>(&'a AtomicBool);

impl Drop for PanicStop<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Release);
        }
    }
}

/// One shard's scheduler: a worker-local policy over worker-local hot
/// members, fed from the shard's cold queue (and, when idle, from other
/// shards' queues via stealing). Indices are fleet-global throughout —
/// `states`/`hot` are full-length vectors so policies see the same
/// `TaskState` shapes as the single-thread host.
struct ShardWorker<'a> {
    shard: usize,
    supervise: SupervisionPolicy,
    plan: Option<&'a FaultPlan>,
    names: &'a [String],
    queues: &'a [Mutex<Vec<ColdMember>>],
    steals_out: &'a [AtomicU64],
    /// Fleet-wide count of members not yet in a terminal state; 0 is the
    /// shutdown signal.
    live: &'a AtomicUsize,
    stop: &'a AtomicBool,
    /// Shared stamp source for restart re-queues.
    stamps: &'a AtomicU64,
    /// FailFast failures, formatted into the fleet-aborting error by the
    /// main thread (lowest session index wins).
    failures: &'a Mutex<Vec<(usize, String)>>,
    tx: mpsc::Sender<HostEvent>,
    policy: Box<dyn SchedPolicy + Send>,
    states: Vec<TaskState>,
    hot: Vec<Option<HotMember>>,
    /// Ready hot members, sorted ascending (the policy contract).
    ready: Vec<usize>,
    /// Worker-local scheduler clock: one increment per op. Restart
    /// backoff and staleness are measured on this clock, so they are
    /// op-granular on the sharded host (round-granular on the
    /// single-thread host) — deterministic outputs do not depend on
    /// either.
    tick: u64,
    telemetry: FaultTelemetry,
    stats: ShardStats,
    step_ms: f64,
}

/// Lock a shard's cold queue, surfacing lock poisoning (a sibling
/// worker panicked while holding it) as a typed scheduler error instead
/// of a second panic. The panicking worker already carries the root
/// cause; the fleet surfaces it after joining, so a poisoned lock here
/// only needs a clean unwind, not a fresh backtrace.
fn lock_queue(
    queue: &Mutex<Vec<ColdMember>>,
) -> Result<std::sync::MutexGuard<'_, Vec<ColdMember>>> {
    queue
        .lock()
        .map_err(|_| Error::Sched("fleet cold queue poisoned by a panicked worker".into()))
}

/// Send a host event to the main thread. The receiver lives on the
/// main thread for the entire `thread::scope`, so a failed send means
/// the main thread is gone (it panicked out of the event loop): trip
/// the fleet-wide stop so every worker winds down instead of spinning
/// against a dead channel.
fn emit(tx: &mpsc::Sender<HostEvent>, stop: &AtomicBool, event: HostEvent) {
    if tx.send(event).is_err() {
        stop.store(true, Ordering::Release);
    }
}

impl ShardWorker<'_> {
    fn run(mut self) -> Result<(FaultTelemetry, ShardStats)> {
        let sw = Stopwatch::start();
        while self.live.load(Ordering::Acquire) > 0 && !self.stop.load(Ordering::Relaxed) {
            let admitted = self.admit_one()?;
            if self.ready.is_empty() {
                if !admitted && !self.steal()? {
                    // nothing to run, admit or steal: another worker is
                    // finishing the stragglers
                    std::thread::yield_now();
                }
                continue;
            }
            let idx = pick_validated(self.policy.as_mut(), &self.states, &self.ready)?;
            self.tick_session(idx)?;
        }
        self.stats.host_ms = sw.elapsed_ms();
        self.stats.step_ms = self.step_ms;
        self.stats.sched_overhead_ms = (self.stats.host_ms - self.step_ms).max(0.0);
        Ok((self.telemetry, self.stats))
    }

    /// Admit at most one cold member per loop iteration — the
    /// oldest-stamped one whose `wake_at` has come — building its session
    /// on this thread. Lazy admission keeps a 10k-session fleet from
    /// paying 10k up-front builds before the first op runs. With nothing
    /// ready and nothing due, jumps the local clock to the earliest
    /// wake-up (backoff is tick-deterministic, never wall-clock).
    fn admit_one(&mut self) -> Result<bool> {
        let member = {
            let mut queue = lock_queue(&self.queues[self.shard])?;
            if self.ready.is_empty() && !queue.is_empty() {
                // detlint: allow(R001) guarded by !queue.is_empty() on the previous line
                let wake = queue.iter().map(|m| m.wake_at).min().expect("non-empty");
                self.tick = self.tick.max(wake);
            }
            queue
                .iter()
                .enumerate()
                .filter(|(_, m)| m.wake_at <= self.tick)
                .min_by_key(|(_, m)| m.stamp)
                .map(|(i, _)| i)
                .map(|i| queue.swap_remove(i))
        };
        let Some(member) = member else { return Ok(false) };
        let idx = member.idx;
        let session = member.builder.build().map_err(|e| {
            // parity with the single-thread host, where any member
            // failing to build aborts the fleet regardless of supervision
            Error::Pipeline(format!(
                "fleet session {:?}: failed to build: {e}",
                self.names[idx]
            ))
        })?;
        self.hot[idx] = Some(HotMember {
            session: Box::new(session),
            factory: member.factory,
            checkpoint: member.checkpoint,
            restarts_used: member.restarts_used,
            fired: member.fired,
        });
        self.states[idx] = member.state;
        if let Err(pos) = self.ready.binary_search(&idx) {
            self.ready.insert(pos, idx);
        }
        self.stats.sessions += 1;
        self.policy.prepare(&self.states, &self.ready);
        Ok(true)
    }

    /// Idle-worker work stealing: take the oldest-stamped cold member
    /// from the most-loaded foreign queue and splice it into our own
    /// (admission then happens through the normal [`Self::admit_one`]
    /// path). Only cold members move — hot sessions are pinned — so a
    /// steal hands over a recipe, never mid-op state. Locks are taken one
    /// at a time, so no ordering discipline is needed.
    fn steal(&mut self) -> Result<bool> {
        // `len >= best` keeps max_by_key's last-maximal tie break (the
        // highest-index shard among equally loaded victims)
        let mut victim: Option<(usize, usize)> = None;
        for s in (0..self.queues.len()).filter(|&s| s != self.shard) {
            let len = lock_queue(&self.queues[s])?.len();
            if len > 0 && victim.map_or(true, |(_, best)| len >= best) {
                victim = Some((s, len));
            }
        }
        let Some((victim, _)) = victim else { return Ok(false) };
        let stolen = {
            let mut queue = lock_queue(&self.queues[victim])?;
            queue
                .iter()
                .enumerate()
                .min_by_key(|(_, m)| m.stamp)
                .map(|(i, _)| i)
                .map(|i| queue.swap_remove(i))
        };
        // the queue may have drained between the length probe and the
        // lock re-take; that just means someone else got there first
        let Some(member) = stolen else { return Ok(false) };
        self.steals_out[victim].fetch_add(1, Ordering::Relaxed);
        self.stats.steals_in += 1;
        lock_queue(&self.queues[self.shard])?.push(member);
        Ok(true)
    }

    /// One scheduler tick: maybe inject a fault (only at a round
    /// boundary, where the single-thread host makes every decision), then
    /// advance the picked session by exactly one op.
    fn tick_session(&mut self, idx: usize) -> Result<()> {
        // detlint: allow(R001) invariant: idx comes from `ready`, and ready members are hot
        let member = self.hot[idx].as_mut().expect("ready session is hot");
        if member.session.at_round_boundary() {
            // keyed on the session's own round (not any host clock) so
            // the plan names cells a user can reason about; the gate
            // order matches the single-thread host exactly
            let session_round = member.session.rounds_completed();
            let total_rounds = member.session.cfg().rounds;
            let fault = self
                .plan
                .filter(|_| session_round < total_rounds)
                .and_then(|plan| plan.fault_for(idx, session_round))
                .filter(|_| member.fired.insert(session_round));
            if let Some(kind) = fault {
                self.telemetry.record(idx, session_round, &kind);
                emit(
                    &self.tx,
                    self.stop,
                    HostEvent::Fault { session: idx, round: session_round, kind: kind.name() },
                );
                match kind {
                    FaultKind::Transient => {
                        // clears on retry: the session stays ready, but
                        // the pick consumed the policy's indexed entry
                        self.policy.prepare(&self.states, &self.ready);
                        return Ok(());
                    }
                    FaultKind::Straggler { slowdown } => {
                        member.session.inject_slowdown(slowdown);
                    }
                    FaultKind::EnergyBrownout { joules } => {
                        member.session.inject_brownout(joules);
                    }
                    FaultKind::Crash => {
                        return self.fail(idx, session_round, "injected crash".into());
                    }
                    // every remaining kind damages the on-disk checkpoint
                    other => {
                        let seed =
                            self.plan.map_or(0, |p| p.corruption_seed(idx, session_round));
                        inject_checkpoint_fault(&other, member.checkpoint.as_ref(), seed);
                    }
                }
            }
        }

        // detlint: allow(R001) invariant: idx comes from `ready`, and ready members are hot
        let member = self.hot[idx].as_mut().expect("ready session is hot");
        let step_sw = Stopwatch::start();
        let stepped = member.session.step_op();
        // detlint: allow(D004) host-profiling accumulator; *_ms fields are diff-ignored
        self.step_ms += step_sw.elapsed_ms();
        self.tick += 1;
        self.stats.ops += 1;
        match stepped {
            Ok(StepEvent::OpCompleted(_)) => {
                self.states[idx].last_run = self.tick;
                self.policy.task_ran(idx, &self.states);
                Ok(())
            }
            Ok(StepEvent::RoundCompleted(outcome)) => {
                self.states[idx].rounds_done += 1;
                self.states[idx].last_run = self.tick;
                self.stats.rounds += 1;
                self.policy.task_ran(idx, &self.states);
                emit(&self.tx, self.stop, HostEvent::Round { session: idx, outcome });
                // the main thread got the outcome; drop the session's copy
                // detlint: allow(R001) invariant: idx comes from `ready`, and ready members are hot
                let member = self.hot[idx].as_mut().expect("ready session is hot");
                member.session.take_outcomes();
                Ok(())
            }
            Ok(StepEvent::Finished(record)) => {
                self.hot[idx] = None;
                self.remove_ready(idx);
                self.live.fetch_sub(1, Ordering::AcqRel);
                emit(
                    &self.tx,
                    self.stop,
                    HostEvent::Finished { session: idx, record: Box::new(record) },
                );
                Ok(())
            }
            Err(e) => {
                let round = self.hot[idx]
                    .as_ref()
                    // detlint: allow(R001) invariant: a stepping session is hot by construction
                    .expect("ready session is hot")
                    .session
                    .rounds_completed();
                self.fail(idx, round, e.to_string())
            }
        }
    }

    /// Route one failed hot session through the supervision policy.
    /// `FailFast` records the failure for the main thread and trips the
    /// fleet-wide stop; `Isolate` and `Restart` keep the shard running.
    fn fail(&mut self, idx: usize, round: usize, reason: String) -> Result<()> {
        match self.supervise {
            SupervisionPolicy::FailFast => {
                self.failures
                    .lock()
                    .map_err(|_| {
                        Error::Sched("fleet failure list poisoned by a panicked worker".into())
                    })?
                    .push((idx, reason));
                self.stop.store(true, Ordering::Release);
                Ok(())
            }
            SupervisionPolicy::Isolate => {
                self.quarantine(idx, round, reason);
                self.policy.prepare(&self.states, &self.ready);
                Ok(())
            }
            SupervisionPolicy::Restart { max_retries, backoff_rounds, backoff_cap } => {
                // detlint: allow(R001) invariant: fail() is only called for a hot session
                let used = self.hot[idx].as_ref().expect("failed session is hot").restarts_used;
                if used >= max_retries {
                    let reason = format!("{reason} ({max_retries} restarts exhausted)");
                    self.quarantine(idx, round, reason);
                } else {
                    // detlint: allow(R001) invariant: fail() is only called for a hot session
                    let member = self.hot[idx].take().expect("failed session is hot");
                    match rebuild_builder(member.factory.as_ref(), member.checkpoint.as_ref())
                    {
                        Ok((builder, resumed_round, recovery)) => {
                            self.telemetry.restarts += 1;
                            self.telemetry.rounds_recovered +=
                                round.saturating_sub(resumed_round);
                            if let Some(telemetry) = recovery {
                                // must reach the main thread before the
                                // member is re-queued: channel order then
                                // guarantees Recovery precedes the
                                // session's eventual Finished
                                emit(
                                    &self.tx,
                                    self.stop,
                                    HostEvent::Recovery { session: idx, telemetry },
                                );
                            }
                            // capped exponential backoff, on the worker's
                            // op-granular clock
                            let delay =
                                restart_backoff(backoff_rounds, backoff_cap, member.restarts_used);
                            log::info!(
                                "fleet: restarting session {:?} from round {resumed_round} \
                                 (failed at {round}: {reason}; retry {}/{max_retries}, \
                                 backoff {delay} ticks)",
                                self.names[idx],
                                member.restarts_used + 1,
                            );
                            self.remove_ready(idx);
                            // back to our own cold queue (stealable from
                            // there): the rebuilt session has not started,
                            // so it is movable again
                            let stamp = self.stamps.fetch_add(1, Ordering::Relaxed);
                            lock_queue(&self.queues[self.shard])?.push(ColdMember {
                                idx,
                                builder,
                                factory: member.factory,
                                checkpoint: member.checkpoint,
                                stamp,
                                wake_at: self.tick + delay,
                                state: self.states[idx],
                                restarts_used: member.restarts_used + 1,
                                fired: member.fired,
                            });
                        }
                        Err(e) => {
                            let reason = format!("{reason}; restart failed: {e}");
                            self.quarantine(idx, round, reason);
                        }
                    }
                }
                self.policy.prepare(&self.states, &self.ready);
                Ok(())
            }
        }
    }

    /// Terminal quarantine: the member leaves scheduling for good and the
    /// fleet-wide live count drops.
    fn quarantine(&mut self, idx: usize, round: usize, reason: String) {
        log::warn!(
            "fleet: quarantining session {:?} at round {round}: {reason}",
            self.names[idx]
        );
        self.telemetry.quarantines += 1;
        emit(&self.tx, self.stop, HostEvent::Quarantined { session: idx, round, reason });
        self.hot[idx] = None;
        self.remove_ready(idx);
        self.live.fetch_sub(1, Ordering::AcqRel);
    }

    fn remove_ready(&mut self, idx: usize) {
        if let Ok(pos) = self.ready.binary_search(&idx) {
            self.ready.remove(pos);
        }
    }
}

impl Fleet {
    /// The sharded host: sessions are partitioned into `host_threads`
    /// shards by [`shard_of`] and run on scoped worker threads, each
    /// advancing one of its members by one **op** per tick under its own
    /// fresh copy of the scheduling policy ([`SchedPolicy::fresh`]); idle
    /// workers steal the oldest cold member from the most-loaded foreign
    /// shard. Per-session work is untouched — only the interleaving
    /// changes — so every deterministic output is bit-identical to
    /// [`Fleet::run_single`].
    fn run_sharded(mut self) -> Result<FleetRecord> {
        let n = self.builders.len();
        let threads = self.host_threads.min(n);

        let mut worker_policies: Vec<Box<dyn SchedPolicy + Send>> =
            Vec::with_capacity(threads);
        for _ in 0..threads {
            match self.policy.fresh() {
                Some(p) => worker_policies.push(p),
                None => {
                    return Err(Error::Sched(format!(
                        "policy {:?} has no fresh() and cannot run sharded; use \
                         host_threads(1) or implement SchedPolicy::fresh",
                        self.policy.name()
                    )))
                }
            }
        }

        let fleet_sw = Stopwatch::start();
        // per-shard cold queues seeded by the stable shard hash; initial
        // stamps are the session indices, so "oldest" starts out meaning
        // "first added"
        let queues: Vec<Mutex<Vec<ColdMember>>> =
            (0..threads).map(|_| Mutex::new(Vec::new())).collect();
        {
            let builders = std::mem::take(&mut self.builders);
            let factories = std::mem::take(&mut self.factories);
            let checkpoints = std::mem::take(&mut self.checkpoints);
            for (idx, ((builder, factory), checkpoint)) in
                builders.into_iter().zip(factories).zip(checkpoints).enumerate()
            {
                lock_queue(&queues[shard_of(idx, threads)])?.push(ColdMember {
                    idx,
                    builder,
                    factory,
                    checkpoint,
                    stamp: idx as u64,
                    wake_at: 0,
                    state: TaskState::default(),
                    restarts_used: 0,
                    fired: HashSet::new(),
                });
            }
        }

        let live = AtomicUsize::new(n);
        let stop = AtomicBool::new(false);
        let stamps = AtomicU64::new(n as u64);
        let steals_out: Vec<AtomicU64> = (0..threads).map(|_| AtomicU64::new(0)).collect();
        let failures: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());
        let plan = self.fault_plan.clone();
        let supervise = self.supervise;

        let mut records: Vec<Option<RunRecord>> = (0..n).map(|_| None).collect();
        let mut statuses: Vec<Option<SessionStatus>> = vec![None; n];
        let mut session_rounds = vec![0usize; n];
        let mut rounds_executed = 0usize;
        let mut device_ops = 0u64;
        let mut recoveries = std::mem::take(&mut self.recoveries);

        let (queues, steals_out) = (&queues, &steals_out);
        let (live, stop, stamps, failures) = (&live, &stop, &stamps, &failures);
        let names: &[String] = &self.names;
        let observers = &mut self.observers;
        // degraded assembly-time resumes, surfaced before the first tick
        // (same order as the single-thread host)
        for (i, t) in recoveries.iter().enumerate() {
            if let Some(t) = t {
                for obs in observers.iter_mut() {
                    obs.on_recovery(i, &names[i], t);
                }
            }
        }
        let (tx, rx) = mpsc::channel::<HostEvent>();

        let worker_results: Result<Vec<(FaultTelemetry, ShardStats)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for (shard, policy) in worker_policies.into_iter().enumerate() {
                    let tx = tx.clone();
                    let plan = plan.as_ref();
                    handles.push(scope.spawn(move || {
                        let _guard = PanicStop(stop);
                        let worker = ShardWorker {
                            shard,
                            supervise,
                            plan,
                            names,
                            queues,
                            steals_out,
                            live,
                            stop,
                            stamps,
                            failures,
                            tx,
                            policy,
                            states: vec![TaskState::default(); n],
                            hot: (0..n).map(|_| None).collect(),
                            ready: Vec::new(),
                            tick: 0,
                            telemetry: FaultTelemetry::default(),
                            stats: ShardStats { shard, ..ShardStats::default() },
                            step_ms: 0.0,
                        };
                        let result = worker.run();
                        if result.is_err() {
                            // a dead worker's members can never finish, so
                            // the fleet would otherwise wait forever
                            stop.store(true, Ordering::Release);
                        }
                        result
                    }));
                }
                // the main thread owns the (possibly non-Send) fleet
                // observers: workers stream events here and this loop runs
                // until every worker has dropped its sender
                drop(tx);
                while let Ok(event) = rx.recv() {
                    match event {
                        HostEvent::Round { session, outcome } => {
                            session_rounds[session] += 1;
                            rounds_executed += 1;
                            // +1: the round's TrainStep on the CPU lane
                            device_ops += outcome.selector.ops.len() as u64 + 1;
                            for obs in observers.iter_mut() {
                                obs.on_session_round(session, &names[session], &outcome);
                            }
                        }
                        HostEvent::Finished { session, record } => {
                            let mut record = *record;
                            // any Recovery for this session already
                            // arrived (sent before its re-queue), so the
                            // stamp matches the single-thread host's
                            record.recovery = recoveries[session].clone();
                            for obs in observers.iter_mut() {
                                obs.on_session_finished(session, &names[session], &record);
                            }
                            records[session] = Some(record);
                            statuses[session] = Some(SessionStatus::Finished);
                        }
                        HostEvent::Fault { session, round, kind } => {
                            for obs in observers.iter_mut() {
                                obs.on_fault(session, &names[session], round, kind);
                            }
                        }
                        HostEvent::Quarantined { session, round, reason } => {
                            for obs in observers.iter_mut() {
                                obs.on_session_quarantined(
                                    session,
                                    &names[session],
                                    round,
                                    &reason,
                                );
                            }
                            statuses[session] =
                                Some(SessionStatus::Quarantined { round, reason });
                        }
                        HostEvent::Recovery { session, telemetry } => {
                            for obs in observers.iter_mut() {
                                obs.on_recovery(session, &names[session], &telemetry);
                            }
                            recoveries[session]
                                .get_or_insert_with(RecoveryTelemetry::default)
                                .merge(&telemetry);
                        }
                    }
                }
                let joins: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
                let mut out = Vec::with_capacity(threads);
                for joined in joins {
                    out.push(
                        joined
                            .map_err(|_| {
                                Error::Pipeline("fleet shard worker panicked".into())
                            })??,
                    );
                }
                Ok(out)
            });

        // FailFast failures outrank worker-level errors: the historical
        // contract is an error naming the failing session, and with
        // several racing the lowest index wins (any single one is a
        // legitimate outcome; this picks one deterministically)
        let recorded = {
            let mut f = failures.lock().unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *f)
        };
        if let Some((idx, reason)) = recorded.into_iter().min_by_key(|&(idx, _)| idx) {
            return Err(Error::Pipeline(format!(
                "fleet session {:?}: {reason}",
                self.names[idx]
            )));
        }
        let worker_results = worker_results?;

        let mut faults = FaultTelemetry::default();
        let mut shards = Vec::with_capacity(threads);
        let mut steals = 0u64;
        let mut sched_overhead_ms = 0.0f64;
        for (shard, (telemetry, mut stats)) in worker_results.into_iter().enumerate() {
            faults.merge_from(telemetry);
            stats.steals_out = steals_out[shard].load(Ordering::Relaxed);
            steals += stats.steals_in;
            // detlint: allow(D004) host-profiling accumulator; *_ms fields are diff-ignored
            sched_overhead_ms += stats.sched_overhead_ms;
            shards.push(stats);
        }
        // canonical (session, round) event order — workers log
        // concurrently, and fault cells are unique per (session, round),
        // so this is a total order shared with the single-thread host
        faults.events.sort_unstable_by_key(|e| (e.session, e.round));

        let statuses: Vec<SessionStatus> = statuses
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.unwrap_or_else(|| SessionStatus::Quarantined {
                    round: session_rounds[i],
                    reason: "scheduler exited without a terminal status".into(),
                })
            })
            .collect();
        let total_host_ms = fleet_sw.elapsed_ms();
        // totals fold over records in session-index order — the same
        // float-summation order as the single-thread host, so the sums
        // are bit-identical, not merely close
        let finished = records.iter().flatten();
        let retention = finished
            .clone()
            .filter_map(|r| r.retention.as_ref())
            .fold(None, |acc: Option<crate::retention::RetentionTelemetry>, t| {
                let mut sum = acc.unwrap_or_default();
                sum.merge(t);
                Some(sum)
            });
        Ok(FleetRecord {
            policy: self.policy.name().to_string(),
            supervision: self.supervise.name().to_string(),
            names: self.names,
            session_rounds,
            rounds_executed,
            device_ops,
            total_device_ms: finished.clone().map(|r| r.total_device_ms).sum(),
            energy_j: finished.clone().map(|r| r.energy_j).sum(),
            peak_memory_bytes: finished.map(|r| r.peak_memory_bytes).sum(),
            records,
            statuses,
            faults,
            fault_plan: self.fault_plan.as_ref().map(|p| p.to_json()),
            retention,
            recovery: merge_recoveries(&recoveries),
            total_host_ms,
            sched_overhead_ms,
            host_threads: threads,
            steals,
            shards,
        })
    }
}

/// How one fleet member ended its run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionStatus {
    /// The session ran to completion and has a [`RunRecord`].
    Finished,
    /// Supervision gave up on the session at its `round`; it has no
    /// final record.
    Quarantined {
        /// The session-local round at which supervision gave up.
        round: usize,
        /// Why (the failing error, or the injected fault).
        reason: String,
    },
}

impl SessionStatus {
    pub fn is_finished(&self) -> bool {
        matches!(self, SessionStatus::Finished)
    }

    /// Display/JSON label: `finished` or `quarantined`.
    pub fn label(&self) -> &'static str {
        match self {
            SessionStatus::Finished => "finished",
            SessionStatus::Quarantined { .. } => "quarantined",
        }
    }
}

/// One injected fault. The telemetry's event log is kept in canonical
/// `(session, round)` order — fault cells are unique per (session,
/// round), so that order is total, and it is the same no matter how many
/// host threads injected the faults.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Fleet index of the session the fault hit.
    pub session: usize,
    /// The session-local round it hit at.
    pub round: usize,
    /// [`FaultKind::name`] of what fired.
    pub kind: String,
}

/// Fault + supervision telemetry for one fleet run. Fully deterministic
/// for a given (config, fault plan) pair — it counts injected faults and
/// the scheduler's deterministic reactions, never wall-clock effects.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultTelemetry {
    /// Injected `Crash` faults.
    pub crashes: usize,
    /// Injected `Transient` faults (each also counts one retry).
    pub transients: usize,
    /// Picks consumed by a fault that left the session ready to retry.
    pub retries: usize,
    /// Injected `Straggler` slowdowns.
    pub stragglers: usize,
    /// Injected `EnergyBrownout` drains.
    pub brownouts: usize,
    /// Injected checkpoint corruptions — every
    /// [`FaultKind::corrupts_checkpoint`] flavor (truncation, torn write,
    /// bit flip, stale rename); the event log keeps the flavor.
    pub corruptions: usize,
    /// Successful session rebuilds under restart supervision.
    pub restarts: usize,
    /// Sessions supervision gave up on.
    pub quarantines: usize,
    /// Σ over restarts of (failed-at round − resumed-from round): rounds
    /// a checkpoint saved the fleet from re-running. 0 with no
    /// checkpoints (scratch restarts re-run everything).
    pub rounds_recovered: usize,
    /// Every injected fault, in canonical `(session, round)` order (see
    /// [`FaultEvent`]).
    pub events: Vec<FaultEvent>,
}

impl FaultTelemetry {
    /// Fold another telemetry (a shard worker's) into this one. Events
    /// concatenate; the caller re-sorts into canonical order afterwards.
    fn merge_from(&mut self, other: FaultTelemetry) {
        self.crashes += other.crashes;
        self.transients += other.transients;
        self.retries += other.retries;
        self.stragglers += other.stragglers;
        self.brownouts += other.brownouts;
        self.corruptions += other.corruptions;
        self.restarts += other.restarts;
        self.quarantines += other.quarantines;
        self.rounds_recovered += other.rounds_recovered;
        self.events.extend(other.events);
    }

    /// Count one injected fault and append it to the event log.
    fn record(&mut self, session: usize, round: usize, kind: &FaultKind) {
        match kind {
            FaultKind::Crash => self.crashes += 1,
            FaultKind::Transient => {
                self.transients += 1;
                self.retries += 1;
            }
            FaultKind::Straggler { .. } => self.stragglers += 1,
            FaultKind::EnergyBrownout { .. } => self.brownouts += 1,
            // all four checkpoint-corruption flavors share one counter;
            // the per-event `kind` string keeps them distinguishable
            FaultKind::CorruptCheckpoint
            | FaultKind::TornWrite
            | FaultKind::BitFlip
            | FaultKind::StaleRename => self.corruptions += 1,
        }
        self.events.push(FaultEvent { session, round, kind: kind.name().to_string() });
    }

    /// Total injected faults.
    pub fn total(&self) -> usize {
        self.events.len()
    }

    pub fn to_json(&self) -> Json {
        let events = Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("session", Json::Num(e.session as f64)),
                        ("round", Json::Num(e.round as f64)),
                        ("kind", Json::Str(e.kind.clone())),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("crashes", Json::Num(self.crashes as f64)),
            ("transients", Json::Num(self.transients as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("stragglers", Json::Num(self.stragglers as f64)),
            ("brownouts", Json::Num(self.brownouts as f64)),
            ("corruptions", Json::Num(self.corruptions as f64)),
            ("restarts", Json::Num(self.restarts as f64)),
            ("quarantines", Json::Num(self.quarantines as f64)),
            ("rounds_recovered", Json::Num(self.rounds_recovered as f64)),
            ("events", events),
        ])
    }
}

/// Aggregate record of one fleet run: per-session [`RunRecord`]s plus the
/// shared host accounting.
#[derive(Clone, Debug)]
pub struct FleetRecord {
    /// Policy display name.
    pub policy: String,
    /// Supervision policy display name ([`SupervisionPolicy::name`]).
    pub supervision: String,
    /// Session display names, index-aligned with `records`/`statuses`.
    pub names: Vec<String>,
    /// Final per-session records — `Some` exactly for
    /// [`SessionStatus::Finished`] members, and identical to solo runs
    /// for every session that is reproducible solo (see the module
    /// docs).
    pub records: Vec<Option<RunRecord>>,
    /// How each session ended.
    pub statuses: Vec<SessionStatus>,
    /// Rounds each session completed **in this fleet run** (a restarted
    /// member counts replayed rounds again — they were re-executed).
    pub session_rounds: Vec<usize>,
    /// Total interleaved rounds across all sessions.
    pub rounds_executed: usize,
    /// Device-sim ops charged across all sessions (selector ops + one
    /// train step per round).
    pub device_ops: u64,
    /// Σ per-session simulated device clocks (ms), finished members only.
    pub total_device_ms: f64,
    /// Host wall clock of the whole fleet run (ms).
    pub total_host_ms: f64,
    /// Host wall time outside `Session::step` — scheduling, bookkeeping
    /// and fleet-observer fan-out (ms).
    pub sched_overhead_ms: f64,
    /// Σ per-session simulated energy (J), finished members only.
    pub energy_j: f64,
    /// Σ per-session peak-memory estimates (bytes) — every session's
    /// working set is resident concurrently on the host.
    pub peak_memory_bytes: usize,
    /// Injected-fault and supervision telemetry (all zero with no plan
    /// or a zero-rate plan).
    pub faults: FaultTelemetry,
    /// The fault plan that ran, serialized ([`FaultPlan::to_json`]);
    /// None when the fleet ran unfaulted.
    pub fault_plan: Option<Json>,
    /// Component-wise sum of finished members' retention telemetry
    /// (`bytes_held` reads as total bytes held across members); None when
    /// no member retained.
    pub retention: Option<crate::retention::RetentionTelemetry>,
    /// Component-wise sum of members' checkpoint-vault recovery telemetry
    /// ([`RecoveryTelemetry::merge`]); None when every resume was clean.
    pub recovery: Option<RecoveryTelemetry>,
    /// Worker threads the host actually ran with (1 = the single-thread
    /// reference host; clamped to the fleet size).
    pub host_threads: usize,
    /// Total cross-shard work steals (Σ shards' `steals_in`); 0 on the
    /// single-thread host. Wall-clock-dependent, like the shard stats.
    pub steals: u64,
    /// Per-shard scheduler accounting, in shard order; empty on the
    /// single-thread host.
    pub shards: Vec<ShardStats>,
}

impl FleetRecord {
    /// Scheduler overhead amortized per interleaved round (ms).
    pub fn sched_overhead_per_round_ms(&self) -> f64 {
        if self.rounds_executed == 0 {
            0.0
        } else {
            self.sched_overhead_ms / self.rounds_executed as f64
        }
    }

    /// Finished sessions (those with a [`RunRecord`]).
    pub fn finished(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_finished()).count()
    }

    pub fn to_json(&self) -> Json {
        let sessions = Json::Arr(
            self.names
                .iter()
                .zip(&self.records)
                .zip(self.statuses.iter().zip(&self.session_rounds))
                .map(|((name, record), (status, &rounds))| {
                    let mut fields = vec![
                        ("name", Json::Str(name.clone())),
                        ("rounds", Json::Num(rounds as f64)),
                        ("status", Json::Str(status.label().into())),
                    ];
                    if let SessionStatus::Quarantined { round, reason } = status {
                        fields.push(("quarantine_round", Json::Num(*round as f64)));
                        fields.push(("reason", Json::Str(reason.clone())));
                    }
                    fields
                        .push(("record", record.as_ref().map_or(Json::Null, |r| r.to_json())));
                    Json::obj(fields)
                })
                .collect(),
        );
        let mut fields = vec![
            ("policy", Json::Str(self.policy.clone())),
            ("supervision", Json::Str(self.supervision.clone())),
            ("sessions", sessions),
            ("rounds_executed", Json::Num(self.rounds_executed as f64)),
            ("device_ops", Json::Num(self.device_ops as f64)),
            ("total_device_ms", Json::Num(self.total_device_ms)),
            ("total_host_ms", Json::Num(self.total_host_ms)),
            ("sched_overhead_ms", Json::Num(self.sched_overhead_ms)),
            (
                "sched_overhead_per_round_ms",
                Json::Num(self.sched_overhead_per_round_ms()),
            ),
            ("energy_j", Json::Num(self.energy_j)),
            ("peak_memory_bytes", Json::Num(self.peak_memory_bytes as f64)),
            ("host_threads", Json::Num(self.host_threads as f64)),
            ("steals", Json::Num(self.steals as f64)),
            ("faults", self.faults.to_json()),
        ];
        if !self.shards.is_empty() {
            fields.push((
                "shards",
                Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()),
            ));
        }
        if let Some(plan) = &self.fault_plan {
            fields.push(("fault_plan", plan.clone()));
        }
        if let Some(t) = &self.retention {
            fields.push(("retention", t.to_json()));
        }
        if let Some(t) = &self.recovery {
            fields.push(("recovery", t.to_json()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(rounds: &[usize], last_run: &[u64]) -> Vec<TaskState> {
        rounds
            .iter()
            .zip(last_run)
            .map(|(&rounds_done, &last_run)| TaskState { rounds_done, last_run })
            .collect()
    }

    #[test]
    fn round_robin_cycles_and_skips_finished() {
        let mut p = RoundRobin::new();
        let s = states(&[0, 0, 0], &[0, 0, 0]);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 0);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 1);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 2);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 0); // wraps
        // session 1 finished: the cycle skips it
        assert_eq!(p.pick(&s, &[0, 2]), 2);
        assert_eq!(p.pick(&s, &[0, 2]), 0);
    }

    #[test]
    fn fewest_rounds_prefers_laggards_then_index() {
        // unprepared policy: the scan fallback answers
        let mut p = FewestRoundsFirst::new();
        let s = states(&[3, 1, 1, 5], &[0, 0, 0, 0]);
        assert_eq!(p.pick(&s, &[0, 1, 2, 3]), 1); // min rounds, tie -> min index
        assert_eq!(p.pick(&s, &[0, 2, 3]), 2);
        assert_eq!(p.pick(&s, &[0, 3]), 0);
    }

    #[test]
    fn staleness_prefers_longest_waiting_then_index() {
        // staleness = ticks since last_run, so stalest = smallest last_run
        let mut p = StalenessPriority::new();
        let s = states(&[0, 0, 0, 0], &[5, 1, 1, 6]);
        assert_eq!(p.pick(&s, &[0, 1, 2, 3]), 1); // max staleness, tie -> min index
        assert_eq!(p.pick(&s, &[0, 2, 3]), 2);
        assert_eq!(p.pick(&s, &[0, 3]), 0);
    }

    /// THE policy-order equivalence pin (N ≤ 100): the heap-backed path
    /// (driven through prepare/task_ran) must reproduce the scan
    /// fallback's pick sequence exactly, through runs, finishes and
    /// re-preparations, for both keyed policies.
    #[test]
    fn heap_policies_match_scan_reference() {
        for n in [1usize, 2, 3, 17, 100] {
            for seed in 0..5u64 {
                check_heap_vs_scan(&mut FewestRoundsFirst::new(), n, seed);
                check_heap_vs_scan(&mut StalenessPriority::new(), n, seed);
            }
        }
    }

    fn check_heap_vs_scan(heap: &mut dyn SchedPolicy, n: usize, seed: u64) {
        // scan twin: same type, never prepared -> always the scan path.
        // Both twins see the same states; only the heap one gets hooks.
        let mut scan = match heap.name() {
            "fewest-rounds-first" => {
                Box::new(FewestRoundsFirst::new()) as Box<dyn SchedPolicy>
            }
            _ => Box::new(StalenessPriority::new()),
        };
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed ^ n as u64);
        let budgets: Vec<usize> = (0..n).map(|_| 1 + rng.index(6)).collect();
        let mut states = vec![TaskState::default(); n];
        let mut ready: Vec<usize> = (0..n).collect();
        let mut tick = 0u64;
        heap.prepare(&states, &ready);
        while !ready.is_empty() {
            let a = pick_validated(heap, &states, &ready).unwrap();
            let b = pick_validated(scan.as_mut(), &states, &ready).unwrap();
            assert_eq!(a, b, "{} n={n} seed={seed} tick={tick}", heap.name());
            states[a].rounds_done += 1;
            tick += 1;
            states[a].last_run = tick;
            if states[a].rounds_done >= budgets[a] {
                ready.retain(|&i| i != a); // finished: no task_ran
            } else {
                heap.task_ran(a, &states);
            }
        }
    }

    #[test]
    fn pick_validated_rejects_misbehaving_policy() {
        struct Bad;
        impl SchedPolicy for Bad {
            fn pick(&mut self, _states: &[TaskState], _ready: &[usize]) -> usize {
                999 // out of range AND not ready
            }
            fn name(&self) -> &'static str {
                "bad"
            }
        }
        let s = states(&[0, 0], &[0, 0]);
        assert!(pick_validated(&mut Bad, &s, &[0, 1]).is_err());
        assert_eq!(pick_validated(&mut RoundRobin::new(), &s, &[1]).unwrap(), 1);
    }

    #[test]
    fn policy_parsing() {
        for (name, want) in [
            ("rr", "round-robin"),
            ("round-robin", "round-robin"),
            ("fewest", "fewest-rounds-first"),
            ("staleness", "priority-by-staleness"),
        ] {
            assert_eq!(parse_policy(name).unwrap().name(), want);
        }
        assert!(parse_policy("bogus").is_err());
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(FleetBuilder::new().build().is_err());
    }

    // Sessions build and start lazily, so supervision paths driven
    // entirely by scripted round-0 crashes (which fire *before* the first
    // step) are testable without model artifacts.

    fn unstarted_session(rounds: usize) -> SessionBuilder {
        let mut cfg = presets::table1("mlp", Method::Rs);
        cfg.rounds = rounds;
        cfg.pipeline = false;
        SessionBuilder::new(cfg)
    }

    fn crash_everyone(n: usize) -> FaultPlan {
        let mut plan = FaultPlan::new(0);
        for i in 0..n {
            plan = plan.script(i, 0, FaultKind::Crash);
        }
        plan
    }

    #[test]
    fn scripted_crashes_quarantine_under_isolate() {
        let record = FleetBuilder::new()
            .session("a", unstarted_session(3))
            .session("b", unstarted_session(3))
            .supervise(SupervisionPolicy::Isolate)
            .fault_plan(crash_everyone(2))
            .run()
            .unwrap();
        assert_eq!(record.supervision, "isolate");
        assert_eq!(record.rounds_executed, 0);
        assert_eq!(record.finished(), 0);
        for (status, rec) in record.statuses.iter().zip(&record.records) {
            assert_eq!(
                status,
                &SessionStatus::Quarantined { round: 0, reason: "injected crash".into() }
            );
            assert!(rec.is_none());
        }
        assert_eq!(record.faults.crashes, 2);
        assert_eq!(record.faults.quarantines, 2);
        assert_eq!(record.faults.total(), 2);
        assert!(record.fault_plan.is_some());
    }

    #[test]
    fn scripted_crash_aborts_under_failfast() {
        let err = FleetBuilder::new()
            .session("doomed", unstarted_session(3))
            .fault_plan(crash_everyone(1))
            .run()
            .unwrap_err();
        // the historical fleet-abort shape, naming the session
        assert_eq!(err.to_string(), "pipeline error: fleet session \"doomed\": injected crash");
    }

    #[test]
    fn restart_without_factory_quarantines() {
        let record = FleetBuilder::new()
            .session("fixed", unstarted_session(3))
            .supervise(SupervisionPolicy::Restart {
                max_retries: 2,
                backoff_rounds: 1,
                backoff_cap: 32,
            })
            .fault_plan(crash_everyone(1))
            .run()
            .unwrap();
        assert_eq!(record.faults.restarts, 0);
        assert_eq!(record.faults.quarantines, 1);
        let SessionStatus::Quarantined { round, reason } = &record.statuses[0] else {
            panic!("expected quarantine, got {:?}", record.statuses[0]);
        };
        assert_eq!(*round, 0);
        assert!(reason.contains("restart failed"), "unexpected reason: {reason}");
        assert!(reason.contains("no session factory"), "unexpected reason: {reason}");
    }

    #[test]
    fn restart_quarantines_when_the_factory_breaks() {
        // factory works for the initial build, then breaks — the restart
        // path must degrade to quarantine, not abort the fleet. (Arc +
        // atomic because factories are Send: they travel to shard
        // workers with their member.)
        let calls = std::sync::Arc::new(AtomicUsize::new(0));
        let seen = std::sync::Arc::clone(&calls);
        let factory = move || {
            if seen.fetch_add(1, Ordering::SeqCst) + 1 > 1 {
                return Err(Error::Other("factory broke".into()));
            }
            let mut cfg = presets::table1("mlp", Method::Rs);
            cfg.rounds = 3;
            cfg.pipeline = false;
            Ok(SessionBuilder::new(cfg))
        };
        let record = FleetBuilder::new()
            .session_restartable("flaky", factory)
            .unwrap()
            .supervise(SupervisionPolicy::Restart {
                max_retries: 2,
                backoff_rounds: 0,
                backoff_cap: 32,
            })
            .fault_plan(crash_everyone(1))
            .run()
            .unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 2, "initial build + one rebuild attempt");
        assert_eq!(record.faults.restarts, 0);
        let SessionStatus::Quarantined { reason, .. } = &record.statuses[0] else {
            panic!("expected quarantine, got {:?}", record.statuses[0]);
        };
        assert!(reason.contains("factory broke"), "unexpected reason: {reason}");
    }

    #[test]
    fn zero_rate_plan_injects_nothing() {
        let plan = FaultPlan::new(42);
        assert!(plan.is_zero());
        let record = FleetBuilder::new()
            .session("a", unstarted_session(3))
            .supervise(SupervisionPolicy::Isolate)
            .fault_plan(plan)
            .run()
            .unwrap();
        // without artifacts the session fails at start and is isolated
        // (a real failure, counted as a quarantine); with artifacts it
        // finishes — either way the plan injected nothing
        assert_eq!(record.faults.total(), 0);
        assert!(record.faults.events.is_empty());
        assert_eq!(record.faults.restarts, 0);
        assert_eq!(record.faults.rounds_recovered, 0);
    }

    #[test]
    fn fleet_record_json_shape() {
        let mut faults = FaultTelemetry::default();
        faults.record(1, 3, &FaultKind::Crash);
        faults.quarantines = 1;
        let rec = FleetRecord {
            policy: "round-robin".into(),
            supervision: "isolate".into(),
            names: vec!["a".into(), "b".into()],
            records: vec![Some(RunRecord::new("rs", "mlp")), None],
            statuses: vec![
                SessionStatus::Finished,
                SessionStatus::Quarantined { round: 3, reason: "injected crash".into() },
            ],
            session_rounds: vec![4, 3],
            rounds_executed: 10,
            device_ops: 25,
            total_device_ms: 1234.5,
            total_host_ms: 80.0,
            sched_overhead_ms: 2.0,
            energy_j: 9.0,
            peak_memory_bytes: 2048,
            faults,
            fault_plan: Some(FaultPlan::new(7).to_json()),
            retention: None,
            recovery: None,
            host_threads: 1,
            steals: 0,
            shards: Vec::new(),
        };
        assert!((rec.sched_overhead_per_round_ms() - 0.2).abs() < 1e-12);
        assert_eq!(rec.finished(), 1);
        let j = rec.to_json();
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(), "round-robin");
        assert_eq!(j.get("supervision").unwrap().as_str().unwrap(), "isolate");
        let sessions = j.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].get("status").unwrap().as_str().unwrap(), "finished");
        assert!(sessions[0].get("record").unwrap() != &Json::Null);
        assert_eq!(sessions[1].get("status").unwrap().as_str().unwrap(), "quarantined");
        assert_eq!(sessions[1].get("quarantine_round").unwrap().as_usize().unwrap(), 3);
        assert_eq!(sessions[1].get("reason").unwrap().as_str().unwrap(), "injected crash");
        assert_eq!(sessions[1].get("record").unwrap(), &Json::Null);
        let faults = j.get("faults").unwrap();
        assert_eq!(faults.get("crashes").unwrap().as_usize().unwrap(), 1);
        assert_eq!(faults.get("quarantines").unwrap().as_usize().unwrap(), 1);
        assert_eq!(faults.get("events").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("fault_plan").is_ok());
        assert!(j.get("retention").is_err(), "no retaining member, no retention key");
        assert!(j.get("recovery").is_err(), "no degraded resume, no recovery key");
        assert_eq!(j.get("rounds_executed").unwrap().as_usize().unwrap(), 10);
        assert_eq!(j.get("host_threads").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("steals").unwrap().as_usize().unwrap(), 0);
        assert!(j.get("shards").is_err(), "single-thread record emits no shards key");
        // a sharded record emits per-shard stats
        let mut sharded = rec.clone();
        sharded.host_threads = 2;
        sharded.steals = 3;
        sharded.shards = vec![
            ShardStats { shard: 0, sessions: 1, ops: 10, ..ShardStats::default() },
            ShardStats { shard: 1, sessions: 1, ops: 15, steals_in: 3, ..ShardStats::default() },
        ];
        let j = sharded.to_json();
        assert_eq!(j.get("host_threads").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("steals").unwrap().as_usize().unwrap(), 3);
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[1].get("steals_in").unwrap().as_usize().unwrap(), 3);
        assert_eq!(shards[1].get("ops").unwrap().as_usize().unwrap(), 15);
        // a fleet with a retention aggregate emits it
        let mut with_ret = rec.clone();
        let mut t = crate::retention::RetentionTelemetry::default();
        t.offers = 12;
        t.bytes_held = 4096;
        with_ret.retention = Some(t);
        let j = with_ret.to_json();
        assert_eq!(j.get("retention").unwrap().get("offers").unwrap().as_usize().unwrap(), 12);
        // a fleet with a degraded resume emits the recovery aggregate
        let mut with_rec = rec.clone();
        with_rec.recovery = Some(RecoveryTelemetry {
            frames_scanned: 3,
            torn_frames: 1,
            generation_used: 2,
            rounds_lost: 2,
            ..Default::default()
        });
        let j = with_rec.to_json();
        let r = j.get("recovery").unwrap();
        assert_eq!(r.get("rounds_lost").unwrap().as_usize().unwrap(), 2);
        assert_eq!(r.get("generation_used").unwrap().as_usize().unwrap(), 2);
        let j = with_ret.to_json();
        let roundtrip = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            roundtrip.get("sched_overhead_per_round_ms").unwrap().as_f64().unwrap(),
            0.2
        );
    }

    #[test]
    fn merge_recoveries_aggregates_or_none() {
        assert!(merge_recoveries(&[]).is_none());
        assert!(merge_recoveries(&[None, None]).is_none());
        let a = RecoveryTelemetry {
            frames_scanned: 2,
            torn_frames: 1,
            generation_used: 1,
            rounds_lost: 2,
            ..Default::default()
        };
        let b = RecoveryTelemetry {
            frames_scanned: 1,
            crc_failures: 1,
            generation_used: 3,
            ..Default::default()
        };
        let m = merge_recoveries(&[Some(a), None, Some(b)]).unwrap();
        assert_eq!(m.frames_scanned, 3);
        assert_eq!(m.torn_frames, 1);
        assert_eq!(m.crc_failures, 1);
        assert_eq!(m.generation_used, 3, "generation_used keeps the max");
        assert_eq!(m.rounds_lost, 2);
    }

    // ---- artifact-gated fleet runs ------------------------------------

    use crate::config::{presets, Method};
    use crate::coordinator::SessionBuilder;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny_session(method: Method, rounds: usize, seed_off: u64) -> SessionBuilder {
        let mut cfg = presets::table1("mlp", method);
        cfg.rounds = rounds;
        cfg.test_size = 200;
        cfg.eval_every = 2;
        cfg.pipeline = false;
        cfg.seed += seed_off;
        SessionBuilder::new(cfg)
    }

    /// A fleet observer that records the interleaving for assertions.
    struct Trace(std::rc::Rc<std::cell::RefCell<Vec<(usize, usize)>>>);

    impl FleetObserver for Trace {
        fn on_session_round(&mut self, session: usize, _name: &str, outcome: &RoundOutcome) {
            self.0.borrow_mut().push((session, outcome.round));
        }
    }

    #[test]
    fn round_robin_interleaves_heterogeneous_sessions() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let trace = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let record = FleetBuilder::new()
            .session("short", tiny_session(Method::Rs, 2, 0))
            .session("long", tiny_session(Method::Rs, 4, 1))
            .observe(Trace(std::rc::Rc::clone(&trace)))
            .run()
            .unwrap();
        assert_eq!(record.session_rounds, vec![2, 4]);
        assert_eq!(record.rounds_executed, 6);
        assert_eq!(record.records.len(), 2);
        assert!(record.records.iter().all(|r| r.is_some()));
        assert!(record.statuses.iter().all(|s| s.is_finished()));
        assert_eq!(record.supervision, "failfast");
        assert_eq!(record.faults, FaultTelemetry::default());
        assert!(record.fault_plan.is_none());
        // strict alternation while both live, then the long tail
        let seen = trace.borrow().clone();
        assert_eq!(
            seen,
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (1, 2), (1, 3)],
            "unexpected interleaving: {seen:?}"
        );
        assert!(record.total_device_ms > 0.0);
        assert!(record.peak_memory_bytes > 0);
    }

    // ---- sharded host -------------------------------------------------

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let mut hits = vec![0usize; threads];
            for idx in 0..10_000 {
                let s = shard_of(idx, threads);
                assert!(s < threads);
                assert_eq!(s, shard_of(idx, threads), "pure function of (idx, threads)");
                hits[s] += 1;
            }
            // splitmix64 spreads 10k indices roughly uniformly: no shard
            // is starved or grossly overloaded
            for (s, &count) in hits.iter().enumerate() {
                let expect = 10_000 / threads;
                assert!(
                    count > expect / 2 && count < expect * 2,
                    "shard {s}/{threads} got {count} of 10000"
                );
            }
        }
        assert_eq!(shard_of(3, 0), 0, "degenerate thread count clamps to 1");
    }

    #[test]
    fn shard_stats_per_tick_math() {
        let zero = ShardStats::default();
        assert_eq!(zero.sched_overhead_per_tick_ms(), 0.0);
        let s = ShardStats { ops: 8, sched_overhead_ms: 2.0, ..ShardStats::default() };
        assert!((s.sched_overhead_per_tick_ms() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn builtin_policies_are_sharding_capable() {
        for policy in [parse_policy("rr"), parse_policy("fewest"), parse_policy("staleness")] {
            let policy = policy.unwrap();
            let fresh = policy.fresh().expect("builtin policies implement fresh()");
            assert_eq!(fresh.name(), policy.name());
        }
    }

    #[test]
    fn sharded_host_rejects_fresh_less_policies() {
        struct NoFresh;
        impl SchedPolicy for NoFresh {
            fn pick(&mut self, _states: &[TaskState], ready: &[usize]) -> usize {
                ready[0]
            }
            fn name(&self) -> &'static str {
                "no-fresh"
            }
        }
        let err = FleetBuilder::new()
            .session("a", unstarted_session(3))
            .session("b", unstarted_session(3))
            .policy(NoFresh)
            .host_threads(2)
            .run()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("scheduler error:"), "want Error::Sched, got: {msg}");
        assert!(msg.contains("no-fresh"), "names the policy: {msg}");
    }

    /// Cross-thread-count determinism on the non-artifact path: scripted
    /// round-0 crashes under Isolate produce identical statuses, fault
    /// telemetry (including canonical event order) and per-session round
    /// counts for every host thread count. The artifact-gated
    /// integration suite pins full RunRecord equality; this pins the
    /// supervision plane in any environment.
    #[test]
    fn sharded_isolate_matches_single_thread() {
        let run = |threads: usize| {
            FleetBuilder::new()
                .session("a", unstarted_session(3))
                .session("b", unstarted_session(3))
                .session("c", unstarted_session(3))
                .supervise(SupervisionPolicy::Isolate)
                .fault_plan(crash_everyone(3))
                .host_threads(threads)
                .run()
                .unwrap()
        };
        let reference = run(1);
        assert_eq!(reference.host_threads, 1);
        assert!(reference.shards.is_empty());
        assert_eq!(reference.steals, 0);
        for threads in [2usize, 4] {
            let sharded = run(threads);
            assert_eq!(sharded.host_threads, threads.min(3));
            assert_eq!(sharded.shards.len(), threads.min(3));
            assert_eq!(sharded.statuses, reference.statuses, "t={threads}");
            assert_eq!(sharded.faults, reference.faults, "t={threads}");
            assert_eq!(sharded.session_rounds, reference.session_rounds, "t={threads}");
            assert_eq!(sharded.rounds_executed, reference.rounds_executed);
            assert!(sharded.records.iter().all(|r| r.is_none()));
            // every member was admitted exactly once somewhere
            let admitted: usize = sharded.shards.iter().map(|s| s.sessions).sum();
            assert_eq!(admitted, 3, "t={threads}");
        }
    }

    #[test]
    fn sharded_failfast_names_the_crashed_session() {
        // one member keeps the winning failure deterministic in any
        // environment (several racing members may not all get to record
        // theirs before the stop flag lands)
        let err = FleetBuilder::new()
            .session("doomed", unstarted_session(3))
            .fault_plan(crash_everyone(1))
            .host_threads(2)
            .run()
            .unwrap_err();
        // same fleet-abort shape as the single-thread host
        assert_eq!(err.to_string(), "pipeline error: fleet session \"doomed\": injected crash");
    }
}
