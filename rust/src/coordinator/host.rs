//! The host fleet runtime — many device sessions multiplexed on one host.
//!
//! The ROADMAP north star is a host serving millions of device sessions;
//! the prerequisite is that no session may own a thread for its whole
//! run. [`crate::coordinator::session::Session`] is a step-driven state
//! machine, so a [`Fleet`] can own N boxed sessions and interleave them
//! **round-by-round** on one scheduler thread: each scheduler tick picks
//! one ready session under a pluggable [`SchedPolicy`] and advances it by
//! exactly one [`StepEvent`].
//!
//! Sessions are fully independent (own data source, own engines, own
//! device sim), so the interleaving order cannot perturb any session's
//! output: for every session that is reproducible solo — any
//! sequential-backend session, and pipelined sessions with
//! parameter-independent selection — the per-session [`RunRecord`] in a
//! fleet is identical to the solo record, under every policy (pinned by
//! the fleet integration tests). Pipelined sessions with
//! parameter-*dependent* selection are timing-sensitive by design (the
//! latest-only param slot; see the session module docs), so their
//! records vary run-to-run with or without a fleet around them.
//!
//! Shared host accounting rolls up into a [`FleetRecord`]: aggregate
//! simulated device time and ops, energy, the summed peak-memory estimate
//! (all sessions are resident concurrently), and the scheduler's own
//! overhead (host wall time *not* spent inside `Session::step` — the
//! pick + bookkeeping + observer fan-out cost per interleaved round,
//! tracked in PERF.md).
//!
//! Edge fleets get killed; [`FleetBuilder::session_checkpointed`] wires
//! each member to its own on-disk snapshot (the
//! [`observers::Checkpoint`](crate::coordinator::session::observers::Checkpoint)
//! observer) so a restarted `titan fleet --resume` run picks every
//! member back up at its own saved round instead of re-spending
//! device-ms from round 0.
//!
//! Edge fleets also fail *while running*: [`FleetBuilder::fault_plan`]
//! attaches a seeded, deterministic [`FaultPlan`] that injects crashes,
//! transient errors, stragglers, energy brown-outs and checkpoint
//! corruption per (session, round) cell, and
//! [`FleetBuilder::supervise`] picks what the scheduler does about
//! failures: [`SupervisionPolicy::FailFast`] aborts the fleet (the
//! historical behavior and still the default),
//! [`SupervisionPolicy::Isolate`] quarantines the failed member and
//! finishes everyone else, and [`SupervisionPolicy::Restart`] rebuilds
//! the member from its factory — resuming from its latest valid
//! checkpoint when it has one — after a deterministic scheduler-tick
//! backoff. Every terminal state is reported per session as a
//! [`SessionStatus`]; fault activity rolls up into
//! [`FleetRecord::faults`]. With a zero-rate plan (or none) every
//! policy is bit-identical to the unsupervised fleet on all
//! deterministic fields.
//!
//! ```no_run
//! use titan::config::{presets, Method};
//! use titan::coordinator::host::{FewestRoundsFirst, FleetBuilder};
//! use titan::coordinator::SessionBuilder;
//!
//! let mut fleet = FleetBuilder::new().policy(FewestRoundsFirst::new());
//! for (i, method) in [Method::Titan, Method::Rs].into_iter().enumerate() {
//!     let mut cfg = presets::table1("mlp", method);
//!     cfg.pipeline = false;
//!     cfg.seed += i as u64;
//!     fleet = fleet.session(format!("dev{i}"), SessionBuilder::new(cfg).build()?);
//! }
//! let record = fleet.run()?;
//! println!("{} rounds interleaved", record.rounds_executed);
//! # Ok::<(), titan::Error>(())
//! ```

use std::collections::HashSet;
use std::path::PathBuf;

use crate::coordinator::session::{observers::Checkpoint, Session, SessionBuilder, StepEvent};
use crate::coordinator::snapshot::{load_checkpoint, Loaded};
use crate::coordinator::RoundOutcome;
use crate::fault::{FaultKind, FaultPlan, SupervisionPolicy};
use crate::metrics::RunRecord;
use crate::util::json::Json;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};

/// Per-task scheduling bookkeeping the policies decide on. The driver
/// (fleet or FL orchestrator) maintains one per task.
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskState {
    /// Rounds this task has completed.
    pub rounds_done: usize,
    /// Driver tick at which this task last ran (0 = never). Staleness is
    /// the *difference* `now − last_run`, so ordering "stalest first" is
    /// ordering "smallest last_run first" — which is what lets the driver
    /// update one entry per tick instead of aging all N.
    pub last_run: u64,
}

/// A scheduling policy over ready tasks.
///
/// `ready` is non-empty, **sorted ascending**, and holds indices into
/// `states`; `pick` must return one of them, and must be
/// **deterministic** (no wall clock, no RNG) so fleet runs replay
/// exactly. Policies may keep internal state (e.g. the round-robin
/// cursor).
///
/// The optional lifecycle hooks let a policy maintain O(log N) indexed
/// state instead of scanning `ready` on every pick: the driver calls
/// [`SchedPolicy::prepare`] whenever the ready set is (re)initialized
/// and [`SchedPolicy::task_ran`] after a picked task finished a unit of
/// work *and remains ready* (its `states` entry already updated). A task
/// that leaves the ready set simply gets no `task_ran` — a picked entry
/// is consumed. Policies that ignore the hooks (the default no-ops) must
/// answer `pick` from `states`/`ready` alone, and the built-in
/// heap-backed policies fall back to exactly that scan when the driver
/// never prepared them.
pub trait SchedPolicy {
    /// Pick the next task to run among `ready`.
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize;

    /// The ready set was (re)initialized (fleet start, FL comm round).
    fn prepare(&mut self, _states: &[TaskState], _ready: &[usize]) {}

    /// `task` was picked, ran one unit, and is ready again; its
    /// `states[task]` is current.
    fn task_ran(&mut self, _task: usize, _states: &[TaskState]) {}

    /// Display name for records and logs.
    fn name(&self) -> &'static str;
}

/// Cyclic fairness: the smallest ready index strictly after the last
/// pick, wrapping to the smallest ready index.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRobin {
    last: Option<usize>,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { last: None }
    }
}

impl SchedPolicy for RoundRobin {
    fn pick(&mut self, _states: &[TaskState], ready: &[usize]) -> usize {
        let next = self
            .last
            .and_then(|l| ready.iter().copied().filter(|&i| i > l).min())
            .unwrap_or_else(|| ready.iter().copied().min().expect("ready is non-empty"));
        self.last = Some(next);
        next
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Key-ordered policy core shared by [`FewestRoundsFirst`] and
/// [`StalenessPriority`]: a lazy-deletion min-heap over `(key, index)`.
///
/// `task_ran` pushes the task's fresh key without hunting down the old
/// entry; `pick` pops until the top entry's key still matches the task's
/// current key and the task is live — O(log N) amortized (each stale
/// entry is popped exactly once). Without `prepare` the heap is empty
/// and `pick` answers with the original O(|ready|) scan, which doubles
/// as the equivalence oracle (`heap_policies_match_scan_reference`).
#[derive(Clone, Debug, Default)]
struct KeyHeap {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>>,
    /// `queued[i]`: task i has exactly one live entry in the heap.
    queued: Vec<bool>,
    prepared: bool,
}

impl KeyHeap {
    fn prepare(&mut self, n: usize, ready: &[usize], key: impl Fn(usize) -> u64) {
        self.heap.clear();
        self.queued = vec![false; n];
        self.prepared = true;
        for &i in ready {
            self.heap.push(std::cmp::Reverse((key(i), i)));
            self.queued[i] = true;
        }
    }

    fn push(&mut self, task: usize, key: u64) {
        if self.prepared {
            self.heap.push(std::cmp::Reverse((key, task)));
            self.queued[task] = true;
        }
    }

    /// Pop the live minimum, or None when unprepared / drained.
    fn pop_min(&mut self, key: impl Fn(usize) -> u64) -> Option<usize> {
        if !self.prepared {
            return None;
        }
        while let Some(std::cmp::Reverse((k, i))) = self.heap.pop() {
            if self.queued.get(i).copied().unwrap_or(false) && key(i) == k {
                self.queued[i] = false;
                return Some(i);
            }
            // stale: superseded by a later push or consumed — drop it
        }
        None
    }
}

/// Progress fairness: the ready task with the fewest completed rounds
/// (ties: smallest index). Keeps heterogeneous-length sessions aligned.
///
/// Heap-backed through the [`SchedPolicy`] lifecycle hooks — O(log N)
/// per pick on prepared drivers, with the original scan as the
/// unprepared fallback (and the pinned reference).
#[derive(Clone, Debug, Default)]
pub struct FewestRoundsFirst {
    heap: KeyHeap,
}

impl FewestRoundsFirst {
    pub fn new() -> FewestRoundsFirst {
        FewestRoundsFirst::default()
    }
}

impl SchedPolicy for FewestRoundsFirst {
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize {
        self.heap
            .pop_min(|i| states[i].rounds_done as u64)
            .unwrap_or_else(|| {
                ready
                    .iter()
                    .copied()
                    .min_by_key(|&i| (states[i].rounds_done, i))
                    .expect("ready is non-empty")
            })
    }

    fn prepare(&mut self, states: &[TaskState], ready: &[usize]) {
        self.heap.prepare(states.len(), ready, |i| states[i].rounds_done as u64);
    }

    fn task_ran(&mut self, task: usize, states: &[TaskState]) {
        self.heap.push(task, states[task].rounds_done as u64);
    }

    fn name(&self) -> &'static str {
        "fewest-rounds-first"
    }
}

/// Staleness priority: the ready task that has waited longest since it
/// last ran — the smallest [`TaskState::last_run`] (ties: smallest
/// index; a never-run task has `last_run` 0 and outranks everything).
/// Bounds per-session latency when the ready set churns.
///
/// Heap-backed exactly like [`FewestRoundsFirst`]; `last_run` only moves
/// forward, so each pick invalidates at most one heap entry.
#[derive(Clone, Debug, Default)]
pub struct StalenessPriority {
    heap: KeyHeap,
}

impl StalenessPriority {
    pub fn new() -> StalenessPriority {
        StalenessPriority::default()
    }
}

impl SchedPolicy for StalenessPriority {
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize {
        self.heap.pop_min(|i| states[i].last_run).unwrap_or_else(|| {
            ready
                .iter()
                .copied()
                .min_by_key(|&i| (states[i].last_run, i))
                .expect("ready is non-empty")
        })
    }

    fn prepare(&mut self, states: &[TaskState], ready: &[usize]) {
        self.heap.prepare(states.len(), ready, |i| states[i].last_run);
    }

    fn task_ran(&mut self, task: usize, states: &[TaskState]) {
        self.heap.push(task, states[task].last_run);
    }

    fn name(&self) -> &'static str {
        "priority-by-staleness"
    }
}

/// Pick under `policy` and validate the choice against `ready`.
///
/// The shared dispatch seam for every policy consumer (the session
/// [`Fleet`] and the FL orchestrator): a misbehaving custom policy must
/// fail loudly here instead of hanging a drain loop or indexing out of
/// bounds in release builds, where a `debug_assert!` would vanish.
/// `ready` is sorted ascending (the [`SchedPolicy`] contract), so the
/// membership check is a binary search, not a scan.
pub fn pick_validated(
    policy: &mut dyn SchedPolicy,
    states: &[TaskState],
    ready: &[usize],
) -> Result<usize> {
    debug_assert!(ready.windows(2).all(|w| w[0] < w[1]), "ready must be sorted");
    let idx = policy.pick(states, ready);
    if ready.binary_search(&idx).is_err() {
        return Err(Error::Pipeline(format!(
            "policy {:?} picked non-ready task {idx} (ready: {ready:?})",
            policy.name()
        )));
    }
    Ok(idx)
}

/// Parse a policy by its CLI name.
pub fn parse_policy(name: &str) -> Result<Box<dyn SchedPolicy>> {
    match name {
        "rr" | "round-robin" => Ok(Box::new(RoundRobin::new())),
        "fewest" | "fewest-rounds-first" => Ok(Box::new(FewestRoundsFirst::new())),
        "staleness" | "priority-by-staleness" => Ok(Box::new(StalenessPriority::new())),
        other => Err(Error::Config(format!(
            "unknown scheduling policy {other:?} (rr|fewest|staleness)"
        ))),
    }
}

/// Fleet-level observer: sees every session's rounds in the order the
/// scheduler interleaves them. Per-session
/// [`RoundObserver`](crate::coordinator::session::RoundObserver)s still
/// fire inside each session; this is the cross-session fan-out
/// (dashboards, fleet-wide audits).
pub trait FleetObserver {
    /// One session completed one round.
    fn on_session_round(&mut self, _session: usize, _name: &str, _outcome: &RoundOutcome) {}

    /// One session finished its run.
    fn on_session_finished(&mut self, _session: usize, _name: &str, _record: &RunRecord) {}

    /// The fault plan fired `kind` (see [`FaultKind::name`]) against a
    /// session at its `round`.
    fn on_fault(&mut self, _session: usize, _name: &str, _round: usize, _kind: &str) {}

    /// Supervision gave up on a session: it is out of the fleet with no
    /// final record.
    fn on_session_quarantined(&mut self, _session: usize, _name: &str, _round: usize, _reason: &str) {
    }
}

/// Built-in fleet observer: logs interleaving progress at debug level.
pub struct FleetProgress {
    every: usize,
    steps: usize,
}

impl FleetProgress {
    /// Log every `every` interleaved rounds (0 = finishes only).
    pub fn every(every: usize) -> FleetProgress {
        FleetProgress { every, steps: 0 }
    }
}

impl FleetObserver for FleetProgress {
    fn on_session_round(&mut self, session: usize, name: &str, outcome: &RoundOutcome) {
        self.steps += 1;
        if self.every > 0 && self.steps % self.every == 0 {
            log::debug!(
                "fleet step {:>6}: session {session} ({name}) round {} loss {:.4}",
                self.steps,
                outcome.round + 1,
                outcome.train_loss
            );
        }
    }

    fn on_session_finished(&mut self, session: usize, name: &str, record: &RunRecord) {
        log::debug!(
            "fleet: session {session} ({name}) finished, final acc {:.2}%",
            record.final_accuracy * 100.0
        );
    }
}

/// Rebuilds a member's [`SessionBuilder`] from scratch for
/// [`SupervisionPolicy::Restart`]: same config, same backend, an
/// identically constructed data source. Determinism of the fleet under
/// restarts is exactly the determinism of this closure.
pub type SessionFactory = Box<dyn Fn() -> Result<SessionBuilder>>;

/// Builder for a [`Fleet`]: named sessions + policy + fleet observers.
pub struct FleetBuilder {
    names: Vec<String>,
    sessions: Vec<Box<Session>>,
    /// Index-aligned with `sessions`: how to rebuild each member
    /// (restart supervision); None = not restartable.
    factories: Vec<Option<SessionFactory>>,
    /// Index-aligned with `sessions`: each member's checkpoint wiring
    /// (path, cadence); None = not checkpointed.
    checkpoints: Vec<Option<(PathBuf, usize)>>,
    policy: Box<dyn SchedPolicy>,
    supervise: SupervisionPolicy,
    fault_plan: Option<FaultPlan>,
    observers: Vec<Box<dyn FleetObserver>>,
}

impl FleetBuilder {
    pub fn new() -> FleetBuilder {
        FleetBuilder {
            names: Vec::new(),
            sessions: Vec::new(),
            factories: Vec::new(),
            checkpoints: Vec::new(),
            policy: Box::new(RoundRobin::new()),
            supervise: SupervisionPolicy::FailFast,
            fault_plan: None,
            observers: Vec::new(),
        }
    }

    /// Add a session under a display name; repeatable. Sessions start
    /// lazily, so assembling a large fleet is cheap.
    pub fn session(mut self, name: impl Into<String>, session: Session) -> Self {
        self.names.push(name.into());
        self.sessions.push(Box::new(session));
        self.factories.push(None);
        self.checkpoints.push(None);
        self
    }

    /// Add a session [`SupervisionPolicy::Restart`] can rebuild: the
    /// factory must reassemble the member's [`SessionBuilder`] from
    /// scratch (same config, same backend, identically constructed data
    /// source). Without a checkpoint the rebuilt member restarts from
    /// round 0 — deterministic sessions reproduce the lost rounds
    /// exactly; pair with
    /// [`FleetBuilder::session_checkpointed_restartable`] to resume from
    /// the latest snapshot instead.
    pub fn session_restartable(
        mut self,
        name: impl Into<String>,
        factory: impl Fn() -> Result<SessionBuilder> + 'static,
    ) -> Result<Self> {
        let session = factory()?.build()?;
        self.names.push(name.into());
        self.sessions.push(Box::new(session));
        self.factories.push(Some(Box::new(factory)));
        self.checkpoints.push(None);
        Ok(self)
    }

    /// Add a session that checkpoints to `path` every `every` rounds,
    /// and — when `resume` is set — restarts from the snapshot already
    /// at `path`, so a killed `titan fleet` run picks each member back
    /// up **at its own saved round**:
    ///
    /// - no file at `path` (or `resume` unset): the member starts fresh;
    /// - a mid-run snapshot: the member resumes from it (the snapshot's
    ///   config fingerprint must match `builder`'s config — mismatches
    ///   error instead of silently diverging);
    /// - a completion marker **for the same config**: the member already
    ///   finished, so it is **skipped** (logged at info level), and the
    ///   resumed fleet runs only the unfinished members. A completion
    ///   marker whose recorded config does not match `builder`'s errors
    ///   like a mismatched mid-run snapshot would — skipping it would
    ///   silently drop a run the user actually asked for.
    pub fn session_checkpointed(
        self,
        name: impl Into<String>,
        builder: SessionBuilder,
        path: impl Into<PathBuf>,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        self.add_checkpointed(name.into(), builder, None, path.into(), every, resume)
    }

    /// [`FleetBuilder::session_checkpointed`] + a rebuild factory: under
    /// [`SupervisionPolicy::Restart`] a failed member is reassembled from
    /// the factory and resumed from the latest valid snapshot at `path`
    /// (falling back to a fresh start when the file is corrupt or
    /// missing), so recovery costs only the rounds since the last
    /// checkpoint cadence.
    pub fn session_checkpointed_restartable(
        self,
        name: impl Into<String>,
        factory: impl Fn() -> Result<SessionBuilder> + 'static,
        path: impl Into<PathBuf>,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        let builder = factory()?;
        self.add_checkpointed(
            name.into(),
            builder,
            Some(Box::new(factory)),
            path.into(),
            every,
            resume,
        )
    }

    fn add_checkpointed(
        mut self,
        name: String,
        builder: SessionBuilder,
        factory: Option<SessionFactory>,
        path: PathBuf,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        let mut builder = builder;
        if resume && path.exists() {
            match load_checkpoint(&path)? {
                Loaded::Resumable(snap) => {
                    log::info!(
                        "fleet: resuming {name:?} from {} at round {}",
                        path.display(),
                        snap.round
                    );
                    builder = builder.resume_from_snapshot(*snap);
                }
                Loaded::Complete { round, config, .. } => {
                    // Json::Null means the run finished before its first
                    // cadence snapshot — no config to verify against
                    if config != Json::Null
                        && config.to_string_compact() != builder.cfg().fingerprint()
                    {
                        return Err(Error::Config(format!(
                            "{}: completion marker belongs to a differently configured \
                             run — refusing to skip {name:?} (delete the file to start over)",
                            path.display()
                        )));
                    }
                    log::info!(
                        "fleet: {name:?} already finished ({round} rounds per {}), skipping",
                        path.display()
                    );
                    return Ok(self);
                }
            }
        }
        let session = builder.observe(Checkpoint::every(path.clone(), every)).build()?;
        self.names.push(name);
        self.sessions.push(Box::new(session));
        self.factories.push(factory);
        self.checkpoints.push(Some((path, every)));
        Ok(self)
    }

    /// Sessions added so far (resume may skip completed members — see
    /// [`FleetBuilder::session_checkpointed`] — so a caller can detect an
    /// everything-already-finished resume before `build` errors on an
    /// empty fleet).
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Replace the default round-robin policy.
    pub fn policy(mut self, policy: impl SchedPolicy + 'static) -> Self {
        self.policy = Box::new(policy);
        self
    }

    /// Replace the policy with an already-boxed one (CLI parsing).
    pub fn policy_boxed(mut self, policy: Box<dyn SchedPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// What the scheduler does when a session fails (injected or real).
    /// Default: [`SupervisionPolicy::FailFast`], the historical
    /// abort-the-fleet behavior.
    pub fn supervise(mut self, policy: SupervisionPolicy) -> Self {
        self.supervise = policy;
        self
    }

    /// Attach a deterministic fault-injection plan; validated at
    /// [`Fleet::run`]. A zero-rate plan injects nothing and leaves every
    /// deterministic output bit-identical to an unfaulted fleet.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Attach a fleet observer; repeatable, invoked in attach order.
    pub fn observe(mut self, observer: impl FleetObserver + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Assemble the fleet. Errors on an empty session list.
    pub fn build(self) -> Result<Fleet> {
        if self.sessions.is_empty() {
            return Err(Error::Config("fleet needs at least one session".into()));
        }
        Ok(Fleet {
            names: self.names,
            sessions: self.sessions,
            factories: self.factories,
            checkpoints: self.checkpoints,
            policy: self.policy,
            supervise: self.supervise,
            fault_plan: self.fault_plan,
            observers: self.observers,
        })
    }

    /// Build and run in one step.
    pub fn run(self) -> Result<FleetRecord> {
        self.build()?.run()
    }
}

impl Default for FleetBuilder {
    fn default() -> Self {
        FleetBuilder::new()
    }
}

/// N boxed sessions interleaved round-by-round under one [`SchedPolicy`].
pub struct Fleet {
    names: Vec<String>,
    sessions: Vec<Box<Session>>,
    factories: Vec<Option<SessionFactory>>,
    checkpoints: Vec<Option<(PathBuf, usize)>>,
    policy: Box<dyn SchedPolicy>,
    supervise: SupervisionPolicy,
    fault_plan: Option<FaultPlan>,
    observers: Vec<Box<dyn FleetObserver>>,
}

impl Fleet {
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Drive every session to a terminal state under the configured
    /// supervision policy, one round per scheduler tick.
    ///
    /// Under [`SupervisionPolicy::FailFast`] (the default) a session
    /// error aborts the whole fleet (the scheduler acting as a
    /// single-tenant research runtime, not an isolator) and the error
    /// names the session that failed — the historical contract, byte for
    /// byte. `Isolate` and `Restart` turn failures into per-session
    /// [`SessionStatus`]es instead and the fleet runs to completion.
    pub fn run(mut self) -> Result<FleetRecord> {
        if let Some(plan) = &self.fault_plan {
            plan.validate()?;
        }
        let n = self.sessions.len();
        let fleet_sw = Stopwatch::start();
        let mut states = vec![TaskState::default(); n];
        let mut records: Vec<Option<RunRecord>> = (0..n).map(|_| None).collect();
        let mut statuses: Vec<Option<SessionStatus>> = vec![None; n];
        let mut ready: Vec<usize> = (0..n).collect();
        // restart backoff: (scheduler tick at which the session re-enters
        // the ready set, session index)
        let mut parked: Vec<(u64, usize)> = Vec::new();
        let mut restarts_used = vec![0usize; n];
        // (session, session-round) cells whose fault already fired: a
        // Transient clears on retry, and a restarted member replaying
        // earlier rounds does not re-crash on the same cell
        let mut fired: HashSet<(usize, usize)> = HashSet::new();
        let mut faults = FaultTelemetry::default();
        let mut rounds_executed = 0usize;
        let mut device_ops = 0u64;
        let mut step_ms = 0.0f64;
        // scheduler clock for staleness: one O(1) last_run write per tick
        // replaces the old all-tasks aging pass (O(N) per round)
        let mut tick = 0u64;
        self.policy.prepare(&states, &ready);

        loop {
            // re-admit parked (restarting) sessions whose backoff elapsed;
            // with nothing ready, jump the clock to the next wake-up. The
            // clock is scheduler ticks, so backoff is simulation-
            // deterministic — no wall time involved.
            if !parked.is_empty() {
                if ready.is_empty() {
                    let wake =
                        parked.iter().map(|&(at, _)| at).min().expect("parked is non-empty");
                    tick = tick.max(wake);
                }
                if parked.iter().any(|&(at, _)| at <= tick) {
                    let mut due: Vec<usize> = parked
                        .iter()
                        .filter(|&&(at, _)| at <= tick)
                        .map(|&(_, i)| i)
                        .collect();
                    parked.retain(|&(at, _)| at > tick);
                    due.sort_unstable();
                    for i in due {
                        if let Err(pos) = ready.binary_search(&i) {
                            ready.insert(pos, i);
                        }
                    }
                    self.policy.prepare(&states, &ready);
                }
            }
            if ready.is_empty() {
                break;
            }

            let idx = pick_validated(self.policy.as_mut(), &states, &ready)?;

            // fault injection, keyed on the session's own round (not the
            // fleet tick) so the plan names cells a user can reason
            // about; skipped on the finishing step, which runs no round
            let session_round = self.sessions[idx].rounds_completed();
            let fault = self
                .fault_plan
                .as_ref()
                .filter(|_| session_round < self.sessions[idx].cfg().rounds)
                .and_then(|plan| plan.fault_for(idx, session_round))
                .filter(|_| fired.insert((idx, session_round)));
            if let Some(kind) = fault {
                faults.record(idx, session_round, &kind);
                for obs in self.observers.iter_mut() {
                    obs.on_fault(idx, &self.names[idx], session_round, kind.name());
                }
                match kind {
                    FaultKind::Transient => {
                        // clears on retry: the session stays ready, but
                        // the pick consumed the policy's indexed entry
                        self.policy.prepare(&states, &ready);
                        continue;
                    }
                    FaultKind::Straggler { slowdown } => {
                        self.sessions[idx].inject_slowdown(slowdown);
                    }
                    FaultKind::EnergyBrownout { joules } => {
                        self.sessions[idx].inject_brownout(joules);
                    }
                    FaultKind::CorruptCheckpoint => self.corrupt_checkpoint(idx),
                    FaultKind::Crash => {
                        self.handle_failure(
                            idx,
                            session_round,
                            "injected crash".into(),
                            tick,
                            &states,
                            &mut ready,
                            &mut parked,
                            &mut statuses,
                            &mut restarts_used,
                            &mut faults,
                        )?;
                        continue;
                    }
                }
            }

            let step_sw = Stopwatch::start();
            let stepped = self.sessions[idx].step();
            step_ms += step_sw.elapsed_ms();
            let event = match stepped {
                Ok(event) => event,
                Err(e) => {
                    self.handle_failure(
                        idx,
                        session_round,
                        e.to_string(),
                        tick,
                        &states,
                        &mut ready,
                        &mut parked,
                        &mut statuses,
                        &mut restarts_used,
                        &mut faults,
                    )?;
                    continue;
                }
            };
            match event {
                StepEvent::RoundCompleted(outcome) => {
                    states[idx].rounds_done += 1;
                    tick += 1;
                    states[idx].last_run = tick;
                    self.policy.task_ran(idx, &states);
                    rounds_executed += 1;
                    // +1: the round's TrainStep on the CPU lane (selector
                    // ops are the GPU-lane charge)
                    device_ops += outcome.selector.ops.len() as u64 + 1;
                    for obs in self.observers.iter_mut() {
                        obs.on_session_round(idx, &self.names[idx], &outcome);
                    }
                    // drain the outcome the session retained: the fleet
                    // surface for per-round data is the observer fan-out,
                    // and keeping N x R outcomes alive across in-flight
                    // sessions would grow with fleet size
                    self.sessions[idx].take_outcomes();
                }
                StepEvent::Finished(record) => {
                    for obs in self.observers.iter_mut() {
                        obs.on_session_finished(idx, &self.names[idx], &record);
                    }
                    records[idx] = Some(record);
                    statuses[idx] = Some(SessionStatus::Finished);
                    ready.retain(|&i| i != idx);
                }
            }
        }

        // every session that left the ready set carries a terminal
        // status; a scheduler bug that dropped one reports as quarantined
        // instead of panicking the whole fleet
        let statuses: Vec<SessionStatus> = statuses
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.unwrap_or_else(|| SessionStatus::Quarantined {
                    round: states[i].rounds_done,
                    reason: "scheduler exited without a terminal status".into(),
                })
            })
            .collect();
        let total_host_ms = fleet_sw.elapsed_ms();
        let finished = records.iter().flatten();
        // fleet-wide retention aggregate: component-wise sum over the
        // finished members that retained; None when no member did
        let retention = finished
            .clone()
            .filter_map(|r| r.retention.as_ref())
            .fold(None, |acc: Option<crate::retention::RetentionTelemetry>, t| {
                let mut sum = acc.unwrap_or_default();
                sum.merge(t);
                Some(sum)
            });
        Ok(FleetRecord {
            policy: self.policy.name().to_string(),
            supervision: self.supervise.name().to_string(),
            names: self.names,
            session_rounds: states.iter().map(|s| s.rounds_done).collect(),
            rounds_executed,
            device_ops,
            total_device_ms: finished.clone().map(|r| r.total_device_ms).sum(),
            energy_j: finished.clone().map(|r| r.energy_j).sum(),
            peak_memory_bytes: finished.map(|r| r.peak_memory_bytes).sum(),
            records,
            statuses,
            faults,
            fault_plan: self.fault_plan.as_ref().map(|p| p.to_json()),
            retention,
            total_host_ms,
            sched_overhead_ms: (total_host_ms - step_ms).max(0.0),
        })
    }

    /// Apply the supervision policy to one failed session. `FailFast`
    /// returns the historical fleet-aborting error; `Isolate` and
    /// `Restart` mutate the scheduler state and return `Ok`.
    #[allow(clippy::too_many_arguments)]
    fn handle_failure(
        &mut self,
        idx: usize,
        round: usize,
        reason: String,
        tick: u64,
        states: &[TaskState],
        ready: &mut Vec<usize>,
        parked: &mut Vec<(u64, usize)>,
        statuses: &mut [Option<SessionStatus>],
        restarts_used: &mut [usize],
        faults: &mut FaultTelemetry,
    ) -> Result<()> {
        match self.supervise {
            SupervisionPolicy::FailFast => {
                Err(Error::Pipeline(format!("fleet session {:?}: {reason}", self.names[idx])))
            }
            SupervisionPolicy::Isolate => {
                self.quarantine(idx, round, reason, ready, statuses, faults);
                self.policy.prepare(states, ready);
                Ok(())
            }
            SupervisionPolicy::Restart { max_retries, backoff_rounds } => {
                if restarts_used[idx] >= max_retries {
                    let reason = format!("{reason} ({max_retries} restarts exhausted)");
                    self.quarantine(idx, round, reason, ready, statuses, faults);
                } else {
                    match self.rebuild_session(idx) {
                        Ok(resumed_round) => {
                            restarts_used[idx] += 1;
                            faults.restarts += 1;
                            faults.rounds_recovered += round.saturating_sub(resumed_round);
                            log::info!(
                                "fleet: restarting session {:?} from round {resumed_round} \
                                 (failed at {round}: {reason}; retry {}/{max_retries}, \
                                 backoff {backoff_rounds} ticks)",
                                self.names[idx],
                                restarts_used[idx],
                            );
                            ready.retain(|&i| i != idx);
                            parked.push((tick + backoff_rounds as u64, idx));
                        }
                        Err(e) => {
                            let reason = format!("{reason}; restart failed: {e}");
                            self.quarantine(idx, round, reason, ready, statuses, faults);
                        }
                    }
                }
                self.policy.prepare(states, ready);
                Ok(())
            }
        }
    }

    /// Remove a session from scheduling with a terminal
    /// [`SessionStatus::Quarantined`]; the rest of the fleet keeps
    /// running.
    fn quarantine(
        &mut self,
        idx: usize,
        round: usize,
        reason: String,
        ready: &mut Vec<usize>,
        statuses: &mut [Option<SessionStatus>],
        faults: &mut FaultTelemetry,
    ) {
        log::warn!(
            "fleet: quarantining session {:?} at round {round}: {reason}",
            self.names[idx]
        );
        for obs in self.observers.iter_mut() {
            obs.on_session_quarantined(idx, &self.names[idx], round, &reason);
        }
        statuses[idx] = Some(SessionStatus::Quarantined { round, reason });
        ready.retain(|&i| i != idx);
        faults.quarantines += 1;
    }

    /// Rebuild session `idx` from its factory for restart supervision,
    /// resuming from its latest valid checkpoint when it has one; a
    /// corrupt (or otherwise unusable) checkpoint file degrades to a
    /// fresh start — deterministic sessions reproduce the lost rounds
    /// exactly. Returns the round the rebuilt session starts from.
    fn rebuild_session(&mut self, idx: usize) -> Result<usize> {
        let Some(factory) = &self.factories[idx] else {
            return Err(Error::Config(
                "no session factory registered (use session_restartable / \
                 session_checkpointed_restartable)"
                    .into(),
            ));
        };
        let mut builder = factory()?;
        let mut resumed_round = 0usize;
        if let Some((path, every)) = &self.checkpoints[idx] {
            if path.exists() {
                match load_checkpoint(path) {
                    Ok(Loaded::Resumable(snap)) => {
                        resumed_round = snap.round;
                        builder = builder.resume_from_snapshot(*snap);
                    }
                    Ok(Loaded::Complete { .. }) => {
                        log::warn!(
                            "fleet: {} marks a completed run but the session failed — \
                             restarting from scratch",
                            path.display()
                        );
                    }
                    Err(e) => {
                        log::warn!("fleet: discarding unusable checkpoint: {e}");
                    }
                }
            }
            builder = builder.observe(Checkpoint::every(path.clone(), *every));
        }
        self.sessions[idx] = Box::new(builder.build()?);
        Ok(resumed_round)
    }

    /// Injected checkpoint corruption: truncate the member's on-disk
    /// snapshot to half its size (a torn write). The typed loader rejects
    /// the remnant, so a later restart falls back to a fresh start; a
    /// member without checkpoint wiring makes this a no-op.
    fn corrupt_checkpoint(&self, idx: usize) {
        let Some((path, _)) = &self.checkpoints[idx] else { return };
        let Ok(meta) = std::fs::metadata(path) else { return };
        let result = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .and_then(|f| f.set_len(meta.len() / 2));
        if let Err(e) = result {
            log::warn!("fleet: corrupt-checkpoint fault on {} failed: {e}", path.display());
        }
    }
}

/// How one fleet member ended its run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionStatus {
    /// The session ran to completion and has a [`RunRecord`].
    Finished,
    /// Supervision gave up on the session at its `round`; it has no
    /// final record.
    Quarantined {
        /// The session-local round at which supervision gave up.
        round: usize,
        /// Why (the failing error, or the injected fault).
        reason: String,
    },
}

impl SessionStatus {
    pub fn is_finished(&self) -> bool {
        matches!(self, SessionStatus::Finished)
    }

    /// Display/JSON label: `finished` or `quarantined`.
    pub fn label(&self) -> &'static str {
        match self {
            SessionStatus::Finished => "finished",
            SessionStatus::Quarantined { .. } => "quarantined",
        }
    }
}

/// One injected fault, in injection order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Fleet index of the session the fault hit.
    pub session: usize,
    /// The session-local round it hit at.
    pub round: usize,
    /// [`FaultKind::name`] of what fired.
    pub kind: String,
}

/// Fault + supervision telemetry for one fleet run. Fully deterministic
/// for a given (config, fault plan) pair — it counts injected faults and
/// the scheduler's deterministic reactions, never wall-clock effects.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultTelemetry {
    /// Injected `Crash` faults.
    pub crashes: usize,
    /// Injected `Transient` faults (each also counts one retry).
    pub transients: usize,
    /// Picks consumed by a fault that left the session ready to retry.
    pub retries: usize,
    /// Injected `Straggler` slowdowns.
    pub stragglers: usize,
    /// Injected `EnergyBrownout` drains.
    pub brownouts: usize,
    /// Injected `CorruptCheckpoint` truncations.
    pub corruptions: usize,
    /// Successful session rebuilds under restart supervision.
    pub restarts: usize,
    /// Sessions supervision gave up on.
    pub quarantines: usize,
    /// Σ over restarts of (failed-at round − resumed-from round): rounds
    /// a checkpoint saved the fleet from re-running. 0 with no
    /// checkpoints (scratch restarts re-run everything).
    pub rounds_recovered: usize,
    /// Every injected fault, in injection order.
    pub events: Vec<FaultEvent>,
}

impl FaultTelemetry {
    /// Count one injected fault and append it to the event log.
    fn record(&mut self, session: usize, round: usize, kind: &FaultKind) {
        match kind {
            FaultKind::Crash => self.crashes += 1,
            FaultKind::Transient => {
                self.transients += 1;
                self.retries += 1;
            }
            FaultKind::Straggler { .. } => self.stragglers += 1,
            FaultKind::EnergyBrownout { .. } => self.brownouts += 1,
            FaultKind::CorruptCheckpoint => self.corruptions += 1,
        }
        self.events.push(FaultEvent { session, round, kind: kind.name().to_string() });
    }

    /// Total injected faults.
    pub fn total(&self) -> usize {
        self.events.len()
    }

    pub fn to_json(&self) -> Json {
        let events = Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("session", Json::Num(e.session as f64)),
                        ("round", Json::Num(e.round as f64)),
                        ("kind", Json::Str(e.kind.clone())),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("crashes", Json::Num(self.crashes as f64)),
            ("transients", Json::Num(self.transients as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("stragglers", Json::Num(self.stragglers as f64)),
            ("brownouts", Json::Num(self.brownouts as f64)),
            ("corruptions", Json::Num(self.corruptions as f64)),
            ("restarts", Json::Num(self.restarts as f64)),
            ("quarantines", Json::Num(self.quarantines as f64)),
            ("rounds_recovered", Json::Num(self.rounds_recovered as f64)),
            ("events", events),
        ])
    }
}

/// Aggregate record of one fleet run: per-session [`RunRecord`]s plus the
/// shared host accounting.
#[derive(Clone, Debug)]
pub struct FleetRecord {
    /// Policy display name.
    pub policy: String,
    /// Supervision policy display name ([`SupervisionPolicy::name`]).
    pub supervision: String,
    /// Session display names, index-aligned with `records`/`statuses`.
    pub names: Vec<String>,
    /// Final per-session records — `Some` exactly for
    /// [`SessionStatus::Finished`] members, and identical to solo runs
    /// for every session that is reproducible solo (see the module
    /// docs).
    pub records: Vec<Option<RunRecord>>,
    /// How each session ended.
    pub statuses: Vec<SessionStatus>,
    /// Rounds each session completed **in this fleet run** (a restarted
    /// member counts replayed rounds again — they were re-executed).
    pub session_rounds: Vec<usize>,
    /// Total interleaved rounds across all sessions.
    pub rounds_executed: usize,
    /// Device-sim ops charged across all sessions (selector ops + one
    /// train step per round).
    pub device_ops: u64,
    /// Σ per-session simulated device clocks (ms), finished members only.
    pub total_device_ms: f64,
    /// Host wall clock of the whole fleet run (ms).
    pub total_host_ms: f64,
    /// Host wall time outside `Session::step` — scheduling, bookkeeping
    /// and fleet-observer fan-out (ms).
    pub sched_overhead_ms: f64,
    /// Σ per-session simulated energy (J), finished members only.
    pub energy_j: f64,
    /// Σ per-session peak-memory estimates (bytes) — every session's
    /// working set is resident concurrently on the host.
    pub peak_memory_bytes: usize,
    /// Injected-fault and supervision telemetry (all zero with no plan
    /// or a zero-rate plan).
    pub faults: FaultTelemetry,
    /// The fault plan that ran, serialized ([`FaultPlan::to_json`]);
    /// None when the fleet ran unfaulted.
    pub fault_plan: Option<Json>,
    /// Component-wise sum of finished members' retention telemetry
    /// (`bytes_held` reads as total bytes held across members); None when
    /// no member retained.
    pub retention: Option<crate::retention::RetentionTelemetry>,
}

impl FleetRecord {
    /// Scheduler overhead amortized per interleaved round (ms).
    pub fn sched_overhead_per_round_ms(&self) -> f64 {
        if self.rounds_executed == 0 {
            0.0
        } else {
            self.sched_overhead_ms / self.rounds_executed as f64
        }
    }

    /// Finished sessions (those with a [`RunRecord`]).
    pub fn finished(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_finished()).count()
    }

    pub fn to_json(&self) -> Json {
        let sessions = Json::Arr(
            self.names
                .iter()
                .zip(&self.records)
                .zip(self.statuses.iter().zip(&self.session_rounds))
                .map(|((name, record), (status, &rounds))| {
                    let mut fields = vec![
                        ("name", Json::Str(name.clone())),
                        ("rounds", Json::Num(rounds as f64)),
                        ("status", Json::Str(status.label().into())),
                    ];
                    if let SessionStatus::Quarantined { round, reason } = status {
                        fields.push(("quarantine_round", Json::Num(*round as f64)));
                        fields.push(("reason", Json::Str(reason.clone())));
                    }
                    fields
                        .push(("record", record.as_ref().map_or(Json::Null, |r| r.to_json())));
                    Json::obj(fields)
                })
                .collect(),
        );
        let mut fields = vec![
            ("policy", Json::Str(self.policy.clone())),
            ("supervision", Json::Str(self.supervision.clone())),
            ("sessions", sessions),
            ("rounds_executed", Json::Num(self.rounds_executed as f64)),
            ("device_ops", Json::Num(self.device_ops as f64)),
            ("total_device_ms", Json::Num(self.total_device_ms)),
            ("total_host_ms", Json::Num(self.total_host_ms)),
            ("sched_overhead_ms", Json::Num(self.sched_overhead_ms)),
            (
                "sched_overhead_per_round_ms",
                Json::Num(self.sched_overhead_per_round_ms()),
            ),
            ("energy_j", Json::Num(self.energy_j)),
            ("peak_memory_bytes", Json::Num(self.peak_memory_bytes as f64)),
            ("faults", self.faults.to_json()),
        ];
        if let Some(plan) = &self.fault_plan {
            fields.push(("fault_plan", plan.clone()));
        }
        if let Some(t) = &self.retention {
            fields.push(("retention", t.to_json()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(rounds: &[usize], last_run: &[u64]) -> Vec<TaskState> {
        rounds
            .iter()
            .zip(last_run)
            .map(|(&rounds_done, &last_run)| TaskState { rounds_done, last_run })
            .collect()
    }

    #[test]
    fn round_robin_cycles_and_skips_finished() {
        let mut p = RoundRobin::new();
        let s = states(&[0, 0, 0], &[0, 0, 0]);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 0);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 1);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 2);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 0); // wraps
        // session 1 finished: the cycle skips it
        assert_eq!(p.pick(&s, &[0, 2]), 2);
        assert_eq!(p.pick(&s, &[0, 2]), 0);
    }

    #[test]
    fn fewest_rounds_prefers_laggards_then_index() {
        // unprepared policy: the scan fallback answers
        let mut p = FewestRoundsFirst::new();
        let s = states(&[3, 1, 1, 5], &[0, 0, 0, 0]);
        assert_eq!(p.pick(&s, &[0, 1, 2, 3]), 1); // min rounds, tie -> min index
        assert_eq!(p.pick(&s, &[0, 2, 3]), 2);
        assert_eq!(p.pick(&s, &[0, 3]), 0);
    }

    #[test]
    fn staleness_prefers_longest_waiting_then_index() {
        // staleness = ticks since last_run, so stalest = smallest last_run
        let mut p = StalenessPriority::new();
        let s = states(&[0, 0, 0, 0], &[5, 1, 1, 6]);
        assert_eq!(p.pick(&s, &[0, 1, 2, 3]), 1); // max staleness, tie -> min index
        assert_eq!(p.pick(&s, &[0, 2, 3]), 2);
        assert_eq!(p.pick(&s, &[0, 3]), 0);
    }

    /// THE policy-order equivalence pin (N ≤ 100): the heap-backed path
    /// (driven through prepare/task_ran) must reproduce the scan
    /// fallback's pick sequence exactly, through runs, finishes and
    /// re-preparations, for both keyed policies.
    #[test]
    fn heap_policies_match_scan_reference() {
        for n in [1usize, 2, 3, 17, 100] {
            for seed in 0..5u64 {
                check_heap_vs_scan(&mut FewestRoundsFirst::new(), n, seed);
                check_heap_vs_scan(&mut StalenessPriority::new(), n, seed);
            }
        }
    }

    fn check_heap_vs_scan(heap: &mut dyn SchedPolicy, n: usize, seed: u64) {
        // scan twin: same type, never prepared -> always the scan path.
        // Both twins see the same states; only the heap one gets hooks.
        let mut scan = match heap.name() {
            "fewest-rounds-first" => {
                Box::new(FewestRoundsFirst::new()) as Box<dyn SchedPolicy>
            }
            _ => Box::new(StalenessPriority::new()),
        };
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed ^ n as u64);
        let budgets: Vec<usize> = (0..n).map(|_| 1 + rng.index(6)).collect();
        let mut states = vec![TaskState::default(); n];
        let mut ready: Vec<usize> = (0..n).collect();
        let mut tick = 0u64;
        heap.prepare(&states, &ready);
        while !ready.is_empty() {
            let a = pick_validated(heap, &states, &ready).unwrap();
            let b = pick_validated(scan.as_mut(), &states, &ready).unwrap();
            assert_eq!(a, b, "{} n={n} seed={seed} tick={tick}", heap.name());
            states[a].rounds_done += 1;
            tick += 1;
            states[a].last_run = tick;
            if states[a].rounds_done >= budgets[a] {
                ready.retain(|&i| i != a); // finished: no task_ran
            } else {
                heap.task_ran(a, &states);
            }
        }
    }

    #[test]
    fn pick_validated_rejects_misbehaving_policy() {
        struct Bad;
        impl SchedPolicy for Bad {
            fn pick(&mut self, _states: &[TaskState], _ready: &[usize]) -> usize {
                999 // out of range AND not ready
            }
            fn name(&self) -> &'static str {
                "bad"
            }
        }
        let s = states(&[0, 0], &[0, 0]);
        assert!(pick_validated(&mut Bad, &s, &[0, 1]).is_err());
        assert_eq!(pick_validated(&mut RoundRobin::new(), &s, &[1]).unwrap(), 1);
    }

    #[test]
    fn policy_parsing() {
        for (name, want) in [
            ("rr", "round-robin"),
            ("round-robin", "round-robin"),
            ("fewest", "fewest-rounds-first"),
            ("staleness", "priority-by-staleness"),
        ] {
            assert_eq!(parse_policy(name).unwrap().name(), want);
        }
        assert!(parse_policy("bogus").is_err());
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(FleetBuilder::new().build().is_err());
    }

    // Sessions start lazily, so supervision paths driven entirely by
    // scripted round-0 crashes (which fire *before* the first step) are
    // testable without model artifacts.

    fn unstarted_session(rounds: usize) -> Session {
        let mut cfg = presets::table1("mlp", Method::Rs);
        cfg.rounds = rounds;
        cfg.pipeline = false;
        SessionBuilder::new(cfg).build().unwrap()
    }

    fn crash_everyone(n: usize) -> FaultPlan {
        let mut plan = FaultPlan::new(0);
        for i in 0..n {
            plan = plan.script(i, 0, FaultKind::Crash);
        }
        plan
    }

    #[test]
    fn scripted_crashes_quarantine_under_isolate() {
        let record = FleetBuilder::new()
            .session("a", unstarted_session(3))
            .session("b", unstarted_session(3))
            .supervise(SupervisionPolicy::Isolate)
            .fault_plan(crash_everyone(2))
            .run()
            .unwrap();
        assert_eq!(record.supervision, "isolate");
        assert_eq!(record.rounds_executed, 0);
        assert_eq!(record.finished(), 0);
        for (status, rec) in record.statuses.iter().zip(&record.records) {
            assert_eq!(
                status,
                &SessionStatus::Quarantined { round: 0, reason: "injected crash".into() }
            );
            assert!(rec.is_none());
        }
        assert_eq!(record.faults.crashes, 2);
        assert_eq!(record.faults.quarantines, 2);
        assert_eq!(record.faults.total(), 2);
        assert!(record.fault_plan.is_some());
    }

    #[test]
    fn scripted_crash_aborts_under_failfast() {
        let err = FleetBuilder::new()
            .session("doomed", unstarted_session(3))
            .fault_plan(crash_everyone(1))
            .run()
            .unwrap_err();
        // the historical fleet-abort shape, naming the session
        assert_eq!(err.to_string(), "pipeline error: fleet session \"doomed\": injected crash");
    }

    #[test]
    fn restart_without_factory_quarantines() {
        let record = FleetBuilder::new()
            .session("fixed", unstarted_session(3))
            .supervise(SupervisionPolicy::Restart { max_retries: 2, backoff_rounds: 1 })
            .fault_plan(crash_everyone(1))
            .run()
            .unwrap();
        assert_eq!(record.faults.restarts, 0);
        assert_eq!(record.faults.quarantines, 1);
        let SessionStatus::Quarantined { round, reason } = &record.statuses[0] else {
            panic!("expected quarantine, got {:?}", record.statuses[0]);
        };
        assert_eq!(*round, 0);
        assert!(reason.contains("restart failed"), "unexpected reason: {reason}");
        assert!(reason.contains("no session factory"), "unexpected reason: {reason}");
    }

    #[test]
    fn restart_quarantines_when_the_factory_breaks() {
        // factory works for the initial build, then breaks — the restart
        // path must degrade to quarantine, not abort the fleet
        let calls = std::rc::Rc::new(std::cell::Cell::new(0usize));
        let seen = std::rc::Rc::clone(&calls);
        let factory = move || {
            seen.set(seen.get() + 1);
            if seen.get() > 1 {
                return Err(Error::Other("factory broke".into()));
            }
            let mut cfg = presets::table1("mlp", Method::Rs);
            cfg.rounds = 3;
            cfg.pipeline = false;
            Ok(SessionBuilder::new(cfg))
        };
        let record = FleetBuilder::new()
            .session_restartable("flaky", factory)
            .unwrap()
            .supervise(SupervisionPolicy::Restart { max_retries: 2, backoff_rounds: 0 })
            .fault_plan(crash_everyone(1))
            .run()
            .unwrap();
        assert_eq!(calls.get(), 2, "initial build + one rebuild attempt");
        assert_eq!(record.faults.restarts, 0);
        let SessionStatus::Quarantined { reason, .. } = &record.statuses[0] else {
            panic!("expected quarantine, got {:?}", record.statuses[0]);
        };
        assert!(reason.contains("factory broke"), "unexpected reason: {reason}");
    }

    #[test]
    fn zero_rate_plan_injects_nothing() {
        let plan = FaultPlan::new(42);
        assert!(plan.is_zero());
        let record = FleetBuilder::new()
            .session("a", unstarted_session(3))
            .supervise(SupervisionPolicy::Isolate)
            .fault_plan(plan)
            .run()
            .unwrap();
        // without artifacts the session fails at start and is isolated
        // (a real failure, counted as a quarantine); with artifacts it
        // finishes — either way the plan injected nothing
        assert_eq!(record.faults.total(), 0);
        assert!(record.faults.events.is_empty());
        assert_eq!(record.faults.restarts, 0);
        assert_eq!(record.faults.rounds_recovered, 0);
    }

    #[test]
    fn fleet_record_json_shape() {
        let mut faults = FaultTelemetry::default();
        faults.record(1, 3, &FaultKind::Crash);
        faults.quarantines = 1;
        let rec = FleetRecord {
            policy: "round-robin".into(),
            supervision: "isolate".into(),
            names: vec!["a".into(), "b".into()],
            records: vec![Some(RunRecord::new("rs", "mlp")), None],
            statuses: vec![
                SessionStatus::Finished,
                SessionStatus::Quarantined { round: 3, reason: "injected crash".into() },
            ],
            session_rounds: vec![4, 3],
            rounds_executed: 10,
            device_ops: 25,
            total_device_ms: 1234.5,
            total_host_ms: 80.0,
            sched_overhead_ms: 2.0,
            energy_j: 9.0,
            peak_memory_bytes: 2048,
            faults,
            fault_plan: Some(FaultPlan::new(7).to_json()),
            retention: None,
        };
        assert!((rec.sched_overhead_per_round_ms() - 0.2).abs() < 1e-12);
        assert_eq!(rec.finished(), 1);
        let j = rec.to_json();
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(), "round-robin");
        assert_eq!(j.get("supervision").unwrap().as_str().unwrap(), "isolate");
        let sessions = j.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].get("status").unwrap().as_str().unwrap(), "finished");
        assert!(sessions[0].get("record").unwrap() != &Json::Null);
        assert_eq!(sessions[1].get("status").unwrap().as_str().unwrap(), "quarantined");
        assert_eq!(sessions[1].get("quarantine_round").unwrap().as_usize().unwrap(), 3);
        assert_eq!(sessions[1].get("reason").unwrap().as_str().unwrap(), "injected crash");
        assert_eq!(sessions[1].get("record").unwrap(), &Json::Null);
        let faults = j.get("faults").unwrap();
        assert_eq!(faults.get("crashes").unwrap().as_usize().unwrap(), 1);
        assert_eq!(faults.get("quarantines").unwrap().as_usize().unwrap(), 1);
        assert_eq!(faults.get("events").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("fault_plan").is_ok());
        assert!(j.get("retention").is_err(), "no retaining member, no retention key");
        assert_eq!(j.get("rounds_executed").unwrap().as_usize().unwrap(), 10);
        // a fleet with a retention aggregate emits it
        let mut with_ret = rec.clone();
        let mut t = crate::retention::RetentionTelemetry::default();
        t.offers = 12;
        t.bytes_held = 4096;
        with_ret.retention = Some(t);
        let j = with_ret.to_json();
        assert_eq!(j.get("retention").unwrap().get("offers").unwrap().as_usize().unwrap(), 12);
        let roundtrip = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            roundtrip.get("sched_overhead_per_round_ms").unwrap().as_f64().unwrap(),
            0.2
        );
    }

    // ---- artifact-gated fleet runs ------------------------------------

    use crate::config::{presets, Method};
    use crate::coordinator::SessionBuilder;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny_session(method: Method, rounds: usize, seed_off: u64) -> Session {
        let mut cfg = presets::table1("mlp", method);
        cfg.rounds = rounds;
        cfg.test_size = 200;
        cfg.eval_every = 2;
        cfg.pipeline = false;
        cfg.seed += seed_off;
        SessionBuilder::new(cfg).build().unwrap()
    }

    /// A fleet observer that records the interleaving for assertions.
    struct Trace(std::rc::Rc<std::cell::RefCell<Vec<(usize, usize)>>>);

    impl FleetObserver for Trace {
        fn on_session_round(&mut self, session: usize, _name: &str, outcome: &RoundOutcome) {
            self.0.borrow_mut().push((session, outcome.round));
        }
    }

    #[test]
    fn round_robin_interleaves_heterogeneous_sessions() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let trace = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let record = FleetBuilder::new()
            .session("short", tiny_session(Method::Rs, 2, 0))
            .session("long", tiny_session(Method::Rs, 4, 1))
            .observe(Trace(std::rc::Rc::clone(&trace)))
            .run()
            .unwrap();
        assert_eq!(record.session_rounds, vec![2, 4]);
        assert_eq!(record.rounds_executed, 6);
        assert_eq!(record.records.len(), 2);
        assert!(record.records.iter().all(|r| r.is_some()));
        assert!(record.statuses.iter().all(|s| s.is_finished()));
        assert_eq!(record.supervision, "failfast");
        assert_eq!(record.faults, FaultTelemetry::default());
        assert!(record.fault_plan.is_none());
        // strict alternation while both live, then the long tail
        let seen = trace.borrow().clone();
        assert_eq!(
            seen,
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (1, 2), (1, 3)],
            "unexpected interleaving: {seen:?}"
        );
        assert!(record.total_device_ms > 0.0);
        assert!(record.peak_memory_bytes > 0);
    }
}
