//! The host fleet runtime — many device sessions multiplexed on one host.
//!
//! The ROADMAP north star is a host serving millions of device sessions;
//! the prerequisite is that no session may own a thread for its whole
//! run. [`crate::coordinator::session::Session`] is a step-driven state
//! machine, so a [`Fleet`] can own N boxed sessions and interleave them
//! **round-by-round** on one scheduler thread: each scheduler tick picks
//! one ready session under a pluggable [`SchedPolicy`] and advances it by
//! exactly one [`StepEvent`].
//!
//! Sessions are fully independent (own data source, own engines, own
//! device sim), so the interleaving order cannot perturb any session's
//! output: for every session that is reproducible solo — any
//! sequential-backend session, and pipelined sessions with
//! parameter-independent selection — the per-session [`RunRecord`] in a
//! fleet is identical to the solo record, under every policy (pinned by
//! the fleet integration tests). Pipelined sessions with
//! parameter-*dependent* selection are timing-sensitive by design (the
//! latest-only param slot; see the session module docs), so their
//! records vary run-to-run with or without a fleet around them.
//!
//! Shared host accounting rolls up into a [`FleetRecord`]: aggregate
//! simulated device time and ops, energy, the summed peak-memory estimate
//! (all sessions are resident concurrently), and the scheduler's own
//! overhead (host wall time *not* spent inside `Session::step` — the
//! pick + bookkeeping + observer fan-out cost per interleaved round,
//! tracked in PERF.md).
//!
//! Edge fleets get killed; [`FleetBuilder::session_checkpointed`] wires
//! each member to its own on-disk snapshot (the
//! [`observers::Checkpoint`](crate::coordinator::session::observers::Checkpoint)
//! observer) so a restarted `titan fleet --resume` run picks every
//! member back up at its own saved round instead of re-spending
//! device-ms from round 0.
//!
//! ```no_run
//! use titan::config::{presets, Method};
//! use titan::coordinator::host::{FewestRoundsFirst, FleetBuilder};
//! use titan::coordinator::SessionBuilder;
//!
//! let mut fleet = FleetBuilder::new().policy(FewestRoundsFirst::new());
//! for (i, method) in [Method::Titan, Method::Rs].into_iter().enumerate() {
//!     let mut cfg = presets::table1("mlp", method);
//!     cfg.pipeline = false;
//!     cfg.seed += i as u64;
//!     fleet = fleet.session(format!("dev{i}"), SessionBuilder::new(cfg).build()?);
//! }
//! let record = fleet.run()?;
//! println!("{} rounds interleaved", record.rounds_executed);
//! # Ok::<(), titan::Error>(())
//! ```

use std::path::PathBuf;

use crate::coordinator::session::{observers::Checkpoint, Session, SessionBuilder, StepEvent};
use crate::coordinator::snapshot::{load_checkpoint, Loaded};
use crate::coordinator::RoundOutcome;
use crate::metrics::RunRecord;
use crate::util::json::Json;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};

/// Per-task scheduling bookkeeping the policies decide on. The driver
/// (fleet or FL orchestrator) maintains one per task.
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskState {
    /// Rounds this task has completed.
    pub rounds_done: usize,
    /// Driver tick at which this task last ran (0 = never). Staleness is
    /// the *difference* `now − last_run`, so ordering "stalest first" is
    /// ordering "smallest last_run first" — which is what lets the driver
    /// update one entry per tick instead of aging all N.
    pub last_run: u64,
}

/// A scheduling policy over ready tasks.
///
/// `ready` is non-empty, **sorted ascending**, and holds indices into
/// `states`; `pick` must return one of them, and must be
/// **deterministic** (no wall clock, no RNG) so fleet runs replay
/// exactly. Policies may keep internal state (e.g. the round-robin
/// cursor).
///
/// The optional lifecycle hooks let a policy maintain O(log N) indexed
/// state instead of scanning `ready` on every pick: the driver calls
/// [`SchedPolicy::prepare`] whenever the ready set is (re)initialized
/// and [`SchedPolicy::task_ran`] after a picked task finished a unit of
/// work *and remains ready* (its `states` entry already updated). A task
/// that leaves the ready set simply gets no `task_ran` — a picked entry
/// is consumed. Policies that ignore the hooks (the default no-ops) must
/// answer `pick` from `states`/`ready` alone, and the built-in
/// heap-backed policies fall back to exactly that scan when the driver
/// never prepared them.
pub trait SchedPolicy {
    /// Pick the next task to run among `ready`.
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize;

    /// The ready set was (re)initialized (fleet start, FL comm round).
    fn prepare(&mut self, _states: &[TaskState], _ready: &[usize]) {}

    /// `task` was picked, ran one unit, and is ready again; its
    /// `states[task]` is current.
    fn task_ran(&mut self, _task: usize, _states: &[TaskState]) {}

    /// Display name for records and logs.
    fn name(&self) -> &'static str;
}

/// Cyclic fairness: the smallest ready index strictly after the last
/// pick, wrapping to the smallest ready index.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRobin {
    last: Option<usize>,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { last: None }
    }
}

impl SchedPolicy for RoundRobin {
    fn pick(&mut self, _states: &[TaskState], ready: &[usize]) -> usize {
        let next = self
            .last
            .and_then(|l| ready.iter().copied().filter(|&i| i > l).min())
            .unwrap_or_else(|| ready.iter().copied().min().expect("ready is non-empty"));
        self.last = Some(next);
        next
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Key-ordered policy core shared by [`FewestRoundsFirst`] and
/// [`StalenessPriority`]: a lazy-deletion min-heap over `(key, index)`.
///
/// `task_ran` pushes the task's fresh key without hunting down the old
/// entry; `pick` pops until the top entry's key still matches the task's
/// current key and the task is live — O(log N) amortized (each stale
/// entry is popped exactly once). Without `prepare` the heap is empty
/// and `pick` answers with the original O(|ready|) scan, which doubles
/// as the equivalence oracle (`heap_policies_match_scan_reference`).
#[derive(Clone, Debug, Default)]
struct KeyHeap {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>>,
    /// `queued[i]`: task i has exactly one live entry in the heap.
    queued: Vec<bool>,
    prepared: bool,
}

impl KeyHeap {
    fn prepare(&mut self, n: usize, ready: &[usize], key: impl Fn(usize) -> u64) {
        self.heap.clear();
        self.queued = vec![false; n];
        self.prepared = true;
        for &i in ready {
            self.heap.push(std::cmp::Reverse((key(i), i)));
            self.queued[i] = true;
        }
    }

    fn push(&mut self, task: usize, key: u64) {
        if self.prepared {
            self.heap.push(std::cmp::Reverse((key, task)));
            self.queued[task] = true;
        }
    }

    /// Pop the live minimum, or None when unprepared / drained.
    fn pop_min(&mut self, key: impl Fn(usize) -> u64) -> Option<usize> {
        if !self.prepared {
            return None;
        }
        while let Some(std::cmp::Reverse((k, i))) = self.heap.pop() {
            if self.queued.get(i).copied().unwrap_or(false) && key(i) == k {
                self.queued[i] = false;
                return Some(i);
            }
            // stale: superseded by a later push or consumed — drop it
        }
        None
    }
}

/// Progress fairness: the ready task with the fewest completed rounds
/// (ties: smallest index). Keeps heterogeneous-length sessions aligned.
///
/// Heap-backed through the [`SchedPolicy`] lifecycle hooks — O(log N)
/// per pick on prepared drivers, with the original scan as the
/// unprepared fallback (and the pinned reference).
#[derive(Clone, Debug, Default)]
pub struct FewestRoundsFirst {
    heap: KeyHeap,
}

impl FewestRoundsFirst {
    pub fn new() -> FewestRoundsFirst {
        FewestRoundsFirst::default()
    }
}

impl SchedPolicy for FewestRoundsFirst {
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize {
        self.heap
            .pop_min(|i| states[i].rounds_done as u64)
            .unwrap_or_else(|| {
                ready
                    .iter()
                    .copied()
                    .min_by_key(|&i| (states[i].rounds_done, i))
                    .expect("ready is non-empty")
            })
    }

    fn prepare(&mut self, states: &[TaskState], ready: &[usize]) {
        self.heap.prepare(states.len(), ready, |i| states[i].rounds_done as u64);
    }

    fn task_ran(&mut self, task: usize, states: &[TaskState]) {
        self.heap.push(task, states[task].rounds_done as u64);
    }

    fn name(&self) -> &'static str {
        "fewest-rounds-first"
    }
}

/// Staleness priority: the ready task that has waited longest since it
/// last ran — the smallest [`TaskState::last_run`] (ties: smallest
/// index; a never-run task has `last_run` 0 and outranks everything).
/// Bounds per-session latency when the ready set churns.
///
/// Heap-backed exactly like [`FewestRoundsFirst`]; `last_run` only moves
/// forward, so each pick invalidates at most one heap entry.
#[derive(Clone, Debug, Default)]
pub struct StalenessPriority {
    heap: KeyHeap,
}

impl StalenessPriority {
    pub fn new() -> StalenessPriority {
        StalenessPriority::default()
    }
}

impl SchedPolicy for StalenessPriority {
    fn pick(&mut self, states: &[TaskState], ready: &[usize]) -> usize {
        self.heap.pop_min(|i| states[i].last_run).unwrap_or_else(|| {
            ready
                .iter()
                .copied()
                .min_by_key(|&i| (states[i].last_run, i))
                .expect("ready is non-empty")
        })
    }

    fn prepare(&mut self, states: &[TaskState], ready: &[usize]) {
        self.heap.prepare(states.len(), ready, |i| states[i].last_run);
    }

    fn task_ran(&mut self, task: usize, states: &[TaskState]) {
        self.heap.push(task, states[task].last_run);
    }

    fn name(&self) -> &'static str {
        "priority-by-staleness"
    }
}

/// Pick under `policy` and validate the choice against `ready`.
///
/// The shared dispatch seam for every policy consumer (the session
/// [`Fleet`] and the FL orchestrator): a misbehaving custom policy must
/// fail loudly here instead of hanging a drain loop or indexing out of
/// bounds in release builds, where a `debug_assert!` would vanish.
/// `ready` is sorted ascending (the [`SchedPolicy`] contract), so the
/// membership check is a binary search, not a scan.
pub fn pick_validated(
    policy: &mut dyn SchedPolicy,
    states: &[TaskState],
    ready: &[usize],
) -> Result<usize> {
    debug_assert!(ready.windows(2).all(|w| w[0] < w[1]), "ready must be sorted");
    let idx = policy.pick(states, ready);
    if ready.binary_search(&idx).is_err() {
        return Err(Error::Pipeline(format!(
            "policy {:?} picked non-ready task {idx} (ready: {ready:?})",
            policy.name()
        )));
    }
    Ok(idx)
}

/// Parse a policy by its CLI name.
pub fn parse_policy(name: &str) -> Result<Box<dyn SchedPolicy>> {
    match name {
        "rr" | "round-robin" => Ok(Box::new(RoundRobin::new())),
        "fewest" | "fewest-rounds-first" => Ok(Box::new(FewestRoundsFirst::new())),
        "staleness" | "priority-by-staleness" => Ok(Box::new(StalenessPriority::new())),
        other => Err(Error::Config(format!(
            "unknown scheduling policy {other:?} (rr|fewest|staleness)"
        ))),
    }
}

/// Fleet-level observer: sees every session's rounds in the order the
/// scheduler interleaves them. Per-session
/// [`RoundObserver`](crate::coordinator::session::RoundObserver)s still
/// fire inside each session; this is the cross-session fan-out
/// (dashboards, fleet-wide audits).
pub trait FleetObserver {
    /// One session completed one round.
    fn on_session_round(&mut self, _session: usize, _name: &str, _outcome: &RoundOutcome) {}

    /// One session finished its run.
    fn on_session_finished(&mut self, _session: usize, _name: &str, _record: &RunRecord) {}
}

/// Built-in fleet observer: logs interleaving progress at debug level.
pub struct FleetProgress {
    every: usize,
    steps: usize,
}

impl FleetProgress {
    /// Log every `every` interleaved rounds (0 = finishes only).
    pub fn every(every: usize) -> FleetProgress {
        FleetProgress { every, steps: 0 }
    }
}

impl FleetObserver for FleetProgress {
    fn on_session_round(&mut self, session: usize, name: &str, outcome: &RoundOutcome) {
        self.steps += 1;
        if self.every > 0 && self.steps % self.every == 0 {
            log::debug!(
                "fleet step {:>6}: session {session} ({name}) round {} loss {:.4}",
                self.steps,
                outcome.round + 1,
                outcome.train_loss
            );
        }
    }

    fn on_session_finished(&mut self, session: usize, name: &str, record: &RunRecord) {
        log::debug!(
            "fleet: session {session} ({name}) finished, final acc {:.2}%",
            record.final_accuracy * 100.0
        );
    }
}

/// Builder for a [`Fleet`]: named sessions + policy + fleet observers.
pub struct FleetBuilder {
    names: Vec<String>,
    sessions: Vec<Box<Session>>,
    policy: Box<dyn SchedPolicy>,
    observers: Vec<Box<dyn FleetObserver>>,
}

impl FleetBuilder {
    pub fn new() -> FleetBuilder {
        FleetBuilder {
            names: Vec::new(),
            sessions: Vec::new(),
            policy: Box::new(RoundRobin::new()),
            observers: Vec::new(),
        }
    }

    /// Add a session under a display name; repeatable. Sessions start
    /// lazily, so assembling a large fleet is cheap.
    pub fn session(mut self, name: impl Into<String>, session: Session) -> Self {
        self.names.push(name.into());
        self.sessions.push(Box::new(session));
        self
    }

    /// Add a session that checkpoints to `path` every `every` rounds,
    /// and — when `resume` is set — restarts from the snapshot already
    /// at `path`, so a killed `titan fleet` run picks each member back
    /// up **at its own saved round**:
    ///
    /// - no file at `path` (or `resume` unset): the member starts fresh;
    /// - a mid-run snapshot: the member resumes from it (the snapshot's
    ///   config fingerprint must match `builder`'s config — mismatches
    ///   error instead of silently diverging);
    /// - a completion marker **for the same config**: the member already
    ///   finished, so it is **skipped** (logged at info level), and the
    ///   resumed fleet runs only the unfinished members. A completion
    ///   marker whose recorded config does not match `builder`'s errors
    ///   like a mismatched mid-run snapshot would — skipping it would
    ///   silently drop a run the user actually asked for.
    pub fn session_checkpointed(
        mut self,
        name: impl Into<String>,
        builder: SessionBuilder,
        path: impl Into<PathBuf>,
        every: usize,
        resume: bool,
    ) -> Result<Self> {
        let name = name.into();
        let path = path.into();
        let mut builder = builder;
        if resume && path.exists() {
            match load_checkpoint(&path)? {
                Loaded::Resumable(snap) => {
                    log::info!(
                        "fleet: resuming {name:?} from {} at round {}",
                        path.display(),
                        snap.round
                    );
                    builder = builder.resume_from_snapshot(*snap);
                }
                Loaded::Complete { round, config, .. } => {
                    // Json::Null means the run finished before its first
                    // cadence snapshot — no config to verify against
                    if config != Json::Null
                        && config.to_string_compact() != builder.cfg().fingerprint()
                    {
                        return Err(Error::Config(format!(
                            "{}: completion marker belongs to a differently configured \
                             run — refusing to skip {name:?} (delete the file to start over)",
                            path.display()
                        )));
                    }
                    log::info!(
                        "fleet: {name:?} already finished ({round} rounds per {}), skipping",
                        path.display()
                    );
                    return Ok(self);
                }
            }
        }
        let session = builder.observe(Checkpoint::every(path, every)).build()?;
        self.names.push(name);
        self.sessions.push(Box::new(session));
        Ok(self)
    }

    /// Sessions added so far (resume may skip completed members — see
    /// [`FleetBuilder::session_checkpointed`] — so a caller can detect an
    /// everything-already-finished resume before `build` errors on an
    /// empty fleet).
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Replace the default round-robin policy.
    pub fn policy(mut self, policy: impl SchedPolicy + 'static) -> Self {
        self.policy = Box::new(policy);
        self
    }

    /// Replace the policy with an already-boxed one (CLI parsing).
    pub fn policy_boxed(mut self, policy: Box<dyn SchedPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// Attach a fleet observer; repeatable, invoked in attach order.
    pub fn observe(mut self, observer: impl FleetObserver + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Assemble the fleet. Errors on an empty session list.
    pub fn build(self) -> Result<Fleet> {
        if self.sessions.is_empty() {
            return Err(Error::Config("fleet needs at least one session".into()));
        }
        Ok(Fleet {
            names: self.names,
            sessions: self.sessions,
            policy: self.policy,
            observers: self.observers,
        })
    }

    /// Build and run in one step.
    pub fn run(self) -> Result<FleetRecord> {
        self.build()?.run()
    }
}

impl Default for FleetBuilder {
    fn default() -> Self {
        FleetBuilder::new()
    }
}

/// N boxed sessions interleaved round-by-round under one [`SchedPolicy`].
pub struct Fleet {
    names: Vec<String>,
    sessions: Vec<Box<Session>>,
    policy: Box<dyn SchedPolicy>,
    observers: Vec<Box<dyn FleetObserver>>,
}

impl Fleet {
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Drive every session to completion, one round per scheduler tick.
    ///
    /// A session error aborts the whole fleet (the scheduler is a
    /// single-tenant research runtime, not an isolator); the error names
    /// the session that failed.
    pub fn run(mut self) -> Result<FleetRecord> {
        let n = self.sessions.len();
        let fleet_sw = Stopwatch::start();
        let mut states = vec![TaskState::default(); n];
        let mut records: Vec<Option<RunRecord>> = (0..n).map(|_| None).collect();
        let mut ready: Vec<usize> = (0..n).collect();
        let mut rounds_executed = 0usize;
        let mut device_ops = 0u64;
        let mut step_ms = 0.0f64;
        // scheduler clock for staleness: one O(1) last_run write per tick
        // replaces the old all-tasks aging pass (O(N) per round)
        let mut tick = 0u64;
        self.policy.prepare(&states, &ready);

        while !ready.is_empty() {
            let idx = pick_validated(self.policy.as_mut(), &states, &ready)?;
            let step_sw = Stopwatch::start();
            let event = self.sessions[idx]
                .step()
                .map_err(|e| Error::Pipeline(format!("fleet session {:?}: {e}", self.names[idx])))?;
            step_ms += step_sw.elapsed_ms();
            match event {
                StepEvent::RoundCompleted(outcome) => {
                    states[idx].rounds_done += 1;
                    tick += 1;
                    states[idx].last_run = tick;
                    self.policy.task_ran(idx, &states);
                    rounds_executed += 1;
                    // +1: the round's TrainStep on the CPU lane (selector
                    // ops are the GPU-lane charge)
                    device_ops += outcome.selector.ops.len() as u64 + 1;
                    for obs in self.observers.iter_mut() {
                        obs.on_session_round(idx, &self.names[idx], &outcome);
                    }
                    // drain the outcome the session retained: the fleet
                    // surface for per-round data is the observer fan-out,
                    // and keeping N x R outcomes alive across in-flight
                    // sessions would grow with fleet size
                    self.sessions[idx].take_outcomes();
                }
                StepEvent::Finished(record) => {
                    for obs in self.observers.iter_mut() {
                        obs.on_session_finished(idx, &self.names[idx], &record);
                    }
                    records[idx] = Some(record);
                    ready.retain(|&i| i != idx);
                }
            }
        }

        let records: Vec<RunRecord> = records
            .into_iter()
            .map(|r| r.expect("every session yielded Finished"))
            .collect();
        let total_host_ms = fleet_sw.elapsed_ms();
        Ok(FleetRecord {
            policy: self.policy.name().to_string(),
            names: self.names,
            session_rounds: states.iter().map(|s| s.rounds_done).collect(),
            rounds_executed,
            device_ops,
            total_device_ms: records.iter().map(|r| r.total_device_ms).sum(),
            energy_j: records.iter().map(|r| r.energy_j).sum(),
            peak_memory_bytes: records.iter().map(|r| r.peak_memory_bytes).sum(),
            records,
            total_host_ms,
            sched_overhead_ms: (total_host_ms - step_ms).max(0.0),
        })
    }
}

/// Aggregate record of one fleet run: per-session [`RunRecord`]s plus the
/// shared host accounting.
#[derive(Clone, Debug)]
pub struct FleetRecord {
    /// Policy display name.
    pub policy: String,
    /// Session display names, index-aligned with `records`.
    pub names: Vec<String>,
    /// Final per-session records — identical to solo runs for every
    /// session that is reproducible solo (see the module docs).
    pub records: Vec<RunRecord>,
    /// Rounds each session completed.
    pub session_rounds: Vec<usize>,
    /// Total interleaved rounds across all sessions.
    pub rounds_executed: usize,
    /// Device-sim ops charged across all sessions (selector ops + one
    /// train step per round).
    pub device_ops: u64,
    /// Σ per-session simulated device clocks (ms).
    pub total_device_ms: f64,
    /// Host wall clock of the whole fleet run (ms).
    pub total_host_ms: f64,
    /// Host wall time outside `Session::step` — scheduling, bookkeeping
    /// and fleet-observer fan-out (ms).
    pub sched_overhead_ms: f64,
    /// Σ per-session simulated energy (J).
    pub energy_j: f64,
    /// Σ per-session peak-memory estimates (bytes) — every session's
    /// working set is resident concurrently on the host.
    pub peak_memory_bytes: usize,
}

impl FleetRecord {
    /// Scheduler overhead amortized per interleaved round (ms).
    pub fn sched_overhead_per_round_ms(&self) -> f64 {
        if self.rounds_executed == 0 {
            0.0
        } else {
            self.sched_overhead_ms / self.rounds_executed as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let sessions = Json::Arr(
            self.names
                .iter()
                .zip(&self.records)
                .zip(&self.session_rounds)
                .map(|((name, record), &rounds)| {
                    Json::obj(vec![
                        ("name", Json::Str(name.clone())),
                        ("rounds", Json::Num(rounds as f64)),
                        ("record", record.to_json()),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("sessions", sessions),
            ("rounds_executed", Json::Num(self.rounds_executed as f64)),
            ("device_ops", Json::Num(self.device_ops as f64)),
            ("total_device_ms", Json::Num(self.total_device_ms)),
            ("total_host_ms", Json::Num(self.total_host_ms)),
            ("sched_overhead_ms", Json::Num(self.sched_overhead_ms)),
            (
                "sched_overhead_per_round_ms",
                Json::Num(self.sched_overhead_per_round_ms()),
            ),
            ("energy_j", Json::Num(self.energy_j)),
            ("peak_memory_bytes", Json::Num(self.peak_memory_bytes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(rounds: &[usize], last_run: &[u64]) -> Vec<TaskState> {
        rounds
            .iter()
            .zip(last_run)
            .map(|(&rounds_done, &last_run)| TaskState { rounds_done, last_run })
            .collect()
    }

    #[test]
    fn round_robin_cycles_and_skips_finished() {
        let mut p = RoundRobin::new();
        let s = states(&[0, 0, 0], &[0, 0, 0]);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 0);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 1);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 2);
        assert_eq!(p.pick(&s, &[0, 1, 2]), 0); // wraps
        // session 1 finished: the cycle skips it
        assert_eq!(p.pick(&s, &[0, 2]), 2);
        assert_eq!(p.pick(&s, &[0, 2]), 0);
    }

    #[test]
    fn fewest_rounds_prefers_laggards_then_index() {
        // unprepared policy: the scan fallback answers
        let mut p = FewestRoundsFirst::new();
        let s = states(&[3, 1, 1, 5], &[0, 0, 0, 0]);
        assert_eq!(p.pick(&s, &[0, 1, 2, 3]), 1); // min rounds, tie -> min index
        assert_eq!(p.pick(&s, &[0, 2, 3]), 2);
        assert_eq!(p.pick(&s, &[0, 3]), 0);
    }

    #[test]
    fn staleness_prefers_longest_waiting_then_index() {
        // staleness = ticks since last_run, so stalest = smallest last_run
        let mut p = StalenessPriority::new();
        let s = states(&[0, 0, 0, 0], &[5, 1, 1, 6]);
        assert_eq!(p.pick(&s, &[0, 1, 2, 3]), 1); // max staleness, tie -> min index
        assert_eq!(p.pick(&s, &[0, 2, 3]), 2);
        assert_eq!(p.pick(&s, &[0, 3]), 0);
    }

    /// THE policy-order equivalence pin (N ≤ 100): the heap-backed path
    /// (driven through prepare/task_ran) must reproduce the scan
    /// fallback's pick sequence exactly, through runs, finishes and
    /// re-preparations, for both keyed policies.
    #[test]
    fn heap_policies_match_scan_reference() {
        for n in [1usize, 2, 3, 17, 100] {
            for seed in 0..5u64 {
                check_heap_vs_scan(&mut FewestRoundsFirst::new(), n, seed);
                check_heap_vs_scan(&mut StalenessPriority::new(), n, seed);
            }
        }
    }

    fn check_heap_vs_scan(heap: &mut dyn SchedPolicy, n: usize, seed: u64) {
        // scan twin: same type, never prepared -> always the scan path.
        // Both twins see the same states; only the heap one gets hooks.
        let mut scan = match heap.name() {
            "fewest-rounds-first" => {
                Box::new(FewestRoundsFirst::new()) as Box<dyn SchedPolicy>
            }
            _ => Box::new(StalenessPriority::new()),
        };
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed ^ n as u64);
        let budgets: Vec<usize> = (0..n).map(|_| 1 + rng.index(6)).collect();
        let mut states = vec![TaskState::default(); n];
        let mut ready: Vec<usize> = (0..n).collect();
        let mut tick = 0u64;
        heap.prepare(&states, &ready);
        while !ready.is_empty() {
            let a = pick_validated(heap, &states, &ready).unwrap();
            let b = pick_validated(scan.as_mut(), &states, &ready).unwrap();
            assert_eq!(a, b, "{} n={n} seed={seed} tick={tick}", heap.name());
            states[a].rounds_done += 1;
            tick += 1;
            states[a].last_run = tick;
            if states[a].rounds_done >= budgets[a] {
                ready.retain(|&i| i != a); // finished: no task_ran
            } else {
                heap.task_ran(a, &states);
            }
        }
    }

    #[test]
    fn pick_validated_rejects_misbehaving_policy() {
        struct Bad;
        impl SchedPolicy for Bad {
            fn pick(&mut self, _states: &[TaskState], _ready: &[usize]) -> usize {
                999 // out of range AND not ready
            }
            fn name(&self) -> &'static str {
                "bad"
            }
        }
        let s = states(&[0, 0], &[0, 0]);
        assert!(pick_validated(&mut Bad, &s, &[0, 1]).is_err());
        assert_eq!(pick_validated(&mut RoundRobin::new(), &s, &[1]).unwrap(), 1);
    }

    #[test]
    fn policy_parsing() {
        for (name, want) in [
            ("rr", "round-robin"),
            ("round-robin", "round-robin"),
            ("fewest", "fewest-rounds-first"),
            ("staleness", "priority-by-staleness"),
        ] {
            assert_eq!(parse_policy(name).unwrap().name(), want);
        }
        assert!(parse_policy("bogus").is_err());
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(FleetBuilder::new().build().is_err());
    }

    #[test]
    fn fleet_record_json_shape() {
        let rec = FleetRecord {
            policy: "round-robin".into(),
            names: vec!["a".into(), "b".into()],
            records: vec![RunRecord::new("rs", "mlp"), RunRecord::new("titan", "mlp")],
            session_rounds: vec![4, 6],
            rounds_executed: 10,
            device_ops: 25,
            total_device_ms: 1234.5,
            total_host_ms: 80.0,
            sched_overhead_ms: 2.0,
            energy_j: 9.0,
            peak_memory_bytes: 2048,
        };
        assert!((rec.sched_overhead_per_round_ms() - 0.2).abs() < 1e-12);
        let j = rec.to_json();
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(), "round-robin");
        assert_eq!(j.get("sessions").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("rounds_executed").unwrap().as_usize().unwrap(), 10);
        let roundtrip = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            roundtrip.get("sched_overhead_per_round_ms").unwrap().as_f64().unwrap(),
            0.2
        );
    }

    // ---- artifact-gated fleet runs ------------------------------------

    use crate::config::{presets, Method};
    use crate::coordinator::SessionBuilder;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny_session(method: Method, rounds: usize, seed_off: u64) -> Session {
        let mut cfg = presets::table1("mlp", method);
        cfg.rounds = rounds;
        cfg.test_size = 200;
        cfg.eval_every = 2;
        cfg.pipeline = false;
        cfg.seed += seed_off;
        SessionBuilder::new(cfg).build().unwrap()
    }

    /// A fleet observer that records the interleaving for assertions.
    struct Trace(std::rc::Rc<std::cell::RefCell<Vec<(usize, usize)>>>);

    impl FleetObserver for Trace {
        fn on_session_round(&mut self, session: usize, _name: &str, outcome: &RoundOutcome) {
            self.0.borrow_mut().push((session, outcome.round));
        }
    }

    #[test]
    fn round_robin_interleaves_heterogeneous_sessions() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let trace = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let record = FleetBuilder::new()
            .session("short", tiny_session(Method::Rs, 2, 0))
            .session("long", tiny_session(Method::Rs, 4, 1))
            .observe(Trace(std::rc::Rc::clone(&trace)))
            .run()
            .unwrap();
        assert_eq!(record.session_rounds, vec![2, 4]);
        assert_eq!(record.rounds_executed, 6);
        assert_eq!(record.records.len(), 2);
        // strict alternation while both live, then the long tail
        let seen = trace.borrow().clone();
        assert_eq!(
            seen,
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (1, 2), (1, 3)],
            "unexpected interleaving: {seen:?}"
        );
        assert!(record.total_device_ms > 0.0);
        assert!(record.peak_memory_bytes > 0);
    }
}
