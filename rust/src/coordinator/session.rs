//! The unified session API — one canonical round loop for every
//! deployment shape.
//!
//! A [`Session`] owns the full round-accounting core that `sequential.rs`
//! and `pipeline.rs` used to duplicate: device-sim op recording,
//! [`RunRecord`] bookkeeping, the eval cadence, peak-memory estimation and
//! the per-round parameter sync. Execution strategy is delegated to an
//! [`ExecBackend`]:
//!
//! - [`ExecBackend::Sequential`] — selection and training alternate on
//!   one thread (the paper's baseline deployment, Fig. 6(a) ablation).
//! - [`ExecBackend::Pipelined`] — the §3.4 design: the selector runs on
//!   its own OS thread, batches cross a bounded `sync_channel(1)` in
//!   round order, and parameters flow back through a latest-only slot
//!   ([`crate::util::sync::Latest`]) as `Arc` snapshots. The one-round
//!   delay falls out of the channel topology: while the trainer updates
//!   `w_t` with batch `B_t` (chosen under `w_{t-1}`), the selector is
//!   already choosing `B_{t+1}` under the freshest params it has seen.
//!
//! Both backends drive the *same* loop body, so on the same
//! `RunConfig` + seed they produce identical selection/training streams
//! whenever selection is parameter-independent (e.g. `Method::Rs`), and
//! differ only by the documented one-round parameter delay otherwise. The
//! device clock still differs by construction (lanes overlap when
//! pipelined, plus the per-round `Op::Sync`); `RunRecord.curve`'s
//! loss/accuracy fields are the byte-identical part.
//!
//! Two extension seams keep the loop closed while letting deployments
//! compose around it:
//!
//! - [`DataSource`] (data plane) — where arrivals come from. Defaults to
//!   the synthetic [`StreamSource`]; replay buffers, non-IID federated
//!   device streams and drifting class mixes plug in without touching the
//!   loop.
//! - [`RoundObserver`] — per-round / per-eval hooks that can log
//!   progress, audit budgets, stop the run early by returning
//!   [`Control::Stop`], or persist full session snapshots
//!   ([`RoundObserver::on_snapshot`], consumed by the [`observers::Checkpoint`]
//!   observer) so a killed run resumes via [`SessionBuilder::resume_from`]
//!   instead of re-spending device time from round 0.
//!
//! Execution is **step-driven**: a [`Session`] is a state machine whose
//! [`Session::step`] runs exactly one round and yields a [`StepEvent`]
//! ([`StepEvent::RoundCompleted`] per round, then one
//! [`StepEvent::Finished`] carrying the final [`RunRecord`]).
//! [`Session::run`] is a trivial while-step wrapper, so one-shot callers
//! see byte-identical records — and a host scheduler
//! ([`crate::coordinator::host`]) can interleave many sessions
//! round-by-round on one thread without changing any session's output.
//!
//! Below the round sits the **op level**: [`Session::step_op`] advances
//! exactly one sub-round op (feed → select → train → sync → record, see
//! [`RoundOp`]) and yields [`StepEvent::OpCompleted`] until the record op
//! closes the round. The sharded fleet host interleaves sessions at this
//! granularity so one slow op stalls only its own session; `step` is a
//! loop over `step_op`, so both drive the identical state machine and
//! produce byte-identical records.
//!
//! ```no_run
//! use titan::config::{presets, Method};
//! use titan::coordinator::session::{observers, SessionBuilder};
//! use titan::device::idle::IdleTrace;
//!
//! let cfg = presets::table1("mlp", Method::Titan);
//! let (record, outcomes) = SessionBuilder::new(cfg)
//!     .pipelined(IdleTrace::Sine { min: 0.2, max: 1.0, period: 50.0 })
//!     .observe(observers::ProgressLog::every(10))
//!     .run()?;
//! # Ok::<(), titan::Error>(())
//! ```

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::config::RunConfig;
use crate::coordinator::snapshot::{load_vault_checkpoint, Loaded, SessionSnapshot};
use crate::coordinator::vault::{CheckpointVault, RecoveryTelemetry};
use crate::coordinator::{
    RoundOp, RoundOutcome, SelectorEngine, SelectorReport, SelectorState, TrainBatch,
    TrainerEngine,
};
use crate::data::{DataSource, RetainedSource, Sample, StreamSource, SynthTask};
use crate::device::idle::IdleTrace;
use crate::device::{memory, DeviceSim, Lane, Op, RoundTiming};
use crate::metrics::{CurvePoint, RunRecord};
use crate::retention::RetentionTelemetry;
use crate::util::sync::Latest;
use crate::util::timer::{LatencyRecorder, Stopwatch};
use crate::{Error, Result};

/// How a session executes the round loop.
#[derive(Clone, Debug)]
pub enum ExecBackend {
    /// Selection and training alternate on one thread.
    Sequential,
    /// Selector and trainer on two OS threads with one-round-delay batch
    /// handoff; `idle` governs the per-round candidate budget (Fig. 9).
    Pipelined { idle: IdleTrace },
}

impl ExecBackend {
    /// The default pipelined backend (constant full idle capacity).
    pub fn pipelined_default() -> ExecBackend {
        ExecBackend::Pipelined { idle: IdleTrace::Constant(1.0) }
    }

    /// Backend a config asks for (`cfg.pipeline` flag).
    pub fn for_config(cfg: &RunConfig) -> ExecBackend {
        if cfg.pipeline {
            ExecBackend::pipelined_default()
        } else {
            ExecBackend::Sequential
        }
    }

    pub fn is_pipelined(&self) -> bool {
        matches!(self, ExecBackend::Pipelined { .. })
    }

    /// Backend kind for checkpoint fingerprints (`"sequential"` /
    /// `"pipelined"`). Idle traces are configuration the resuming caller
    /// re-supplies; the kind is what a snapshot must not silently cross.
    pub fn kind(&self) -> &'static str {
        if self.is_pipelined() {
            "pipelined"
        } else {
            "sequential"
        }
    }
}

/// Loop control returned by observer hooks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Control {
    /// Keep running.
    #[default]
    Continue,
    /// Finish the current round's bookkeeping, then end the run (final
    /// eval and totals still happen).
    Stop,
}

/// Per-round / per-eval hooks into the session loop.
///
/// Observers run on the trainer thread after the round's accounting is
/// done, so they see exactly what the run record sees and cannot perturb
/// selection. Returning [`Control::Stop`] from either hook ends the run
/// after the current round.
///
/// Observers are `Send` because an un-started [`SessionBuilder`] (which
/// carries them) may be handed to a sharded fleet-host worker thread;
/// shared-handle observers use `Arc<Mutex<..>>`/atomics rather than
/// `Rc`/`RefCell`.
pub trait RoundObserver: Send {
    /// Called once per completed round.
    fn on_round(&mut self, _outcome: &RoundOutcome) -> Control {
        Control::Continue
    }

    /// Called at every eval-cadence checkpoint with the new curve point.
    fn on_eval(&mut self, _point: &CurvePoint) -> Control {
        Control::Continue
    }

    /// Called once per completed round when the run's data source retains
    /// samples (`--store-bytes > 0`), after [`RoundObserver::on_round`],
    /// with the **cumulative** retention telemetry as of this round. Runs
    /// without a retention plane never invoke this hook.
    fn on_retention(&mut self, _round: usize, _telemetry: &RetentionTelemetry) -> Control {
        Control::Continue
    }

    /// Whether this observer ever consumes full session snapshots
    /// ([`RoundObserver::on_snapshot`]). The session only pays the
    /// per-round selector-state capture on the pipelined backend (the
    /// selector thread attaches its state to each batch, since the
    /// trainer thread cannot reach across at checkpoint time) when some
    /// attached observer returns true. [`RoundObserver::snapshot_due`] is
    /// only consulted when this returns true.
    fn wants_snapshots(&self) -> bool {
        false
    }

    /// Whether a snapshot is due after `rounds_done` completed rounds
    /// (asked after the round's `on_round`/`on_eval` hooks, so the
    /// snapshot the observer then receives already includes that round's
    /// eval point).
    fn snapshot_due(&self, _rounds_done: usize) -> bool {
        false
    }

    /// Receive the full session snapshot requested via
    /// [`RoundObserver::snapshot_due`]. Building a snapshot costs one
    /// parameter-vector clone plus the filter-state copy, so it happens
    /// at most once per round, shared by every observer that asked.
    fn on_snapshot(&mut self, _snapshot: &SessionSnapshot) {}

    /// Called exactly once when the run finishes, with the final record
    /// (after teardown, final eval and totals). This is where persisting
    /// observers flush their tail — rounds after the last cadence
    /// multiple would otherwise be lost on disk.
    fn on_finish(&mut self, _record: &RunRecord) {}
}

/// Built-in observers: progress logging, early stopping, budget audits,
/// JSON checkpointing.
pub mod observers {
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Arc, Mutex};

    use super::{Control, RoundObserver, SessionSnapshot};
    use crate::coordinator::snapshot::{completion_marker, load_vault_checkpoint, Loaded};
    use crate::coordinator::vault::CheckpointVault;
    use crate::coordinator::RoundOutcome;
    use crate::metrics::{CurvePoint, RunRecord};
    use crate::util::json::Json;

    /// Logs round loss and eval checkpoints at debug level via the `log`
    /// facade, without touching stdout — experiment tables stay clean.
    pub struct ProgressLog {
        every: usize,
    }

    impl ProgressLog {
        /// Log every `every` rounds (0 = only eval checkpoints).
        pub fn every(every: usize) -> ProgressLog {
            ProgressLog { every }
        }
    }

    impl RoundObserver for ProgressLog {
        fn on_round(&mut self, o: &RoundOutcome) -> Control {
            if self.every > 0 && (o.round + 1) % self.every == 0 {
                // 1-based round, matching on_eval and RunRecord.curve.
                // Selector/device fields only when a selector actually ran
                // this round (FL synthesizes outcomes with train_loss only).
                if o.selector.arrivals > 0 {
                    log::debug!(
                        "round {:>5}: loss {:.4} candidates {} wall {:.0}ms",
                        o.round + 1,
                        o.train_loss,
                        o.selector.candidates,
                        o.device_wall_ms
                    );
                } else {
                    log::debug!("round {:>5}: loss {:.4}", o.round + 1, o.train_loss);
                }
            }
            Control::Continue
        }

        fn on_eval(&mut self, p: &CurvePoint) -> Control {
            log::debug!(
                "eval @ round {:>5}: test_loss {:.4} acc {:.2}%",
                p.round,
                p.test_loss,
                p.test_accuracy * 100.0
            );
            Control::Continue
        }
    }

    /// Stops the run at the first eval checkpoint reaching the target
    /// accuracy — time-to-accuracy runs without paying for the plateau.
    pub struct EarlyStop {
        target_accuracy: f64,
    }

    impl EarlyStop {
        pub fn at_accuracy(target_accuracy: f64) -> EarlyStop {
            EarlyStop { target_accuracy }
        }
    }

    impl RoundObserver for EarlyStop {
        fn on_eval(&mut self, p: &CurvePoint) -> Control {
            if p.test_accuracy >= self.target_accuracy {
                Control::Stop
            } else {
                Control::Continue
            }
        }
    }

    /// Records each round's realized candidate-set size (the Fig. 9
    /// budget audit). The shared handle outlives the session, which takes
    /// the observer by value.
    pub struct CandidateAudit {
        log: Arc<Mutex<Vec<usize>>>,
    }

    impl CandidateAudit {
        pub fn new() -> (CandidateAudit, Arc<Mutex<Vec<usize>>>) {
            let log = Arc::new(Mutex::new(Vec::new()));
            (CandidateAudit { log: Arc::clone(&log) }, log)
        }
    }

    impl RoundObserver for CandidateAudit {
        fn on_round(&mut self, o: &RoundOutcome) -> Control {
            self.log
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(o.selector.candidates);
            Control::Continue
        }
    }

    /// Persists a **full session snapshot**
    /// ([`crate::coordinator::snapshot::SessionSnapshot`]) through a
    /// [`CheckpointVault`] every `k` completed rounds, and a small
    /// completion marker when the run finishes — so a killed run resumes
    /// from its last snapshot via
    /// [`super::SessionBuilder::resume_from`] and a finished run's tail
    /// (eval points after the last cadence multiple) is never lost.
    ///
    /// With the default `keep = 1` the vault writes the payload verbatim
    /// to `path` (unique temp file + rename, bit-identical to the
    /// historical single-file discipline); [`Checkpoint::keep`] retains
    /// checksummed generation files instead, so a torn or bit-flipped
    /// newest write falls back to an older valid generation on resume.
    /// Writes are atomic either way: an interruption mid-write never
    /// destroys the previous valid artifact, and two observers
    /// checkpointing into the same directory can never rename each
    /// other's half-written files into place. Write failures are logged
    /// at warn level and never abort the run.
    pub struct Checkpoint {
        vault: CheckpointVault,
        every: usize,
        /// Config of the observed run, cached off the snapshots so the
        /// completion marker can carry it (Null if the run finished
        /// before the first cadence snapshot).
        config: Json,
        /// Failed writes so far — shared so callers keep visibility after
        /// the session has taken the observer by value (see
        /// [`Checkpoint::failure_counter`]).
        failures: Arc<AtomicU64>,
    }

    /// Summary of a checkpoint file (mid-run snapshot or completion
    /// marker) — the cheap read API; resume goes through
    /// [`super::SessionBuilder::resume_from`] instead.
    #[derive(Clone, Debug, PartialEq)]
    pub struct CheckpointState {
        /// Completed rounds at write time (1-based counter).
        pub round: usize,
        /// `(round, test_accuracy)` eval checkpoints written so far.
        pub accuracy_trace: Vec<(usize, f64)>,
        /// Whether the run finished (nothing left to resume).
        pub complete: bool,
    }

    impl Checkpoint {
        /// Snapshot to `path` every `every` completed rounds (> 0),
        /// keeping a single generation (the historical single-file
        /// discipline; see [`Checkpoint::keep`] for more).
        ///
        /// Vault construction also sweeps temp files a previous
        /// incarnation left behind: a kill between write and rename
        /// orphans a uniquely named `.tmp` sibling, and since every
        /// write generates a fresh name, nothing would ever reclaim
        /// them across crash/resume cycles. Observers are constructed
        /// before any writes happen, so the sweep cannot race a live
        /// writer in normal use; at worst a removed in-flight temp
        /// costs one logged, retried-next-cadence write.
        pub fn every(path: impl Into<PathBuf>, every: usize) -> Checkpoint {
            assert!(every > 0, "checkpoint cadence must be positive");
            Checkpoint {
                vault: CheckpointVault::new(path, 1),
                every,
                config: Json::Null,
                failures: Arc::new(AtomicU64::new(0)),
            }
        }

        /// Retain the newest `keep` (≥ 1) checksummed generations
        /// instead of one bare file — a torn or bit-flipped newest
        /// write then falls back to an older valid generation on
        /// resume (`--keep-checkpoints` on the CLI).
        pub fn keep(mut self, keep: usize) -> Checkpoint {
            self.vault = CheckpointVault::new(self.vault.path().to_path_buf(), keep);
            self
        }

        /// The vault this observer writes through.
        pub fn vault(&self) -> &CheckpointVault {
            &self.vault
        }

        /// Write failures so far (each is also logged at warn level; the
        /// run itself never aborts on one).
        pub fn failures(&self) -> u64 {
            self.failures.load(Ordering::Relaxed)
        }

        /// Shared handle to the failure counter — grab one before
        /// `observe` takes the observer by value to audit write failures
        /// after the run.
        pub fn failure_counter(&self) -> Arc<AtomicU64> {
            Arc::clone(&self.failures)
        }

        /// Vault write: atomic, checksummed when `keep > 1`. Failures
        /// are counted and logged, never propagated — losing a snapshot
        /// must not kill the run it is protecting.
        fn write(&self, round: usize, j: &Json) {
            let fingerprint = self.config.to_string_compact();
            if let Err(e) = self.vault.write(round, &fingerprint, &j.to_string_compact()) {
                self.failures.fetch_add(1, Ordering::Relaxed);
                log::warn!("checkpoint write {} failed: {e}", self.vault.path().display());
            }
        }

        /// Summarize the latest valid checkpoint of the vault rooted at
        /// `path` (framed generations first, the legacy unframed file as
        /// the final fallback).
        pub fn load(path: &Path) -> crate::Result<CheckpointState> {
            let vault = CheckpointVault::new(path, 1);
            let (loaded, _telemetry) = load_vault_checkpoint(&vault)?;
            Ok(match loaded {
                Loaded::Resumable(snap) => CheckpointState {
                    round: snap.round,
                    accuracy_trace: snap
                        .curve
                        .iter()
                        .map(|p| (p.round, p.test_accuracy))
                        .collect(),
                    complete: false,
                },
                Loaded::Complete { round, accuracy_trace, .. } => {
                    CheckpointState { round, accuracy_trace, complete: true }
                }
            })
        }
    }

    impl RoundObserver for Checkpoint {
        fn wants_snapshots(&self) -> bool {
            true
        }

        fn snapshot_due(&self, rounds_done: usize) -> bool {
            rounds_done > 0 && rounds_done % self.every == 0
        }

        fn on_snapshot(&mut self, snapshot: &SessionSnapshot) {
            self.config = snapshot.config.clone();
            self.write(snapshot.round, &snapshot.to_json());
        }

        fn on_finish(&mut self, record: &RunRecord) {
            self.write(record.round_device_ms.len(), &completion_marker(&self.config, record));
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn checkpoint_keep_defaults_to_one_generation() {
            let path = std::env::temp_dir().join("titan_checkpoint_shared.json");
            let a = Checkpoint::every(path.clone(), 2);
            assert_eq!(a.vault.keep(), 1);
            assert_eq!(a.vault.path(), path.as_path());
            let b = Checkpoint::every(path.clone(), 2).keep(3);
            assert_eq!(b.vault.keep(), 3);
        }
    }
}

/// Builder for a [`Session`]. Configure, then [`SessionBuilder::run`].
pub struct SessionBuilder {
    cfg: RunConfig,
    backend: Option<ExecBackend>,
    source: Option<Box<dyn DataSource>>,
    observers: Vec<Box<dyn RoundObserver>>,
    resume: Option<Box<SessionSnapshot>>,
}

impl SessionBuilder {
    pub fn new(cfg: RunConfig) -> SessionBuilder {
        SessionBuilder {
            cfg,
            backend: None,
            source: None,
            observers: Vec::new(),
            resume: None,
        }
    }

    /// The config this builder will run (resume paths compare it against
    /// a checkpoint's fingerprint before deciding what to do with the
    /// file).
    pub fn cfg(&self) -> &RunConfig {
        &self.cfg
    }

    /// Explicit backend choice; overrides `cfg.pipeline`.
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Shorthand for [`ExecBackend::Sequential`].
    pub fn sequential(self) -> Self {
        self.backend(ExecBackend::Sequential)
    }

    /// Shorthand for [`ExecBackend::Pipelined`] with an idle trace.
    pub fn pipelined(self, idle: IdleTrace) -> Self {
        self.backend(ExecBackend::Pipelined { idle })
    }

    /// Replace the default synthetic stream with a custom data source.
    pub fn source(mut self, source: impl DataSource + 'static) -> Self {
        self.source = Some(Box::new(source));
        self
    }

    /// Attach an observer; repeatable, invoked in attach order.
    pub fn observe(mut self, observer: impl RoundObserver + 'static) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Resume a killed run from a checkpoint file written by
    /// [`observers::Checkpoint`]. The caller re-supplies the rest of the
    /// assembly exactly as for the original run — same config (enforced
    /// by the snapshot's fingerprint at [`SessionBuilder::build`]), same
    /// backend kind, and an identically constructed data source (the
    /// session fast-forwards it to the snapshot's cursor; see
    /// [`crate::data::DataSource::fast_forward`]). Observer-internal
    /// state is *not* part of a snapshot — observers start fresh.
    ///
    /// Errors if the file marks a completed run.
    pub fn resume_from(self, path: impl AsRef<std::path::Path>) -> Result<Self> {
        let vault = CheckpointVault::new(path.as_ref(), 1);
        Ok(self.resume_from_vault(&vault)?.0)
    }

    /// Vault-aware [`SessionBuilder::resume_from`]: walk the vault's
    /// generations newest → oldest, resume from the first valid one, and
    /// report what the walk saw (rejected frames, the generation used,
    /// rounds lost to corruption) as [`RecoveryTelemetry`].
    pub fn resume_from_vault(
        self,
        vault: &CheckpointVault,
    ) -> Result<(Self, RecoveryTelemetry)> {
        let (loaded, telemetry) = load_vault_checkpoint(vault)?;
        match loaded {
            Loaded::Resumable(snap) => Ok((self.resume_from_snapshot(*snap), telemetry)),
            Loaded::Complete { round, .. } => Err(Error::Config(format!(
                "checkpoint {} marks a completed run ({round} rounds) — nothing to resume",
                vault.path().display()
            ))),
        }
    }

    /// Resume from an in-memory snapshot (the fleet runtime and tests;
    /// CLI paths use [`SessionBuilder::resume_from`]).
    pub fn resume_from_snapshot(mut self, snapshot: SessionSnapshot) -> Self {
        self.resume = Some(Box::new(snapshot));
        self
    }

    /// Everything [`SessionBuilder::build`] would reject, without
    /// consuming the builder: config validity plus resume-snapshot
    /// compatibility (fingerprint, backend kind, round bound). The fleet
    /// host calls this when a builder is *added*, so a bad member fails
    /// at assembly time instead of on some worker thread mid-run.
    pub fn validate(&self) -> Result<()> {
        self.cfg.validate()?;
        let backend =
            self.backend.clone().unwrap_or_else(|| ExecBackend::for_config(&self.cfg));
        if let Some(snap) = &self.resume {
            // refuse mismatched resumes up front: a wrong config or
            // backend would not fail loudly later, it would quietly
            // produce a different run
            snap.check_matches(&self.cfg, backend.kind())?;
            if snap.round > self.cfg.rounds {
                return Err(Error::Config(format!(
                    "checkpoint at round {} exceeds the configured {} rounds",
                    snap.round, self.cfg.rounds
                )));
            }
        }
        Ok(())
    }

    /// Validate the config and assemble the session.
    ///
    /// Building is cheap: engines load and threads spawn lazily on the
    /// first [`Session::step`], so a host can assemble a large fleet of
    /// sessions up front and artifact errors still surface from
    /// `step`/`run` exactly as they did when `run` owned the whole loop.
    pub fn build(self) -> Result<Session> {
        self.validate()?;
        let SessionBuilder { cfg, backend, source, observers, resume } = self;
        let backend = backend.unwrap_or_else(|| ExecBackend::for_config(&cfg));
        let mut source: Box<dyn DataSource> = match source {
            Some(s) => s,
            None => Box::new(default_source(&cfg)),
        };
        // retention plane: a storage budget wraps whatever source the run
        // uses in a byte-budgeted store (unless the caller already
        // supplied a retaining source with its own budget/policy)
        if cfg.store_bytes > 0 && !source.retains() {
            source = Box::new(RetainedSource::new(
                source,
                cfg.store_bytes,
                cfg.retention,
                cfg.replay_mix,
                cfg.seed,
            )?);
        }
        let outcomes = Vec::with_capacity(cfg.rounds);
        let completed = resume.as_ref().map_or(0, |s| s.round);
        Ok(Session {
            cfg,
            state: State::Pending { backend, source, observers, resume },
            outcomes,
            completed,
            pending_slowdown: None,
            pending_brownout: 0.0,
        })
    }

    /// Build and run in one step.
    pub fn run(self) -> Result<(RunRecord, Vec<RoundOutcome>)> {
        self.build()?.run()
    }
}

/// The default data source for a config: the synthetic stream the paper
/// evaluates on (same seeding as the original `build_stream`).
pub fn default_source(cfg: &RunConfig) -> StreamSource {
    let task = SynthTask::for_model(&cfg.model, cfg.seed);
    StreamSource::new(task, cfg.seed, cfg.noise)
}

/// What one [`Session::step`] / [`Session::step_op`] produced.
#[derive(Debug)]
pub enum StepEvent {
    /// One sub-round op completed ([`Session::step_op`] only — a round is
    /// still in flight and the session expects further `step_op` calls).
    /// [`Session::step`] never yields this: it resolves ops internally
    /// and surfaces whole rounds.
    OpCompleted(RoundOp),
    /// One round ran to completion (selection, training, accounting and
    /// observers included). The session is ready for the next step.
    RoundCompleted(RoundOutcome),
    /// The run is over: teardown, final eval and totals are done and the
    /// record is final. The per-round outcomes stay on the session
    /// ([`Session::outcomes`] / [`Session::take_outcomes`]). Stepping
    /// again is an error.
    Finished(RunRecord),
}

/// A fully configured run: one data source, one backend, the canonical
/// accounting loop — as a **step-driven state machine**.
///
/// [`Session::step`] executes exactly one round (the first step also
/// performs the lazy engine/thread start-up) and yields a [`StepEvent`];
/// [`Session::run`] is the trivial while-step wrapper. Both paths produce
/// byte-identical [`RunRecord`]s, which is what lets
/// [`crate::coordinator::host::Fleet`] interleave many sessions
/// round-by-round without perturbing any of them.
pub struct Session {
    cfg: RunConfig,
    state: State,
    outcomes: Vec<RoundOutcome>,
    /// Rounds completed, independent of `outcomes` (which a host may
    /// drain mid-run via [`Session::take_outcomes`]).
    completed: usize,
    /// Fault-plane injection, applied to the device simulator at the next
    /// [`Session::step`] (see [`Session::inject_slowdown`]).
    pending_slowdown: Option<f64>,
    /// Joules to drain at the next step (see [`Session::inject_brownout`]).
    pending_brownout: f64,
}

/// Session lifecycle. `Pending` holds the builder outputs until the first
/// step; `Running` owns the engines; `Finished` is terminal.
enum State {
    Pending {
        backend: ExecBackend,
        source: Box<dyn DataSource>,
        observers: Vec<Box<dyn RoundObserver>>,
        resume: Option<Box<SessionSnapshot>>,
    },
    Running(Box<Running>),
    Finished,
}

/// Message from the selector side to the trainer per round.
struct SelectedBatch {
    round: usize,
    batch: TrainBatch,
    report: SelectorReport,
    /// Selector state after this round's selection — attached only when a
    /// snapshot-consuming observer is listening (checkpoint capture; the
    /// trainer thread cannot reach the selector thread's state directly).
    state: Option<Box<SelectorState>>,
}

/// How the loop obtains each round's selected batch. `Sequential` runs
/// the selector inline (sync params, pull arrivals, select); `Pipelined`
/// receives from the selector thread and ships params back.
enum BatchFeed {
    Sequential {
        selector: SelectorEngine,
        source: Box<dyn DataSource>,
        stream_per_round: usize,
    },
    Pipelined {
        rx: mpsc::Receiver<Result<SelectedBatch>>,
        params: Arc<Latest<Arc<Vec<f32>>>>,
        handle: thread::JoinHandle<Result<()>>,
    },
}

impl BatchFeed {
    /// The [`RoundOp::Feed`] half of producing a round's batch: the
    /// sequential feed syncs the selector's params and pulls the round's
    /// stream arrivals; the pipelined feed is a no-op (its selector
    /// thread owns feed + select) and yields `None`.
    fn feed_arrivals(&mut self, trainer: &TrainerEngine) -> Result<Option<Vec<Sample>>> {
        match self {
            BatchFeed::Sequential { selector, source, stream_per_round } => {
                // sequential has no delay: selection sees current params
                // (share_params is a refcount bump, not a Vec clone)
                selector.sync_params(trainer.share_params())?;
                Ok(Some(source.next_round(*stream_per_round)))
            }
            BatchFeed::Pipelined { .. } => Ok(None),
        }
    }

    /// The [`RoundOp::Select`] half: produce round `round`'s batch +
    /// report from the feed op's arrivals, plus the pipelined selector's
    /// state capsule when checkpoint capture is on (the sequential
    /// selector is exported directly at snapshot time).
    fn select(
        &mut self,
        round: usize,
        arrivals: Option<Vec<Sample>>,
    ) -> Result<(TrainBatch, SelectorReport, Option<Box<SelectorState>>)> {
        match self {
            BatchFeed::Sequential { selector, source, .. } => {
                // detlint: allow(R001) invariant: the sequential feed op always yields arrivals
                let arrivals = arrivals.expect("sequential feed op produced arrivals");
                let (batch, mut report) = selector.select_round(round, arrivals)?;
                if source.retains() {
                    // retention stage: offer the round's scored candidates
                    // to the store, then report the post-round telemetry
                    source.offer_retention(selector.take_scored());
                    report.retention = source.retention_stats();
                }
                Ok((batch, report, None))
            }
            BatchFeed::Pipelined { rx, .. } => {
                let sel = rx
                    .recv()
                    .map_err(|_| Error::Pipeline("selector thread terminated".into()))??;
                debug_assert_eq!(sel.round, round);
                Ok((sel.batch, sel.report, sel.state))
            }
        }
    }

    /// Post-train hook: the pipelined backend ships a zero-copy param
    /// snapshot to the selector (overwriting any unconsumed one — the
    /// selector only ever wants the newest).
    fn after_train(&mut self, trainer: &TrainerEngine) {
        if let BatchFeed::Pipelined { params, .. } = self {
            params.publish(trainer.share_params());
        }
    }

    /// Tear down: hang up the channel so the selector thread unblocks,
    /// then join it and surface its error, if any.
    fn finish(self) -> Result<()> {
        match self {
            BatchFeed::Sequential { .. } => Ok(()),
            BatchFeed::Pipelined { rx, params, handle } => {
                drop(rx);
                drop(params);
                handle
                    .join()
                    .map_err(|_| Error::Pipeline("selector thread panicked".into()))?
            }
        }
    }
}

/// Where within the current round the next [`Running::step_op`] resumes —
/// the op-level micro-state. Mid-round values (arrivals, the selected
/// batch, the loss/timing pair) travel in the variant, so an op boundary
/// is a plain resumable value rather than a suspended stack frame, and a
/// host can interleave other sessions between any two ops.
enum RoundPhase {
    /// Round boundary: nothing in flight; the next op is [`RoundOp::Feed`].
    Feed,
    /// Feed done; [`RoundOp::Select`] turns the arrivals into a batch.
    Select { arrivals: Option<Vec<Sample>> },
    /// Select done; [`RoundOp::Train`] runs one SGD step on the batch.
    Train { batch: TrainBatch, report: SelectorReport },
    /// Train done; [`RoundOp::Sync`] closes the device-sim round and
    /// ships params back to the selector.
    Sync { loss: f32, train_ms: f64, report: SelectorReport },
    /// Sync done; [`RoundOp::Record`] does the round bookkeeping and
    /// completes the round.
    Record { loss: f32, train_ms: f64, timing: RoundTiming, report: SelectorReport },
}

/// What one [`Running::step_op`] advance produced.
enum OpStep {
    /// A mid-round op completed; the round is still in flight.
    Op(RoundOp),
    /// The record op closed the round.
    Round(RoundOutcome),
}

/// The live half of a session: engines, device sim, accounting state.
/// Created by the first step, consumed by the finishing step.
struct Running {
    pipelined: bool,
    rounds: usize,
    feed: BatchFeed,
    trainer: TrainerEngine,
    sim: DeviceSim,
    record: RunRecord,
    observers: Vec<Box<dyn RoundObserver>>,
    test: Vec<crate::data::Sample>,
    run_sw: Stopwatch,
    round: usize,
    stop: bool,
    /// Op-level resume point within the current round.
    phase: RoundPhase,
    /// Latest pipelined selector-state capsule (checkpoint capture).
    last_selector_state: Option<Box<SelectorState>>,
}

impl Running {
    /// Everything the old run-to-completion loop did before round 0:
    /// build the batch feed (spawning the selector thread when
    /// pipelined), load the trainer, start the clocks. On resume, restore
    /// the explicit snapshot state (params, selector, device sim, partial
    /// record) and fast-forward the data source past the completed
    /// rounds, so round `snapshot.round` starts from exactly the state
    /// the uninterrupted run would have had.
    fn start(
        cfg: &RunConfig,
        backend: ExecBackend,
        mut source: Box<dyn DataSource>,
        observers: Vec<Box<dyn RoundObserver>>,
        resume: Option<Box<SessionSnapshot>>,
    ) -> Result<Running> {
        let pipelined = backend.is_pipelined();
        let rounds = cfg.rounds;
        let capture = observers.iter().any(|o| o.wants_snapshots());
        let retains = source.retains();
        let test = source.test_set(cfg.test_size, cfg.seed);

        // restore the trainer-side state before the feed is built: the
        // pipelined branch pre-publishes the restored params so the
        // resumed selector's first sync sees them, not the init params
        let start_round = resume.as_ref().map_or(0, |s| s.round);
        let mut trainer = TrainerEngine::new(cfg)?;
        let mut sim = DeviceSim::new(&cfg.model);
        let mut record = RunRecord::new(cfg.method.name(), &cfg.model);
        let mut selector_restore: Option<SelectorState> = None;
        if let Some(snap) = resume {
            let snap = *snap;
            trainer.restore(snap.round, snap.params)?;
            sim.restore_state(snap.sim);
            record.curve = snap.curve;
            record.round_device_ms = snap.round_device_ms;
            record.round_host_ms = snap.round_host_ms;
            record.processing_delay = LatencyRecorder::from_samples(snap.delay_ms);
            let mut sel_state = snap.selector;
            source.fast_forward(snap.round, cfg.stream_per_round);
            // the resume contract for retaining sources: fast_forward only
            // replays the inner stream cursor; store contents, policy RNG
            // and telemetry come from the snapshot
            if let Some(ret) = sel_state.retention.take() {
                source.restore_retention(ret)?;
                record.retention = source.retention_stats();
            }
            selector_restore = Some(sel_state);
        }

        let feed = match backend {
            ExecBackend::Sequential => {
                let mut selector = SelectorEngine::new(cfg, source.task())?;
                selector.set_capture_scored(retains);
                if let Some(st) = selector_restore {
                    selector.restore_state(st)?;
                }
                BatchFeed::Sequential {
                    selector,
                    source,
                    stream_per_round: cfg.stream_per_round,
                }
            }
            ExecBackend::Pipelined { idle } => {
                // batches forward over a bounded channel (round-ordered,
                // moved); params backward through a latest-only slot
                let (batch_tx, batch_rx) = mpsc::sync_channel::<Result<SelectedBatch>>(1);
                let param_slot: Arc<Latest<Arc<Vec<f32>>>> = Arc::new(Latest::new());
                if start_round > 0 {
                    param_slot.publish(trainer.share_params());
                }
                let selector_params = Arc::clone(&param_slot);
                let sel_cfg = cfg.clone();
                let mut sel_source = source;
                // blessed spawn seam (detlint D005 / clippy
                // disallowed-methods): the pipelined selector thread
                #[allow(clippy::disallowed_methods)]
                let handle = thread::Builder::new()
                    .name("titan-selector".into())
                    .spawn(move || -> Result<()> {
                        let mut selector = SelectorEngine::new(&sel_cfg, sel_source.task())?;
                        selector.idle = idle;
                        selector.set_capture_scored(retains);
                        if let Some(st) = selector_restore {
                            selector.restore_state(st)?;
                        }
                        // the batch for round r is selected during round
                        // r-1's training window
                        for round in start_round..rounds {
                            // adopt the freshest params the trainer has
                            // shipped (non-blocking; one-round-delay
                            // tolerates staleness)
                            if let Some(p) = selector_params.take() {
                                selector.sync_params(p)?;
                            }
                            let arrivals = sel_source.next_round(sel_cfg.stream_per_round);
                            let out = selector.select_round(round, arrivals).map(|(batch, mut report)| {
                                if retains {
                                    // retention stage lives on the selector
                                    // thread: source + selector share it,
                                    // so the offer/stats pairing is the
                                    // same as the sequential feed's
                                    sel_source.offer_retention(selector.take_scored());
                                    report.retention = sel_source.retention_stats();
                                }
                                // capsule AFTER selecting: the state round
                                // r+1 starts from, i.e. what a snapshot
                                // taken at rounds_done = r+1 must carry
                                let state = capture.then(|| {
                                    let mut st = selector.export_state();
                                    st.retention = sel_source.export_retention();
                                    Box::new(st)
                                });
                                SelectedBatch { round, batch, report, state }
                            });
                            let failed = out.is_err();
                            if batch_tx.send(out).is_err() || failed {
                                break; // trainer hung up or selection failed
                            }
                        }
                        Ok(())
                    })
                    .map_err(|e| Error::Pipeline(format!("spawn selector: {e}")))?;
                BatchFeed::Pipelined { rx: batch_rx, params: param_slot, handle }
            }
        };

        Ok(Running {
            pipelined,
            rounds,
            feed,
            trainer,
            sim,
            record,
            observers,
            test,
            run_sw: Stopwatch::start(),
            round: start_round,
            stop: false,
            phase: RoundPhase::Feed,
            last_selector_state: None,
        })
    }

    /// True when no round is in flight (the next op is the feed op).
    fn at_boundary(&self) -> bool {
        matches!(self.phase, RoundPhase::Feed)
    }

    /// Advance the canonical round loop by exactly one op. The five ops
    /// partition the old whole-round body without reordering a single
    /// statement, so driving a session op-by-op is byte-identical to
    /// round-by-round stepping.
    ///
    /// On an op error the phase has already been reset to the round
    /// boundary (mid-round state is dropped); supervision rebuilds or
    /// quarantines the session, never resumes the broken round.
    fn step_op(&mut self, cfg: &RunConfig) -> Result<OpStep> {
        let round = self.round;
        match std::mem::replace(&mut self.phase, RoundPhase::Feed) {
            RoundPhase::Feed => {
                let arrivals = self.feed.feed_arrivals(&self.trainer)?;
                self.phase = RoundPhase::Select { arrivals };
                Ok(OpStep::Op(RoundOp::Feed))
            }
            RoundPhase::Select { arrivals } => {
                let (batch, report, selector_state) = self.feed.select(round, arrivals)?;
                if selector_state.is_some() {
                    self.last_selector_state = selector_state;
                }
                for &op in &report.ops {
                    self.sim.record(Lane::Gpu, op);
                }
                self.record.processing_delay.record_ms(report.per_sample_host_ms);
                self.phase = RoundPhase::Train { batch, report };
                Ok(OpStep::Op(RoundOp::Select))
            }
            RoundPhase::Train { batch, report } => {
                // training (weighted: the paper's unbiased estimator)
                let (loss, train_ms) = self.trainer.train_batch(&batch)?;
                self.sim.record(Lane::Cpu, Op::TrainStep { batch: batch.len() });
                self.phase = RoundPhase::Sync { loss, train_ms, report };
                Ok(OpStep::Op(RoundOp::Train))
            }
            RoundPhase::Sync { loss, train_ms, report } => {
                if self.pipelined {
                    self.sim.record(Lane::Gpu, Op::Sync); // params + batch handoff
                }
                let timing = self.sim.end_round(self.pipelined);
                self.feed.after_train(&self.trainer);
                self.phase = RoundPhase::Record { loss, train_ms, timing, report };
                Ok(OpStep::Op(RoundOp::Sync))
            }
            RoundPhase::Record { loss, train_ms, timing, report } => {
                self.record_round(cfg, loss, train_ms, timing, report).map(OpStep::Round)
            }
        }
    }

    /// The [`RoundOp::Record`] body: round accounting, observer fan-out,
    /// the eval cadence and the snapshot phase. Completing it closes the
    /// round (`self.round += 1`; the phase is already back at the
    /// boundary).
    fn record_round(
        &mut self,
        cfg: &RunConfig,
        loss: f32,
        train_ms: f64,
        timing: RoundTiming,
        report: SelectorReport,
    ) -> Result<RoundOutcome> {
        let round = self.round;
        self.record.round_device_ms.push(timing.wall_ms);
        // pipelined lanes overlap on the host too; sequential serializes
        self.record.round_host_ms.push(if self.pipelined {
            train_ms.max(report.host_ms)
        } else {
            report.host_ms + train_ms
        });
        let outcome = RoundOutcome {
            round,
            train_loss: loss,
            train_host_ms: train_ms,
            selector: report,
            device_wall_ms: timing.wall_ms,
            device_cpu_ms: timing.cpu_ms,
            device_gpu_ms: timing.gpu_ms,
        };
        let mut stop = false;
        for obs in self.observers.iter_mut() {
            stop |= obs.on_round(&outcome) == Control::Stop;
        }
        if let Some(t) = &outcome.selector.retention {
            // cumulative totals: the last round's telemetry IS the run's
            self.record.retention = Some(t.clone());
            for obs in self.observers.iter_mut() {
                stop |= obs.on_retention(round, t) == Control::Stop;
            }
        }

        // periodic eval (instrumentation; not charged to the device clock)
        if cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0 {
            let rep = self.trainer.evaluate(&self.test)?;
            let point = CurvePoint {
                round: round + 1,
                device_ms: self.sim.total_ms(),
                host_ms: self.run_sw.elapsed_ms(),
                train_loss: loss as f64,
                test_loss: rep.loss,
                test_accuracy: rep.accuracy,
            };
            for obs in self.observers.iter_mut() {
                stop |= obs.on_eval(&point) == Control::Stop;
            }
            self.record.curve.push(point);
        }
        if stop {
            self.stop = true;
        }

        // snapshot phase — after the round's accounting and the
        // on_round/on_eval hooks, so a snapshot taken here is exactly the
        // state the next round starts from (including this round's eval
        // point), and exactly one snapshot is built per round no matter
        // how many observers asked
        if !self.observers.is_empty() {
            let rounds_done = round + 1;
            let due: Vec<bool> = self
                .observers
                .iter()
                .map(|o| o.wants_snapshots() && o.snapshot_due(rounds_done))
                .collect();
            if due.iter().any(|&d| d) {
                let snapshot = self.build_snapshot(cfg, rounds_done)?;
                for (obs, take) in self.observers.iter_mut().zip(due) {
                    if take {
                        obs.on_snapshot(&snapshot);
                    }
                }
            }
        }
        self.round += 1;
        Ok(outcome)
    }

    /// Assemble the full mid-run snapshot after `rounds_done` completed
    /// rounds. The sequential selector is exported on the spot; the
    /// pipelined one comes from the capsule its thread attached to this
    /// round's batch.
    fn build_snapshot(&self, cfg: &RunConfig, rounds_done: usize) -> Result<SessionSnapshot> {
        let selector = match (&self.feed, &self.last_selector_state) {
            (BatchFeed::Sequential { selector, source, .. }, _) => {
                let mut st = selector.export_state();
                st.retention = source.export_retention();
                st
            }
            (BatchFeed::Pipelined { .. }, Some(state)) => (**state).clone(),
            (BatchFeed::Pipelined { .. }, None) => {
                return Err(Error::Pipeline(
                    "snapshot requested but no selector state was captured".into(),
                ));
            }
        };
        Ok(SessionSnapshot {
            config: cfg.to_json(),
            backend: if self.pipelined { "pipelined" } else { "sequential" }.into(),
            round: rounds_done,
            params: self.trainer.rt.export_params(),
            selector,
            sim: self.sim.export_state(),
            curve: self.record.curve.clone(),
            round_device_ms: self.record.round_device_ms.clone(),
            round_host_ms: self.record.round_host_ms.clone(),
            delay_ms: self.record.processing_delay.samples().to_vec(),
        })
    }

    /// Teardown + totals: join the selector thread, final eval, device
    /// clock / energy / memory roll-up, then the observers' `on_finish`
    /// (persisting observers flush their tail here). Consumes the
    /// running half.
    fn finish(self, cfg: &RunConfig) -> Result<RunRecord> {
        let Running {
            pipelined,
            feed,
            trainer,
            sim,
            mut record,
            mut observers,
            test,
            run_sw,
            ..
        } = self;
        feed.finish()?;

        let final_eval = trainer.evaluate(&test)?;
        record.final_accuracy = final_eval.accuracy;
        record.total_device_ms = sim.total_ms();
        record.total_host_ms = run_sw.elapsed_ms();
        record.energy_j = sim.energy().energy_j();
        record.avg_power_w = sim.energy().avg_power_w();
        let meta = &trainer.rt.set.meta;
        record.peak_memory_bytes = memory::estimate(
            meta.param_count,
            memory::act_mult_for(&cfg.model),
            cfg.batch_size,
            meta.input_dim,
            cfg.candidate_size,
            meta.cand_max,
            meta.feature_dim(cfg.filter_blocks),
            meta.filter_chunk,
            pipelined,
        )
        .total();
        for obs in observers.iter_mut() {
            obs.on_finish(&record);
        }
        Ok(record)
    }
}

impl Session {
    /// The run configuration this session executes.
    pub fn cfg(&self) -> &RunConfig {
        &self.cfg
    }

    /// Rounds completed so far (robust to [`Session::take_outcomes`]).
    pub fn rounds_completed(&self) -> usize {
        self.completed
    }

    /// True once [`StepEvent::Finished`] has been yielded.
    pub fn is_finished(&self) -> bool {
        matches!(self.state, State::Finished)
    }

    /// Per-round outcomes accumulated so far (all of them, once finished).
    pub fn outcomes(&self) -> &[RoundOutcome] {
        &self.outcomes
    }

    /// Move the accumulated outcomes out (e.g. after a stepped run).
    pub fn take_outcomes(&mut self) -> Vec<RoundOutcome> {
        std::mem::take(&mut self.outcomes)
    }

    /// Advance the state machine by one round: start up lazily on the
    /// first call, then run exactly one round per call, and finally tear
    /// down and yield the finished [`RunRecord`]. Stepping a finished
    /// session is an error. A loop over [`Session::step_op`], so round-
    /// and op-driven execution are the identical state machine; `step`
    /// never surfaces [`StepEvent::OpCompleted`].
    pub fn step(&mut self) -> Result<StepEvent> {
        loop {
            match self.step_op()? {
                StepEvent::OpCompleted(_) => continue,
                event => return Ok(event),
            }
        }
    }

    /// Advance the state machine by one sub-round op ([`RoundOp`]) —
    /// the sharded fleet host's scheduling quantum. Yields
    /// [`StepEvent::OpCompleted`] for each of feed/select/train/sync,
    /// [`StepEvent::RoundCompleted`] when the record op closes the round,
    /// and [`StepEvent::Finished`] once all rounds (or an observer stop)
    /// are done. Lazy start-up, the done-check and pending fault
    /// injections all apply at round boundaries only, so op-level
    /// interleaving cannot shift which round a fault lands on.
    pub fn step_op(&mut self) -> Result<StepEvent> {
        if matches!(self.state, State::Pending { .. }) {
            let state = std::mem::replace(&mut self.state, State::Finished);
            let State::Pending { backend, source, observers, resume } = state else {
                unreachable!("matched Pending above")
            };
            // on start-up failure the session stays Finished, so the
            // error is not retried on the next step
            let running = Running::start(&self.cfg, backend, source, observers, resume)?;
            self.state = State::Running(Box::new(running));
        }
        let done = match &self.state {
            State::Running(run) => {
                run.at_boundary() && (run.round >= run.rounds || run.stop)
            }
            State::Finished => {
                return Err(Error::Pipeline("session already finished".into()));
            }
            State::Pending { .. } => unreachable!("initialized above"),
        };
        if done {
            let state = std::mem::replace(&mut self.state, State::Finished);
            let State::Running(run) = state else {
                unreachable!("matched Running above")
            };
            let record = run.finish(&self.cfg)?;
            return Ok(StepEvent::Finished(record));
        }
        let State::Running(run) = &mut self.state else {
            unreachable!("checked Running above")
        };
        if run.at_boundary() {
            if let Some(factor) = self.pending_slowdown.take() {
                run.sim.set_round_slowdown(factor);
            }
            if self.pending_brownout > 0.0 {
                run.sim.drain_energy(self.pending_brownout);
                self.pending_brownout = 0.0;
            }
        }
        match run.step_op(&self.cfg)? {
            OpStep::Op(op) => Ok(StepEvent::OpCompleted(op)),
            OpStep::Round(outcome) => {
                self.completed += 1;
                self.outcomes.push(outcome.clone());
                Ok(StepEvent::RoundCompleted(outcome))
            }
        }
    }

    /// True when no round is in flight: before the first step, between
    /// rounds, and after finishing. The fleet host injects faults and
    /// applies supervision decisions only here, so fault cells keyed on
    /// the session-absolute round stay thread-count-independent.
    pub fn at_round_boundary(&self) -> bool {
        match &self.state {
            State::Running(run) => run.at_boundary(),
            State::Pending { .. } | State::Finished => true,
        }
    }

    /// Fault-plane hook: inflate the device clock of the **next** stepped
    /// round by `factor` (a straggler episode). One-shot — the simulator
    /// resets the factor after the round; calling twice before a step
    /// keeps the latest factor.
    pub fn inject_slowdown(&mut self, factor: f64) {
        self.pending_slowdown = Some(factor);
    }

    /// Fault-plane hook: drain `joules` from the device battery at the
    /// next stepped round (an energy brown-out). Accumulates across calls
    /// until a step consumes it.
    pub fn inject_brownout(&mut self, joules: f64) {
        self.pending_brownout += joules.max(0.0);
    }

    /// Run to completion: the trivial while-step wrapper. Byte-identical
    /// records to driving [`Session::step`] by hand.
    pub fn run(mut self) -> Result<(RunRecord, Vec<RoundOutcome>)> {
        loop {
            if let StepEvent::Finished(record) = self.step()? {
                return Ok((record, self.outcomes));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::observers::{CandidateAudit, EarlyStop};
    use super::*;
    use crate::config::{presets, Method};
    use crate::data::ReplaySource;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn small_cfg(method: Method) -> RunConfig {
        let mut c = presets::table1("mlp", method);
        c.rounds = 6;
        c.test_size = 200;
        c.eval_every = 3;
        c
    }

    #[test]
    fn backend_follows_config_flag() {
        let mut cfg = small_cfg(Method::Titan);
        cfg.pipeline = true;
        assert!(ExecBackend::for_config(&cfg).is_pipelined());
        cfg.pipeline = false;
        assert!(!ExecBackend::for_config(&cfg).is_pipelined());
    }

    #[test]
    fn builder_rejects_invalid_config() {
        let mut cfg = small_cfg(Method::Rs);
        cfg.candidate_size = 5; // < batch_size 10
        assert!(SessionBuilder::new(cfg).build().is_err());
    }

    #[test]
    fn early_stop_observer_fires_on_target() {
        let mut obs = EarlyStop::at_accuracy(0.5);
        let mut p = CurvePoint {
            round: 1,
            device_ms: 0.0,
            host_ms: 0.0,
            train_loss: 0.0,
            test_loss: 0.0,
            test_accuracy: 0.4,
        };
        assert_eq!(obs.on_eval(&p), Control::Continue);
        p.test_accuracy = 0.6;
        assert_eq!(obs.on_eval(&p), Control::Stop);
    }

    /// Synthetic snapshot for observer tests (no artifacts needed).
    fn tiny_snapshot(cfg: &RunConfig, round: usize) -> crate::coordinator::SessionSnapshot {
        crate::coordinator::SessionSnapshot {
            config: cfg.to_json(),
            backend: "sequential".into(),
            round,
            params: vec![0.5, -0.25],
            selector: crate::coordinator::SelectorState {
                rng: [1, 2, 3, 4],
                seen_per_class: vec![10, 10],
                filter: None,
                retention: None,
            },
            sim: crate::device::DeviceSimState::default(),
            curve: (1..=round / 2)
                .map(|i| CurvePoint {
                    round: i * 2,
                    device_ms: i as f64,
                    host_ms: i as f64,
                    train_loss: 1.0,
                    test_loss: 0.5,
                    test_accuracy: 0.25 * i as f64,
                })
                .collect(),
            round_device_ms: vec![1.0; round],
            round_host_ms: vec![1.0; round],
            delay_ms: vec![0.1; round],
        }
    }

    #[test]
    fn checkpoint_observer_writes_snapshots_and_final_marker() {
        use super::observers::{Checkpoint, CheckpointState};
        let path = std::env::temp_dir().join("titan_checkpoint_roundtrip.json");
        let _ = std::fs::remove_file(&path);
        let cfg = small_cfg(Method::Rs);
        let mut ck = Checkpoint::every(path.clone(), 2);
        // cadence contract: the session asks snapshot_due after each round
        assert!(ck.wants_snapshots());
        assert!(!ck.snapshot_due(1));
        assert!(ck.snapshot_due(2));
        assert!(!ck.snapshot_due(3));
        ck.on_snapshot(&tiny_snapshot(&cfg, 2));
        assert_eq!(
            Checkpoint::load(&path).unwrap(),
            CheckpointState { round: 2, accuracy_trace: vec![(2, 0.25)], complete: false }
        );
        ck.on_snapshot(&tiny_snapshot(&cfg, 4));
        let state = Checkpoint::load(&path).unwrap();
        assert!(!state.complete);
        assert_eq!(state.round, 4);
        assert_eq!(state.accuracy_trace, vec![(2, 0.25), (4, 0.5)]);
        // a resumable snapshot loads back for SessionBuilder::resume_from
        assert!(SessionBuilder::new(cfg.clone()).sequential().resume_from(&path).is_ok());

        // finish-time write: rounds 5–6 ran after the last cadence
        // multiple; without on_finish their eval points would be lost
        let mut record = RunRecord::new("rs", "mlp");
        record.round_device_ms = vec![1.0; 6];
        record.final_accuracy = 0.875;
        for i in 1..=3usize {
            record.curve.push(CurvePoint {
                round: i * 2,
                device_ms: i as f64,
                host_ms: i as f64,
                train_loss: 1.0,
                test_loss: 0.5,
                test_accuracy: 0.25 * i as f64,
            });
        }
        ck.on_finish(&record);
        let state = Checkpoint::load(&path).unwrap();
        assert_eq!(
            state,
            CheckpointState {
                round: 6,
                accuracy_trace: vec![(2, 0.25), (4, 0.5), (6, 0.75)],
                complete: true
            }
        );
        // resuming a completed run errors instead of silently re-running
        assert!(SessionBuilder::new(cfg).sequential().resume_from(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn checkpoint_zero_cadence_panics() {
        super::observers::Checkpoint::every("unused.json", 0);
    }

    #[test]
    fn checkpoint_write_failure_is_counted_not_fatal() {
        use super::observers::Checkpoint;
        // A regular file as the parent "directory" makes every write fail,
        // even for root (ENOTDIR is not a permission check).
        let blocker = std::env::temp_dir().join("titan_ck_notadir");
        std::fs::write(&blocker, b"not a directory").unwrap();
        let path = blocker.join("ck.json");
        let cfg = small_cfg(Method::Rs);
        let mut ck = Checkpoint::every(path, 2);
        let counter = ck.failure_counter();
        // every write fails, none panics or aborts the observer protocol
        ck.on_snapshot(&tiny_snapshot(&cfg, 2));
        assert_eq!(ck.failures(), 1);
        ck.on_snapshot(&tiny_snapshot(&cfg, 4));
        ck.on_finish(&RunRecord::new("rs", "mlp"));
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 3);
        let _ = std::fs::remove_file(&blocker);
    }

    #[test]
    fn checkpoint_leaves_no_tmp_files_behind() {
        use super::observers::Checkpoint;
        let dir = std::env::temp_dir().join("titan_ck_tmp_sweep");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        // a stale temp file from a killed writer, matching the
        // `<name>.<pid>.<seq>.tmp` pattern the sweeper targets
        let stale = dir.join("ck.json.4242.7.tmp");
        std::fs::write(&stale, b"{half written").unwrap();
        let cfg = small_cfg(Method::Rs);
        let mut ck = Checkpoint::every(path.clone(), 2);
        assert!(!stale.exists(), "construction sweeps stale temp files");
        ck.on_snapshot(&tiny_snapshot(&cfg, 2));
        assert!(path.exists(), "snapshot landed at the target path");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "no .tmp survives a successful write: {leftovers:?}");
        assert_eq!(ck.failures(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn candidate_audit_records_rounds() {
        let (mut audit, log) = CandidateAudit::new();
        for c in [30usize, 15, 22] {
            let o = RoundOutcome {
                selector: SelectorReport { candidates: c, ..Default::default() },
                ..Default::default()
            };
            assert_eq!(audit.on_round(&o), Control::Continue);
        }
        assert_eq!(*log.lock().unwrap(), vec![30, 15, 22]);
    }

    // ---- artifact-gated end-to-end pins ---------------------------------

    /// Deterministic-record equality: every field that does not read the
    /// host wall clock must match byte-for-byte.
    fn assert_deterministic_fields_eq(a: &RunRecord, b: &RunRecord) {
        assert_eq!(a.method, b.method);
        assert_eq!(a.model, b.model);
        assert_eq!(a.final_accuracy, b.final_accuracy);
        assert_eq!(a.total_device_ms, b.total_device_ms);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.avg_power_w, b.avg_power_w);
        assert_eq!(a.peak_memory_bytes, b.peak_memory_bytes);
        assert_eq!(a.round_device_ms, b.round_device_ms);
        assert_eq!(a.curve.len(), b.curve.len());
        for (x, y) in a.curve.iter().zip(&b.curve) {
            assert_eq!(x.round, y.round);
            assert_eq!(x.device_ms, y.device_ms);
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_loss, y.test_loss);
            assert_eq!(x.test_accuracy, y.test_accuracy);
        }
    }

    /// Step-driven execution must be byte-identical to the one-shot
    /// `Session::run` for both backends (`run` is literally a while-step
    /// wrapper, so anything else is a state-machine bug). The pipelined
    /// arm uses RS: parameter-independent selection is the class of run
    /// that is reproducible across *any* two pipelined executions (the
    /// latest-only param slot makes param-dependent selection timing-
    /// sensitive by design — see the module docs on the one-round delay).
    #[test]
    fn stepped_session_matches_one_shot_run_both_backends() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for (method, backend) in [
            (Method::Titan, ExecBackend::Sequential),
            (Method::Rs, ExecBackend::Sequential),
            (Method::Rs, ExecBackend::Pipelined { idle: IdleTrace::Constant(1.0) }),
        ] {
            let cfg = small_cfg(method);
            let (run_rec, run_out) = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .run()
                .unwrap();
            let mut session = SessionBuilder::new(cfg)
                .backend(backend.clone())
                .build()
                .unwrap();
            assert!(!session.is_finished());
            let step_rec = loop {
                match session.step().unwrap() {
                    StepEvent::OpCompleted(op) => {
                        panic!("step() must resolve ops internally, yielded {op:?}")
                    }
                    StepEvent::RoundCompleted(o) => {
                        assert_eq!(o.round + 1, session.rounds_completed());
                    }
                    StepEvent::Finished(record) => break record,
                }
            };
            assert!(session.is_finished());
            let step_out = session.take_outcomes();
            assert_deterministic_fields_eq(&run_rec, &step_rec);
            assert_eq!(run_out.len(), step_out.len(), "{backend:?}");
            for (a, b) in run_out.iter().zip(&step_out) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.train_loss, b.train_loss);
                assert_eq!(a.selector.ops, b.selector.ops);
                assert_eq!(a.selector.arrivals, b.selector.arrivals);
                assert_eq!(a.selector.candidates, b.selector.candidates);
                assert_eq!(a.device_wall_ms, b.device_wall_ms);
            }
        }
    }

    /// Op-granular stepping is the same state machine: driving a session
    /// by [`Session::step_op`] yields the canonical
    /// feed → select → train → sync op sequence each round,
    /// `RoundCompleted` at every boundary, and a final record
    /// byte-identical to whole-round stepping — with
    /// [`Session::at_round_boundary`] true exactly between rounds.
    #[test]
    fn op_stepped_session_matches_round_stepped() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for (method, backend) in [
            (Method::Titan, ExecBackend::Sequential),
            (Method::Rs, ExecBackend::Pipelined { idle: IdleTrace::Constant(1.0) }),
        ] {
            let cfg = small_cfg(method);
            let (want, want_out) = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .run()
                .unwrap();
            let mut session = SessionBuilder::new(cfg)
                .backend(backend.clone())
                .build()
                .unwrap();
            let mut ops: Vec<RoundOp> = Vec::new();
            let mut rounds = 0usize;
            let record = loop {
                assert_eq!(session.at_round_boundary(), ops.is_empty());
                match session.step_op().unwrap() {
                    StepEvent::OpCompleted(op) => ops.push(op),
                    StepEvent::RoundCompleted(o) => {
                        assert_eq!(
                            ops,
                            [RoundOp::Feed, RoundOp::Select, RoundOp::Train, RoundOp::Sync],
                            "{method:?} {backend:?} round {}",
                            o.round
                        );
                        ops.clear();
                        rounds += 1;
                    }
                    StepEvent::Finished(record) => break record,
                }
            };
            assert_eq!(rounds, 6, "{method:?} {backend:?}");
            assert!(session.at_round_boundary());
            assert_deterministic_fields_eq(&want, &record);
            let got_out = session.take_outcomes();
            assert_eq!(want_out.len(), got_out.len());
            for (a, b) in want_out.iter().zip(&got_out) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.train_loss, b.train_loss);
                assert_eq!(a.selector.ops, b.selector.ops);
                assert_eq!(a.device_wall_ms, b.device_wall_ms);
            }
        }
    }

    /// An un-started builder must cross threads (the sharded fleet host
    /// hands cold members to shard workers), and `validate` must agree
    /// with `build` without consuming the builder.
    #[test]
    fn builder_is_send_and_validate_matches_build() {
        fn assert_send<T: Send>() {}
        assert_send::<SessionBuilder>();

        let good = SessionBuilder::new(small_cfg(Method::Rs));
        assert!(good.validate().is_ok());

        let mut bad_cfg = small_cfg(Method::Rs);
        bad_cfg.candidate_size = 5; // < batch_size 10
        let bad = SessionBuilder::new(bad_cfg);
        assert!(bad.validate().is_err());
        assert!(bad.build().is_err());

        // resume bound is part of validate, not just build
        let cfg = small_cfg(Method::Rs);
        let late = tiny_snapshot(&cfg, 99); // beyond cfg.rounds = 6
        let b = SessionBuilder::new(cfg).sequential().resume_from_snapshot(late);
        assert!(b.validate().is_err());
    }

    /// Resume refuses a snapshot whose config fingerprint or backend kind
    /// differs from the session's — silently diverging would be the
    /// worst possible failure mode for a correctness feature.
    #[test]
    fn resume_rejects_mismatched_config_and_backend() {
        let cfg = small_cfg(Method::Rs);
        let snap = tiny_snapshot(&cfg, 2); // records backend "sequential"
        assert!(SessionBuilder::new(cfg.clone())
            .sequential()
            .resume_from_snapshot(snap.clone())
            .build()
            .is_ok());
        let mut other = cfg.clone();
        other.seed += 1;
        assert!(SessionBuilder::new(other)
            .sequential()
            .resume_from_snapshot(snap.clone())
            .build()
            .is_err());
        assert!(SessionBuilder::new(cfg.clone())
            .pipelined(IdleTrace::Constant(1.0))
            .resume_from_snapshot(snap)
            .build()
            .is_err());
        let late = tiny_snapshot(&cfg, 99); // beyond cfg.rounds = 6
        assert!(SessionBuilder::new(cfg)
            .sequential()
            .resume_from_snapshot(late)
            .build()
            .is_err());
    }

    /// The PR's headline pin: run k rounds with checkpointing, drop the
    /// session (the simulated kill — rounds after the last snapshot are
    /// lost), resume from the on-disk snapshot, and the final record is
    /// byte-identical to the uninterrupted run. Sequential covers the
    /// stateful path (Titan: filter estimators + selection RNG mid-run);
    /// Pipelined uses RS, the class of run that is reproducible across
    /// any two pipelined executions (see the one-round-delay module docs).
    #[test]
    fn killed_session_resumes_byte_identically_both_backends() {
        use super::observers::Checkpoint;
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for (method, backend) in [
            (Method::Titan, ExecBackend::Sequential),
            (Method::Rs, ExecBackend::Sequential),
            (Method::Rs, ExecBackend::Pipelined { idle: IdleTrace::Constant(1.0) }),
        ] {
            let path = std::env::temp_dir().join(format!(
                "titan_resume_{}_{}.json",
                method.name(),
                backend.kind()
            ));
            let _ = std::fs::remove_file(&path);
            let cfg = small_cfg(method); // 6 rounds, eval every 3
            let (want, want_out) = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .run()
                .unwrap();

            // checkpoint every 2 rounds, kill after 5: the snapshot holds
            // round 4, so the resumed run re-executes rounds 5–6
            let mut session = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .observe(Checkpoint::every(path.clone(), 2))
                .build()
                .unwrap();
            for _ in 0..5 {
                session.step().unwrap();
            }
            drop(session);

            let session = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .observe(Checkpoint::every(path.clone(), 2))
                .resume_from(&path)
                .unwrap()
                .build()
                .unwrap();
            assert_eq!(session.rounds_completed(), 4, "{method:?} {backend:?}");
            let (got, got_out) = session.run().unwrap();

            assert_deterministic_fields_eq(&want, &got);
            // post-resume outcomes equal the uninterrupted tail: same
            // selector ops, candidate counts and losses, round for round
            assert_eq!(got_out.len(), 2, "{method:?} {backend:?}");
            for (a, b) in want_out[4..].iter().zip(&got_out) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.train_loss, b.train_loss);
                assert_eq!(a.selector.ops, b.selector.ops);
                assert_eq!(a.selector.arrivals, b.selector.arrivals);
                assert_eq!(a.selector.candidates, b.selector.candidates);
                assert_eq!(a.device_wall_ms, b.device_wall_ms);
            }
            // the finished resume overwrote the file with a completion
            // marker covering the whole run
            let state = Checkpoint::load(&path).unwrap();
            assert!(state.complete);
            assert_eq!(state.round, 6);
            let _ = std::fs::remove_file(&path);
        }
    }

    /// Stepping past `Finished` is an error, and observers that stop the
    /// run still get a final `Finished` event on the next step.
    #[test]
    fn step_after_finished_errors() {
        if !have_artifacts() {
            return;
        }
        let mut session = SessionBuilder::new(small_cfg(Method::Rs))
            .sequential()
            .observe(EarlyStop::at_accuracy(0.0)) // stop at the first eval
            .build()
            .unwrap();
        let mut finished = false;
        for _ in 0..100 {
            match session.step().unwrap() {
                StepEvent::OpCompleted(op) => {
                    panic!("step() must resolve ops internally, yielded {op:?}")
                }
                StepEvent::RoundCompleted(_) => {}
                StepEvent::Finished(record) => {
                    assert!(record.final_accuracy.is_finite());
                    finished = true;
                    break;
                }
            }
        }
        assert!(finished, "early stop never finished");
        // the stop fired at the first eval checkpoint (round 3 of 6)
        assert_eq!(session.rounds_completed(), 3);
        assert!(session.step().is_err());
    }

    /// RS selection is parameter-independent, so both backends must make
    /// identical decisions and the learning-relevant record fields must
    /// match byte-for-byte (the device/host clocks legitimately differ).
    #[test]
    fn backends_agree_for_parameter_independent_selection() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let cfg = small_cfg(Method::Rs);
        let (seq, seq_out) = SessionBuilder::new(cfg.clone()).sequential().run().unwrap();
        let (pipe, pipe_out) = SessionBuilder::new(cfg)
            .pipelined(IdleTrace::Constant(1.0))
            .run()
            .unwrap();
        assert_eq!(seq.final_accuracy, pipe.final_accuracy);
        assert_eq!(seq.curve.len(), pipe.curve.len());
        for (a, b) in seq.curve.iter().zip(&pipe.curve) {
            assert_eq!(a.round, b.round);
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.test_loss, b.test_loss);
            assert_eq!(a.test_accuracy, b.test_accuracy);
        }
        // selector reports: identical ops, arrivals and candidate counts
        // (the Sync op is charged by the loop, not the selector report)
        assert_eq!(seq_out.len(), pipe_out.len());
        for (a, b) in seq_out.iter().zip(&pipe_out) {
            assert_eq!(a.selector.ops, b.selector.ops);
            assert_eq!(a.selector.arrivals, b.selector.arrivals);
            assert_eq!(a.selector.candidates, b.selector.candidates);
            assert_eq!(a.train_loss, b.train_loss);
        }
    }

    /// SelectorReport ops must be what the session charges to the GPU
    /// lane: per-round device_gpu_ms == Σ cost(op) (+ sync when pipelined).
    #[test]
    fn selector_report_ops_drive_gpu_lane_accounting() {
        if !have_artifacts() {
            return;
        }
        let cfg = small_cfg(Method::Titan);
        let costs = crate::device::CostModel::for_model(&cfg.model);
        let (_, seq_out) = SessionBuilder::new(cfg.clone()).sequential().run().unwrap();
        for o in &seq_out {
            let expect: f64 = o.selector.ops.iter().map(|&op| costs.cost_ms(op)).sum();
            assert!(
                (o.device_gpu_ms - expect).abs() < 1e-9,
                "round {}: gpu lane {} != op sum {}",
                o.round,
                o.device_gpu_ms,
                expect
            );
        }
        let (_, pipe_out) = SessionBuilder::new(cfg)
            .pipelined(IdleTrace::Constant(1.0))
            .run()
            .unwrap();
        let sync = costs.cost_ms(Op::Sync);
        for o in &pipe_out {
            let expect: f64 =
                o.selector.ops.iter().map(|&op| costs.cost_ms(op)).sum::<f64>() + sync;
            assert!(
                (o.device_gpu_ms - expect).abs() < 1e-9,
                "round {}: gpu lane {} != op sum {}",
                o.round,
                o.device_gpu_ms,
                expect
            );
        }
    }

    /// The pipelined backend is method-agnostic: a non-Titan method runs
    /// through the selector thread unchanged (the old coordinator only
    /// ever pipelined Titan).
    #[test]
    fn pipelined_backend_is_method_agnostic() {
        if !have_artifacts() {
            return;
        }
        for method in [Method::Cis, Method::Camel] {
            let (record, outcomes) = SessionBuilder::new(small_cfg(method))
                .pipelined(IdleTrace::Constant(1.0))
                .run()
                .unwrap();
            assert_eq!(outcomes.len(), 6, "{method:?}");
            assert!(record.final_accuracy.is_finite());
            // lanes overlap on the device clock
            for o in &outcomes {
                assert!(o.device_wall_ms >= o.device_cpu_ms.max(o.device_gpu_ms) - 1e-9);
            }
        }
    }

    /// Custom source + early-stop observer through the full loop: the
    /// session trains from a replay pool and stops at the first eval.
    #[test]
    fn replay_source_and_early_stop_through_session() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = small_cfg(Method::Rs);
        cfg.rounds = 20;
        cfg.eval_every = 2;
        let mut stream = default_source(&cfg);
        let replay =
            ReplaySource::capture(&mut stream, cfg.stream_per_round * 2).unwrap();
        let (record, outcomes) = SessionBuilder::new(cfg)
            .sequential()
            .source(replay)
            .observe(EarlyStop::at_accuracy(0.0)) // any accuracy stops
            .run()
            .unwrap();
        assert_eq!(outcomes.len(), 2, "stopped at the first eval checkpoint");
        assert_eq!(record.curve.len(), 1);
        assert!(record.final_accuracy.is_finite());
    }

    /// A storage budget turns on the retention plane end to end: the
    /// record carries cumulative telemetry, every round fires the
    /// `on_retention` hook, and without a budget neither happens.
    #[test]
    fn retaining_session_reports_telemetry_and_fires_observer_hook() {
        use std::sync::{Arc, Mutex};
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        struct RetentionAudit {
            rounds: Arc<Mutex<Vec<usize>>>,
        }
        impl RoundObserver for RetentionAudit {
            fn on_retention(
                &mut self,
                round: usize,
                t: &crate::retention::RetentionTelemetry,
            ) -> Control {
                assert!(t.offers >= t.admits + t.refreshes + t.rejects);
                self.rounds.lock().unwrap().push(round);
                Control::Continue
            }
        }
        let mut cfg = small_cfg(Method::Titan);
        cfg.store_bytes = 1 << 16;
        cfg.replay_mix = 0.25;
        let log = Arc::new(Mutex::new(Vec::new()));
        let (record, outcomes) = SessionBuilder::new(cfg.clone())
            .sequential()
            .observe(RetentionAudit { rounds: Arc::clone(&log) })
            .run()
            .unwrap();
        let t = record.retention.expect("budgeted run must carry telemetry");
        assert!(t.offers > 0, "candidates were offered to the store");
        assert!(t.admits > 0, "a 64 KiB budget admits something");
        assert!(t.bytes_held > 0 && t.bytes_held <= 1 << 16);
        assert_eq!(*log.lock().unwrap(), (0..outcomes.len()).collect::<Vec<_>>());
        for o in &outcomes {
            assert!(o.selector.retention.is_some());
        }

        // no budget, no retention plane: same config minus the store
        cfg.store_bytes = 0;
        let (plain, plain_out) = SessionBuilder::new(cfg).sequential().run().unwrap();
        assert!(plain.retention.is_none());
        assert!(plain_out.iter().all(|o| o.selector.retention.is_none()));
    }

    /// The retention plane obeys the kill/resume pin: checkpoint, kill,
    /// resume, and the final record — store telemetry included — is
    /// byte-identical to the uninterrupted budgeted run. Sequential Titan
    /// covers the score-weighted store fed by real coarse scores;
    /// pipelined RS covers the capsule path (store state crosses the
    /// thread boundary attached to the batch).
    #[test]
    fn killed_retaining_session_resumes_byte_identically() {
        use super::observers::Checkpoint;
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for (method, backend) in [
            (Method::Titan, ExecBackend::Sequential),
            (Method::Rs, ExecBackend::Pipelined { idle: IdleTrace::Constant(1.0) }),
        ] {
            let path = std::env::temp_dir().join(format!(
                "titan_retention_resume_{}_{}.json",
                method.name(),
                backend.kind()
            ));
            let _ = std::fs::remove_file(&path);
            let mut cfg = small_cfg(method); // 6 rounds, eval every 3
            cfg.store_bytes = 1 << 14;
            cfg.replay_mix = 0.5;
            let (want, _) = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .run()
                .unwrap();
            assert!(want.retention.is_some(), "{method:?} {backend:?}");

            let mut session = SessionBuilder::new(cfg.clone())
                .backend(backend.clone())
                .observe(Checkpoint::every(path.clone(), 2))
                .build()
                .unwrap();
            for _ in 0..5 {
                session.step().unwrap();
            }
            drop(session); // kill: round 5 ran past the round-4 snapshot

            let (got, _) = SessionBuilder::new(cfg)
                .backend(backend.clone())
                .resume_from(&path)
                .unwrap()
                .build()
                .unwrap()
                .run()
                .unwrap();
            assert_deterministic_fields_eq(&want, &got);
            assert_eq!(
                want.retention, got.retention,
                "{method:?} {backend:?}: resumed telemetry must match"
            );
            let _ = std::fs::remove_file(&path);
        }
    }

    /// Observer ordering: audit sees every round exactly once, in order.
    #[test]
    fn audit_observer_sees_every_round() {
        if !have_artifacts() {
            return;
        }
        let cfg = small_cfg(Method::Titan);
        let (audit, log) = CandidateAudit::new();
        let (_, outcomes) = SessionBuilder::new(cfg)
            .pipelined(IdleTrace::Constant(0.5))
            .observe(audit)
            .run()
            .unwrap();
        let seen = log.lock().unwrap().clone();
        assert_eq!(seen.len(), outcomes.len());
        // budget = 0.5 * 30 = 15
        assert!(seen.iter().all(|&c| c <= 15), "{seen:?}");
    }
}
