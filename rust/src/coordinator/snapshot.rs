//! Full-session checkpoints: the on-disk state a killed run resumes from.
//!
//! A [`SessionSnapshot`] is everything a mid-run
//! [`Session`](crate::coordinator::Session) needs to continue **byte-
//! identically** after the process is killed and restarted:
//!
//! - the run's [`RunConfig`] serialization (doubling as the **config
//!   fingerprint** — resume refuses a snapshot whose run was configured
//!   differently, because silently diverging is worse than erroring);
//! - the completed-round counter (deterministic data sources are brought
//!   back to their cursor by drawing-and-discarding that many rounds —
//!   see [`crate::data::DataSource::fast_forward`] — instead of
//!   serializing source internals);
//! - the model parameters and the trainer's round counter (lr schedule);
//! - the selection-side state ([`SelectorState`]: selection RNG, stream
//!   class counts, coarse-filter estimators + retained candidates);
//! - the device simulator's clock/energy accumulators and the partial
//!   run record (accuracy curve, per-round timings, processing delays).
//!
//! Serialization goes through [`crate::util::json`]. All floats are
//! written in Rust's shortest-roundtrip form, so every `f64`/`f32`
//! survives a save/load cycle bit-for-bit; the 64-bit RNG words are hex
//! strings because a JSON number (f64) only carries 53 bits of integer
//! precision.
//!
//! A finished run overwrites its checkpoint with a small **completion
//! marker** (`"complete": true`, final accuracy, accuracy trace) so the
//! tail of the run is never lost to the cadence (rounds after the last
//! cadence multiple) and so a resume of an already-finished run errors
//! cleanly instead of re-running it.

use std::path::Path;

use crate::config::RunConfig;
use crate::coordinator::SelectorState;
use crate::data::buffer::Candidate;
use crate::data::Sample;
use crate::device::{DeviceSimState, RoundTiming};
use crate::filter::FilterState;
use crate::metrics::{CurvePoint, RunRecord};
use crate::retention::{PolicyState, RetentionState, RetentionTelemetry};
use crate::util::json::Json;
use crate::{Error, Result};

/// Checkpoint format version (bumped on incompatible layout changes).
pub const CHECKPOINT_VERSION: usize = 1;

/// Complete mid-run session state — see the module docs.
#[derive(Clone, Debug)]
pub struct SessionSnapshot {
    /// `RunConfig::to_json` of the run; compact form is the fingerprint.
    pub config: Json,
    /// Execution backend kind, `"sequential"` or `"pipelined"` (a
    /// sequential snapshot resumed pipelined would silently change the
    /// run's semantics, so it is checked like the config).
    pub backend: String,
    /// Completed rounds at snapshot time.
    pub round: usize,
    /// Model parameters after `round` rounds.
    pub params: Vec<f32>,
    /// Selection-side state after `round` rounds.
    pub selector: SelectorState,
    /// Device-sim clock/energy accumulators.
    pub sim: DeviceSimState,
    /// Partial run record: eval curve so far.
    pub curve: Vec<CurvePoint>,
    /// Partial run record: per-round device wall ms.
    pub round_device_ms: Vec<f64>,
    /// Partial run record: per-round host wall ms (wall-clock history of
    /// the interrupted run; carried verbatim).
    pub round_host_ms: Vec<f64>,
    /// Partial run record: per-sample processing-delay samples (ms).
    pub delay_ms: Vec<f64>,
}

/// What a checkpoint file holds.
pub enum Loaded {
    /// A mid-run snapshot a session can resume from.
    Resumable(Box<SessionSnapshot>),
    /// The run finished; nothing to resume.
    Complete {
        /// Rounds the finished run executed.
        round: usize,
        /// Final test accuracy of the finished run.
        final_accuracy: f64,
        /// `(round, test_accuracy)` eval checkpoints of the whole run.
        accuracy_trace: Vec<(usize, f64)>,
        /// Config of the finished run (`Json::Null` when the run finished
        /// before its first cadence snapshot — the marker then has no
        /// config to carry). Lets a resume path verify the marker really
        /// belongs to the run it is about to skip.
        config: Json,
    },
}

impl SessionSnapshot {
    /// The config fingerprint this snapshot was taken under.
    pub fn fingerprint(&self) -> String {
        self.config.to_string_compact()
    }

    /// Refuse resume under a different configuration or backend.
    pub fn check_matches(&self, cfg: &RunConfig, backend_kind: &str) -> Result<()> {
        if self.fingerprint() != cfg.fingerprint() {
            return Err(Error::Config(format!(
                "checkpoint config fingerprint does not match this run's config — \
                 resuming would silently diverge.\n  checkpoint: {}\n  session:    {}",
                self.fingerprint(),
                cfg.fingerprint()
            )));
        }
        if self.backend != backend_kind {
            return Err(Error::Config(format!(
                "checkpoint was taken on the {:?} backend, session runs {:?}",
                self.backend, backend_kind
            )));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("titan_checkpoint", Json::Num(CHECKPOINT_VERSION as f64)),
            ("complete", Json::Bool(false)),
            ("round", Json::Num(self.round as f64)),
            ("config", self.config.clone()),
            ("backend", Json::Str(self.backend.clone())),
            ("params", Json::from_f32s(&self.params)),
            ("selector", selector_to_json(&self.selector)),
            ("sim", sim_to_json(&self.sim)),
            (
                "record",
                Json::obj(vec![
                    ("curve", Json::Arr(self.curve.iter().map(|p| p.to_json()).collect())),
                    ("round_device_ms", Json::from_f64s(&self.round_device_ms)),
                    ("round_host_ms", Json::from_f64s(&self.round_host_ms)),
                    ("delay_ms", Json::from_f64s(&self.delay_ms)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SessionSnapshot> {
        let record = j.get("record")?;
        Ok(SessionSnapshot {
            config: j.get("config")?.clone(),
            backend: j.get("backend")?.as_str()?.to_string(),
            round: j.get("round")?.as_usize()?,
            params: f32_list(j.get("params")?)?,
            selector: selector_from_json(j.get("selector")?)?,
            sim: sim_from_json(j.get("sim")?)?,
            curve: record
                .get("curve")?
                .as_arr()?
                .iter()
                .map(CurvePoint::from_json)
                .collect::<Result<Vec<_>>>()?,
            round_device_ms: record.get("round_device_ms")?.f64_list()?,
            round_host_ms: record.get("round_host_ms")?.f64_list()?,
            delay_ms: record.get("delay_ms")?.f64_list()?,
        })
    }
}

/// The small JSON a finished run overwrites its checkpoint with: the
/// completed-round count, the full accuracy trace (including everything
/// after the last cadence snapshot) and the final accuracy.
pub fn completion_marker(config: &Json, record: &RunRecord) -> Json {
    let trace = Json::Arr(
        record
            .curve
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("round", Json::Num(p.round as f64)),
                    ("test_accuracy", Json::Num(p.test_accuracy)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("titan_checkpoint", Json::Num(CHECKPOINT_VERSION as f64)),
        ("complete", Json::Bool(true)),
        ("round", Json::Num(record.round_device_ms.len() as f64)),
        ("config", config.clone()),
        ("accuracy_trace", trace),
        ("final_accuracy", Json::Num(record.final_accuracy)),
    ])
}

/// Read a checkpoint file written by the `Checkpoint` observer.
///
/// Every failure — unreadable file, truncated/corrupt JSON, wrong
/// version, missing or ill-typed field — comes back as a typed
/// [`Error::Checkpoint`] naming the path and the stage that failed, so
/// callers (and the restart supervisor) can distinguish "this file is
/// damaged, fall back" from config errors without string-matching.
pub fn load_checkpoint(path: &Path) -> Result<Loaded> {
    let display = path.display().to_string();
    let text = std::fs::read_to_string(path).map_err(|e| Error::Checkpoint {
        path: display.clone(),
        stage: "read",
        detail: e.to_string(),
    })?;
    load_checkpoint_str(&text, &display)
}

/// Decode checkpoint text already read from disk (`path` names the
/// source file in errors) — the parse half of [`load_checkpoint`], used
/// directly by the vault, whose frame validation already read and
/// checksummed the payload.
pub fn load_checkpoint_str(text: &str, path: &str) -> Result<Loaded> {
    let fail = |stage: &'static str, detail: String| Error::Checkpoint {
        path: path.to_string(),
        stage,
        detail,
    };
    let j = Json::parse(text).map_err(|e| fail("parse", e.to_string()))?;
    let version = j
        .get("titan_checkpoint")
        .map_err(|_| fail("version", "missing titan_checkpoint field — not a titan checkpoint".into()))?;
    let version = version.as_usize().map_err(|e| fail("version", e.to_string()))?;
    if version != CHECKPOINT_VERSION {
        return Err(fail(
            "version",
            format!("unsupported checkpoint version {version} (this build reads {CHECKPOINT_VERSION})"),
        ));
    }
    let complete = j
        .get("complete")
        .and_then(|v| v.as_bool())
        .map_err(|e| fail("field", e.to_string()))?;
    if complete {
        let decode = || -> Result<Loaded> {
            let accuracy_trace = j
                .get("accuracy_trace")?
                .as_arr()?
                .iter()
                .map(|p| Ok((p.get("round")?.as_usize()?, p.get("test_accuracy")?.as_f64()?)))
                .collect::<Result<Vec<_>>>()?;
            Ok(Loaded::Complete {
                round: j.get("round")?.as_usize()?,
                final_accuracy: j.get("final_accuracy")?.as_f64()?,
                accuracy_trace,
                config: j.get("config")?.clone(),
            })
        };
        decode().map_err(|e| fail("field", e.to_string()))
    } else {
        SessionSnapshot::from_json(&j)
            .map(|s| Loaded::Resumable(Box::new(s)))
            .map_err(|e| fail("field", e.to_string()))
    }
}

/// Read the newest valid checkpoint out of a
/// [`CheckpointVault`](crate::coordinator::vault::CheckpointVault):
/// validated framed generations first (newest → oldest), the legacy
/// unframed file last. Returns what resumed plus the
/// [`RecoveryTelemetry`](crate::coordinator::vault::RecoveryTelemetry)
/// of the walk — callers surface it when
/// [`degraded`](crate::coordinator::vault::RecoveryTelemetry::degraded).
pub fn load_vault_checkpoint(
    vault: &crate::coordinator::vault::CheckpointVault,
) -> Result<(Loaded, crate::coordinator::vault::RecoveryTelemetry)> {
    let (win, telemetry) = vault.load_latest_valid();
    let win = win?;
    let loaded = load_checkpoint_str(&win.text, &win.path.display().to_string())?;
    Ok((loaded, telemetry))
}

// ---- field codecs ---------------------------------------------------------

/// u64 with full precision (JSON numbers are f64: 53 integer bits).
/// `pub(crate)`: the FL capsule codec ([`crate::fl`]) reuses these.
pub(crate) fn u64_to_json(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

pub(crate) fn u64_from_json(j: &Json) -> Result<u64> {
    u64::from_str_radix(j.as_str()?, 16)
        .map_err(|e| Error::Json(format!("bad u64 hex: {e}")))
}

pub(crate) fn f32_list(j: &Json) -> Result<Vec<f32>> {
    // f32 -> f64 -> f32 is lossless, so Num carries f32s bit-exactly
    // detlint: allow(C001) decode half of a lossless f32<->f64 roundtrip (pinned by snapshot tests)
    Ok(j.f64_list()?.into_iter().map(|x| x as f32).collect())
}

/// Counters (round/class/arrival counts) stay plain JSON numbers: they
/// are bounded far below 2^53 by construction, unlike RNG words.
fn count_list(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn count_list_from(j: &Json) -> Result<Vec<u64>> {
    j.as_arr()?.iter().map(|v| Ok(v.as_usize()? as u64)).collect()
}

/// Four RNG words as a hex-string array (the xoshiro256** state).
pub(crate) fn words_to_json(ws: &[u64; 4]) -> Json {
    Json::Arr(ws.iter().map(|&w| u64_to_json(w)).collect())
}

pub(crate) fn words_from_json(j: &Json) -> Result<[u64; 4]> {
    let words = j.as_arr()?;
    if words.len() != 4 {
        return Err(Error::Json(format!("rng state has {} words, want 4", words.len())));
    }
    let mut out = [0u64; 4];
    for (slot, w) in out.iter_mut().zip(words) {
        *slot = u64_from_json(w)?;
    }
    Ok(out)
}

fn selector_to_json(s: &SelectorState) -> Json {
    let filter = match &s.filter {
        None => Json::Null,
        Some(f) => filter_to_json(f),
    };
    let mut fields = vec![
        ("rng", words_to_json(&s.rng)),
        ("seen_per_class", count_list(&s.seen_per_class)),
        ("filter", filter),
    ];
    // emitted only for retaining runs, so non-retaining snapshots stay
    // byte-identical to pre-retention builds
    if let Some(r) = &s.retention {
        fields.push(("retention", retention_to_json(r)));
    }
    Json::obj(fields)
}

fn selector_from_json(j: &Json) -> Result<SelectorState> {
    let filter = match j.get("filter")? {
        Json::Null => None,
        f => Some(filter_from_json(f)?),
    };
    // absent (pre-retention snapshots, non-retaining runs) and Null both
    // mean "no retention plane"
    let retention = match j.get("retention") {
        Err(_) | Ok(Json::Null) => None,
        Ok(r) => Some(retention_from_json(r)?),
    };
    Ok(SelectorState {
        rng: words_from_json(j.get("rng")?)?,
        seen_per_class: count_list_from(j.get("seen_per_class")?)?,
        filter,
        retention,
    })
}

fn retention_to_json(r: &RetentionState) -> Json {
    let policy = match &r.policy {
        None => Json::Null,
        Some(p) => Json::obj(vec![
            ("rng", words_to_json(&p.rng)),
            // hex like the RNG words: a counter, but unbounded in principle
            ("seen", u64_to_json(p.seen)),
        ]),
    };
    Json::obj(vec![
        ("entries", Json::Arr(r.entries.iter().map(candidate_to_json).collect())),
        ("telemetry", r.telemetry.to_json()),
        ("policy", policy),
        ("blend_rng", words_to_json(&r.blend_rng)),
    ])
}

fn retention_from_json(j: &Json) -> Result<RetentionState> {
    let policy = match j.get("policy")? {
        Json::Null => None,
        p => Some(PolicyState {
            rng: words_from_json(p.get("rng")?)?,
            seen: u64_from_json(p.get("seen")?)?,
        }),
    };
    Ok(RetentionState {
        entries: j
            .get("entries")?
            .as_arr()?
            .iter()
            .map(candidate_from_json)
            .collect::<Result<Vec<_>>>()?,
        telemetry: RetentionTelemetry::from_json(j.get("telemetry")?)?,
        policy,
        blend_rng: words_from_json(j.get("blend_rng")?)?,
    })
}

fn filter_to_json(f: &FilterState) -> Json {
    let centroid = Json::Arr(
        f.centroid
            .iter()
            .map(|(n, mean)| {
                Json::obj(vec![
                    ("n", Json::Num(*n as f64)),
                    ("mean", Json::from_f64s(mean)),
                ])
            })
            .collect(),
    );
    let norm2 = Json::Arr(
        f.norm2
            .iter()
            .map(|&(n, mean, m2)| {
                Json::obj(vec![
                    ("n", Json::Num(n as f64)),
                    ("mean", Json::Num(mean)),
                    ("m2", Json::Num(m2)),
                ])
            })
            .collect(),
    );
    let buffer = Json::Arr(f.buffer.iter().map(candidate_to_json).collect());
    let thresh = match f.buffer_thresh {
        None => Json::Null,
        Some(t) => Json::Num(t),
    };
    Json::obj(vec![
        ("centroid", centroid),
        ("norm2", norm2),
        ("buffer", buffer),
        ("buffer_cap", Json::Num(f.buffer_cap as f64)),
        ("buffer_thresh", thresh),
        ("processed", Json::Num(f.processed as f64)),
    ])
}

fn filter_from_json(j: &Json) -> Result<FilterState> {
    let centroid = j
        .get("centroid")?
        .as_arr()?
        .iter()
        .map(|c| Ok((c.get("n")?.as_usize()? as u64, c.get("mean")?.f64_list()?)))
        .collect::<Result<Vec<_>>>()?;
    let norm2 = j
        .get("norm2")?
        .as_arr()?
        .iter()
        .map(|w| {
            Ok((
                w.get("n")?.as_usize()? as u64,
                w.get("mean")?.as_f64()?,
                w.get("m2")?.as_f64()?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let buffer = j
        .get("buffer")?
        .as_arr()?
        .iter()
        .map(candidate_from_json)
        .collect::<Result<Vec<_>>>()?;
    // absent (pre-ring snapshots) and Null both mean "no threshold"; a
    // round-boundary snapshot always lands here since the buffer drains
    // every round
    let buffer_thresh = match j.get("buffer_thresh") {
        Err(_) | Ok(Json::Null) => None,
        Ok(v) => Some(v.as_f64()?),
    };
    Ok(FilterState {
        centroid,
        norm2,
        buffer,
        buffer_cap: j.get("buffer_cap")?.as_usize()?,
        buffer_thresh,
        processed: j.get("processed")?.as_usize()? as u64,
    })
}

fn candidate_to_json(c: &Candidate) -> Json {
    Json::obj(vec![
        ("id", Json::Num(c.sample.id as f64)),
        ("label", Json::Num(c.sample.label as f64)),
        ("clean_label", Json::Num(c.sample.clean_label as f64)),
        ("x", Json::from_f32s(&c.sample.x)),
        ("score", Json::Num(c.score)),
    ])
}

fn candidate_from_json(j: &Json) -> Result<Candidate> {
    let mut sample = Sample::new(
        j.get("id")?.as_usize()? as u64,
        j.get("label")?.as_usize()? as u32,
        f32_list(j.get("x")?)?,
    );
    sample.clean_label = j.get("clean_label")?.as_usize()? as u32;
    Ok(Candidate { sample, score: j.get("score")?.as_f64()? })
}

fn sim_to_json(s: &DeviceSimState) -> Json {
    let rounds = Json::Arr(
        s.rounds
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("cpu_ms", Json::Num(t.cpu_ms)),
                    ("gpu_ms", Json::Num(t.gpu_ms)),
                    ("wall_ms", Json::Num(t.wall_ms)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("total_ms", Json::Num(s.total_ms)),
        ("energy_j", Json::Num(s.energy_j)),
        ("energy_wall_ms", Json::Num(s.energy_wall_ms)),
        ("rounds", rounds),
    ])
}

fn sim_from_json(j: &Json) -> Result<DeviceSimState> {
    let rounds = j
        .get("rounds")?
        .as_arr()?
        .iter()
        .map(|t| {
            Ok(RoundTiming {
                cpu_ms: t.get("cpu_ms")?.as_f64()?,
                gpu_ms: t.get("gpu_ms")?.as_f64()?,
                wall_ms: t.get("wall_ms")?.as_f64()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(DeviceSimState {
        total_ms: j.get("total_ms")?.as_f64()?,
        energy_j: j.get("energy_j")?.as_f64()?,
        energy_wall_ms: j.get("energy_wall_ms")?.as_f64()?,
        rounds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> SessionSnapshot {
        let cfg = RunConfig { rounds: 10, ..RunConfig::default() };
        SessionSnapshot {
            config: cfg.to_json(),
            backend: "sequential".into(),
            round: 4,
            params: vec![0.125, -3.5, 1.0e-7, 0.30000001],
            selector: SelectorState {
                // high-bit words exercise the hex codec (f64 would lose them)
                rng: [u64::MAX, 0x8000_0000_0000_0001, 42, 0xDEAD_BEEF_CAFE_F00D],
                seen_per_class: vec![7, 0, 13],
                filter: Some(FilterState {
                    centroid: vec![(2, vec![0.5, -0.25]), (0, vec![0.0, 0.0])],
                    norm2: vec![(2, 1.5, 0.125), (0, 0.0, 0.0)],
                    buffer: vec![Candidate {
                        sample: Sample::new(9, 1, vec![1.5, -2.25]),
                        score: 0.1 + 0.2,
                    }],
                    buffer_cap: 8,
                    // awkward float: the threshold must roundtrip bit-exactly
                    buffer_thresh: Some(0.1 + 0.2),
                    processed: 40,
                }),
                retention: Some(RetentionState {
                    entries: vec![Candidate {
                        sample: Sample::new(77, 2, vec![0.5, 0.75]),
                        score: 1.0 / 3.0,
                    }],
                    telemetry: RetentionTelemetry {
                        offers: 30,
                        admits: 12,
                        refreshes: 3,
                        rejects: 5,
                        evicts_score: 10,
                        evicts_balanced: 0,
                        evicts_reservoir: 0,
                        bytes_held: 40,
                        retained_emitted: 6,
                        emitted_total: 48,
                    },
                    policy: Some(PolicyState {
                        rng: [0xFFFF_0000_FFFF_0000, 1, 2, 3],
                        seen: 30,
                    }),
                    blend_rng: [9, 8, 7, u64::MAX - 1],
                }),
            },
            sim: DeviceSimState {
                total_ms: 1234.567,
                energy_j: 8.25,
                energy_wall_ms: 1234.567,
                rounds: vec![RoundTiming { cpu_ms: 600.0, gpu_ms: 30.5, wall_ms: 630.5 }],
            },
            curve: vec![CurvePoint {
                round: 2,
                device_ms: 100.0,
                host_ms: 3.25,
                train_loss: 1.75,
                test_loss: 1.5,
                test_accuracy: 0.40625,
            }],
            round_device_ms: vec![630.5, 604.0, 604.0, 630.5],
            round_host_ms: vec![1.0, 2.0, 3.0, 4.0],
            delay_ms: vec![0.01, 0.02, 0.03, 0.04],
        }
    }

    #[test]
    fn snapshot_json_roundtrip_is_exact() {
        let snap = sample_snapshot();
        let text = snap.to_json().to_string_compact();
        let back = SessionSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), snap.fingerprint());
        assert_eq!(back.backend, "sequential");
        assert_eq!(back.round, 4);
        assert_eq!(back.params, snap.params);
        assert_eq!(back.selector.rng, snap.selector.rng);
        assert_eq!(back.selector.seen_per_class, snap.selector.seen_per_class);
        let (bf, sf) = (back.selector.filter.unwrap(), snap.selector.filter.unwrap());
        assert_eq!(bf.centroid, sf.centroid);
        assert_eq!(bf.norm2, sf.norm2);
        assert_eq!(bf.buffer_cap, sf.buffer_cap);
        assert_eq!(
            bf.buffer_thresh.map(f64::to_bits),
            sf.buffer_thresh.map(f64::to_bits)
        );
        assert_eq!(bf.processed, sf.processed);
        assert_eq!(bf.buffer.len(), 1);
        assert_eq!(bf.buffer[0].sample.id, 9);
        assert_eq!(bf.buffer[0].score.to_bits(), sf.buffer[0].score.to_bits());
        assert_eq!(*bf.buffer[0].sample.x, *sf.buffer[0].sample.x);
        let (br, sr) = (
            back.selector.retention.as_ref().unwrap(),
            snap.selector.retention.as_ref().unwrap(),
        );
        assert_eq!(br, sr, "retention state must roundtrip bit-exactly");
        assert_eq!(br.entries[0].score.to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(back.sim.total_ms.to_bits(), snap.sim.total_ms.to_bits());
        assert_eq!(back.sim.rounds.len(), 1);
        assert_eq!(back.sim.rounds[0].wall_ms, 630.5);
        assert_eq!(back.curve.len(), 1);
        assert_eq!(back.curve[0].test_accuracy, 0.40625);
        assert_eq!(back.round_device_ms, snap.round_device_ms);
        assert_eq!(back.round_host_ms, snap.round_host_ms);
        assert_eq!(back.delay_ms, snap.delay_ms);
    }

    /// Pre-retention snapshots (no "retention" key) and non-retaining
    /// runs (key omitted) both decode to `retention: None`, and a
    /// retention-free snapshot emits no "retention" key at all — old
    /// checkpoint files stay loadable and new non-retaining ones stay
    /// byte-identical to what earlier builds wrote.
    #[test]
    fn snapshots_without_retention_stay_compatible() {
        let mut snap = sample_snapshot();
        snap.selector.retention = None;
        let text = snap.to_json().to_string_compact();
        assert!(
            !text.contains("\"retention\""),
            "non-retaining snapshot must not emit a retention key"
        );
        let back = SessionSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.selector.retention.is_none());
    }

    #[test]
    fn fingerprint_mismatch_is_rejected() {
        let snap = sample_snapshot();
        let same = RunConfig { rounds: 10, ..RunConfig::default() };
        assert!(snap.check_matches(&same, "sequential").is_ok());
        assert!(snap.check_matches(&same, "pipelined").is_err());
        let other = RunConfig { rounds: 10, seed: 99, ..RunConfig::default() };
        assert!(snap.check_matches(&other, "sequential").is_err());
    }

    #[test]
    fn load_checkpoint_distinguishes_complete_runs() {
        let dir = std::env::temp_dir();
        let path = dir.join("titan_snapshot_load_test.json");
        let snap = sample_snapshot();
        std::fs::write(&path, snap.to_json().to_string_compact()).unwrap();
        assert!(matches!(
            load_checkpoint(&path).unwrap(),
            Loaded::Resumable(s) if s.round == 4
        ));

        let mut record = RunRecord::new("titan", "mlp");
        record.final_accuracy = 0.75;
        record.round_device_ms = vec![1.0; 6];
        record.curve.push(CurvePoint {
            round: 6,
            device_ms: 6.0,
            host_ms: 1.0,
            train_loss: 0.5,
            test_loss: 0.4,
            test_accuracy: 0.75,
        });
        let marker = completion_marker(&snap.config, &record);
        std::fs::write(&path, marker.to_string_compact()).unwrap();
        match load_checkpoint(&path).unwrap() {
            Loaded::Complete { round, final_accuracy, accuracy_trace, config } => {
                assert_eq!(round, 6);
                assert_eq!(final_accuracy, 0.75);
                assert_eq!(accuracy_trace, vec![(6, 0.75)]);
                assert_eq!(config.to_string_compact(), snap.fingerprint());
            }
            Loaded::Resumable(_) => panic!("completion marker loaded as resumable"),
        }

        std::fs::write(&path, "{\"not\": \"a checkpoint\"}").unwrap();
        assert!(load_checkpoint(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    /// ISSUE 6 satellite: clip a valid snapshot at many byte offsets —
    /// every clip must come back as a clean typed [`Error::Checkpoint`]
    /// naming the path, never a panic or a bare JSON error.
    #[test]
    fn truncated_checkpoints_yield_clean_typed_errors() {
        let dir = std::env::temp_dir().join("titan_snapshot_truncation");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        let full = sample_snapshot().to_json().to_string_compact();
        let mut cuts: Vec<usize> = (0..full.len()).step_by(7).collect();
        cuts.extend([1, full.len() / 2, full.len() - 1]);
        for cut in cuts {
            std::fs::write(&path, &full.as_bytes()[..cut]).unwrap();
            let err = match load_checkpoint(&path) {
                Err(e) => e,
                Ok(_) => panic!("clip at {cut}/{} loaded successfully", full.len()),
            };
            match &err {
                Error::Checkpoint { path: p, stage, .. } => {
                    assert!(p.contains("ck.json"), "error does not name the file: {err}");
                    assert!(
                        ["read", "parse", "version", "field"].contains(stage),
                        "unexpected stage {stage:?}: {err}"
                    );
                }
                other => panic!("clip at {cut}: untyped error {other:?}"),
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The typed error distinguishes what failed: parse vs. version vs.
    /// missing-field, each carrying the offending path.
    #[test]
    fn load_errors_name_path_and_stage() {
        let dir = std::env::temp_dir().join("titan_snapshot_stages");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        let stage_of = |body: &str| -> &'static str {
            std::fs::write(&path, body).unwrap();
            match load_checkpoint(&path) {
                Err(Error::Checkpoint { path: p, stage, .. }) => {
                    assert!(p.contains("ck.json"));
                    stage
                }
                other => panic!("expected typed checkpoint error, got {other:?}"),
            }
        };
        assert_eq!(stage_of("{\"titan_checkpoint\": 1,"), "parse");
        assert_eq!(stage_of("{\"complete\": false}"), "version");
        assert_eq!(stage_of("{\"titan_checkpoint\": 99, \"complete\": false}"), "version");
        // valid header, but the snapshot body is missing entirely
        assert_eq!(stage_of("{\"titan_checkpoint\": 1, \"complete\": false}"), "field");
        assert_eq!(stage_of("{\"titan_checkpoint\": 1, \"complete\": true}"), "field");
        // a missing file fails at the read stage
        let _ = std::fs::remove_file(&path);
        match load_checkpoint(&path) {
            Err(Error::Checkpoint { stage: "read", .. }) => {}
            other => panic!("expected read-stage error, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
